"""End-to-end design-space exploration — the paper's co-optimization flow.

Array-native API: declare a `DesignSpace`, score it in ONE vectorized
`dse.sweep` (density, margins, energy, bonding geometry, and the fused
row-cycle tRC all as flat batch arrays), then extract the Pareto front and
the selected design with masked array ops — i.e., regenerates the
substance of Table I / Fig. 9(c) without a single per-combo Python loop.

Run:  PYTHONPATH=src python examples/dram_codesign.py [--smoke] [--mc [N]]
                                                      [--sharded] [--replica]

`--smoke` sweeps a reduced layer grid on CPU — the fast API-regression
mode `tools/ci_check.sh` runs pre-merge.  `--mc [N]` additionally fans
the same space out to N Monte-Carlo samples per design point (SA-offset
+ Vth variation, still ONE fused transient batch) and reports margin/tRC
*yield* instead of nominal-only numbers.  `--sharded` distributes the
fused dispatch over every visible jax device (one slab per device; run
under XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on a
laptop) — results are bit-identical to the single-host sweep.
`--replica` closes the SA-enable timing with a replica bitline per design
point (instead of the fixed own-90% sense window) and prints a
fixed-vs-closed comparison on the Table-1 anchor points.
"""

import argparse

import numpy as np

from repro.core import calibration as cal
from repro.core import dse
from repro.core.space import DesignSpace

parser = argparse.ArgumentParser()
parser.add_argument("--smoke", action="store_true",
                    help="reduced layer grid (fast CI smoke mode)")
parser.add_argument("--mc", type=int, nargs="?", const=128, default=0,
                    metavar="SAMPLES",
                    help="Monte-Carlo samples per design point (default "
                         "128 when the flag is given without a value)")
parser.add_argument("--mc-key", type=int, default=0,
                    help="PRNG seed for the Monte-Carlo draws")
parser.add_argument("--mc-tail", type=int, nargs="?", const=4096, default=0,
                    metavar="SAMPLES",
                    help="importance-sampled deep-tail (ppm) margin-yield "
                         "estimate under correlated within-die variation "
                         "(default 4096 samples when the flag is given "
                         "without a value)")
parser.add_argument("--mc-tail-shift", type=float, default=4.0,
                    help="proposal shift (sigmas) of the SA-offset tail "
                         "draws")
parser.add_argument("--sharded", action="store_true",
                    help="shard the fused sweep over all jax devices")
parser.add_argument("--replica", action="store_true",
                    help="replica-bitline timing closure: the SA enable "
                         "fires on a per-point replica column's crossing "
                         "instead of the fixed own-90%% window")
args = parser.parse_args()

sharding = None
if args.sharded:
    import jax
    from repro.launch.shard import sweep_sharding
    sharding = sweep_sharding()          # all devices, one "batch" axis
    print(f"sharding the sweep over {jax.device_count()} device(s)")

grid = (64, 87, 137) if args.smoke else None
space = DesignSpace.paper_grid(layer_grid=grid)
if args.replica:
    space = space.with_replica()
    print("replica-closed SA-enable timing (per-point replica bitline)")
print(f"sweeping design space ({len(space)} design points, one fused "
      "transient batch)...")
batch = dse.sweep(space, sharding=sharding)

n_feas = int(np.asarray(batch.feasible).sum())
print(f"\n{len(batch)} design points, {n_feas} feasible "
      f"(margin nominal>={cal.MIN_FUNCTIONAL_MARGIN_MV:.0f} mV, "
      f"disturbed>={cal.MIN_DISTURBED_MARGIN_MV:.0f} mV, "
      f"pitch>={cal.HCB_MIN_MANUFACTURABLE_PITCH_UM} um)")

front = dse.pareto_front(batch)          # DesignBatch -> DesignBatch
print(f"\nPareto front ({len(front)} points):")
print(f"{'tech':5s} {'scheme':10s} {'L':>4s} {'Gb/mm2':>7s} {'dV(mV)':>7s} "
      f"{'dV+dist':>8s} {'tRC(ns)':>8s} {'Erd(fJ)':>8s} {'pitch':>6s}")
order = np.argsort(-np.asarray(front.density_gb_mm2))[:12]
for i in order:
    print(f"{front.tech_col[i]:5s} {front.scheme_col[i]:10s} "
          f"{int(front.layers[i]):4d} "
          f"{float(front.density_gb_mm2[i]):7.2f} "
          f"{float(front.margin_mv[i]):7.0f} "
          f"{float(front.margin_disturbed_mv[i]):8.0f} "
          f"{float(front.trc_ns[i]):8.2f} "
          f"{float(front.e_read_fj[i]):8.2f} "
          f"{float(front.hcb_pitch_um[i]):6.2f}")

best = dse.best_design(batch)            # paper's selection rule
print(f"\nselected design (paper's rule: hit {cal.DENSITY_TARGET_GB_MM2} "
      f"Gb/mm2, min tRC):")
print(f"  {best.tech} / {best.scheme} @ {best.layers} layers -> "
      f"{best.density_gb_mm2:.2f} Gb/mm2, tRC {best.trc_ns:.2f} ns, "
      f"margin {best.margin_mv:.0f} mV ({best.margin_disturbed_mv:.0f} mV "
      f"w/ FBE+RH), E_rd {best.e_read_fj:.2f} fJ, "
      f"HCB pitch {best.hcb_pitch_um:.2f} um")

# Table-1 anchors, read straight off the batch columns
tech_col, scheme_col = batch.tech_col, batch.scheme_col
def row(tech, scheme, layers):
    (i,) = [i for i in range(len(batch))
            if tech_col[i] == tech and scheme_col[i] == scheme
            and int(batch.layers[i]) == layers]
    return i

print("\nTable I anchors (from the DesignBatch):")
for tech, scheme, L in (("si", "sel_strap", 137), ("aos", "sel_strap", 87),
                        ("d1b", "direct", 1)):
    i = row(tech, scheme, L)
    print(f"  {tech:4s} {scheme:10s} @{L:3d}L: "
          f"{float(batch.density_gb_mm2[i]):4.2f} Gb/mm2  "
          f"tRC {float(batch.trc_ns[i]):5.2f} ns  "
          f"E_wr {float(batch.e_write_fj[i]):5.2f} fJ  "
          f"E_rd {float(batch.e_read_fj[i]):4.2f} fJ")

# ---------------------------------------------------------------------------
# Replica timing closure (--replica): fixed t_sense vs replica-closed on
# the Table-1 anchors — what per-point timing closure buys (and costs).
# ---------------------------------------------------------------------------
if args.replica:
    from repro.core.report import replica_timing_table
    cmp = replica_timing_table()
    print("\nfixed t_sense vs replica-closed (Table-1 anchors):")
    print(f"  {'tech':4s} {'cells':>5s} {'tRC fix':>8s} {'tRC clo':>8s} "
          f"{'dtRC':>6s} {'fire fix':>8s} {'fire clo':>8s} {'mrg@fire':>9s}")
    for tech, r in cmp.items():
        print(f"  {tech:4s} {r['replica_cells']:5.1f} "
              f"{r['trc_fixed_ns']:8.2f} {r['trc_closed_ns']:8.2f} "
              f"{r['trc_delta_ns']:6.2f} {r['t_fire_fixed_ns']:8.2f} "
              f"{r['t_fire_closed_ns']:8.2f} "
              f"{r['margin_fire_closed_mv']:9.1f}")

i_d1b = row("d1b", "direct", 1)
d1b_trc = float(batch.trc_ns[i_d1b])
d1b_erd = float(batch.e_read_fj[i_d1b])
d1b_dens = float(batch.density_gb_mm2[i_d1b])
print(f"\nvs D1b baseline: density x{best.density_gb_mm2 / d1b_dens:.1f}, "
      f"tRC x{d1b_trc / best.trc_ns:.2f} faster, "
      f"E_rd x{d1b_erd / best.e_read_fj:.2f} lower")

# ---------------------------------------------------------------------------
# Monte-Carlo yield (--mc): same space, fanned out to N samples per point,
# still ONE chunked fused row-cycle dispatch.
# ---------------------------------------------------------------------------
if args.mc:
    print(f"\n== Monte-Carlo yield: {args.mc} samples/design "
          f"(key {args.mc_key}, {len(space) * args.mc} rows, one fused "
          "batch) ==")
    mc_batch = dse.sweep(space.with_mc(samples=args.mc, key=args.mc_key),
                         sharding=sharding)
    trc_ceiling = 1.1 * d1b_trc / 2.0        # spec: comfortably beat D1b/2
    summary = mc_batch.mc_summary(margin_mv=cal.MIN_FUNCTIONAL_MARGIN_MV,
                                  trc_ns=trc_ceiling)
    yf = np.asarray(summary.corners["yield_frac"])
    p05_margin = np.asarray(mc_batch.quantile(0.05, "margin_mv"))
    p95_trc = np.asarray(mc_batch.quantile(0.95, "trc_ns"))

    print(f"spec: margin>={cal.MIN_FUNCTIONAL_MARGIN_MV:.0f} mV & "
          f"tRC<={trc_ceiling:.1f} ns")
    print("Table I anchors (yield over samples, p05 margin, p95 tRC):")
    for tech, scheme, L in (("si", "sel_strap", 137),
                            ("aos", "sel_strap", 87), ("d1b", "direct", 1)):
        i = row(tech, scheme, L)             # summary keeps the base layout
        print(f"  {tech:4s} {scheme:10s} @{L:3d}L: "
              f"yield {yf[i]:5.1%}  "
              f"margin_p05 {p05_margin[i]:6.1f} mV  "
              f"tRC_p95 {p95_trc[i]:5.2f} ns")

    best_y = dse.best_design(summary, min_yield=0.9)
    if best_y is None:
        print("no design meets the density target at >=90% yield")
    else:
        print(f"highest-yield selection (>=90% yield, paper's rule): "
              f"{best_y.tech} / {best_y.scheme} @ {best_y.layers} layers -> "
              f"yield {yf[row(best_y.tech, best_y.scheme, best_y.layers)]:.1%}, "
              f"median tRC {best_y.trc_ns:.2f} ns")

# ---------------------------------------------------------------------------
# Deep-tail ppm yield (--mc-tail): importance-sampled margin-tail estimate
# of the Table-1 target points under correlated within-die variation.  The
# SA-offset proposal is shifted into the failure tail; exact per-row
# log-weights ride the batch as the reserved mc_log_w channel and
# yield_ppm turns the weighted failures into a ppm estimate + CI + a
# tail-ESS diagnostic (NaN when too few effective failures were seen).
# ---------------------------------------------------------------------------
if args.mc_tail:
    shift = args.mc_tail_shift
    print(f"\n== ppm-tail yield: {args.mc_tail} importance samples/design "
          f"(SA proposal shifted {shift:.1f} sigma, correlated "
          "within-die draws) ==")
    tail_space = DesignSpace.paper_targets().with_mc(
        samples=args.mc_tail, key=args.mc_key, corr=1.0,
        tail_shift=(shift, 0.0), tail_scale=(1.2, 1.0))
    tail_batch = dse.sweep(tail_space, with_transient=False)
    floor = cal.MIN_FUNCTIONAL_MARGIN_MV
    ppm = tail_batch.yield_ppm(margin_mv=floor)
    base = tail_batch.base_len
    print(f"spec: margin>={floor:.0f} mV; failure rate in ppm "
          "(95% CI, tail ESS):")
    for i, tech in enumerate(tail_batch.tech_col[:base]):
        est = float(np.asarray(ppm["fail_ppm"])[i])
        lo = float(np.asarray(ppm["fail_ppm_lo"])[i])
        hi = float(np.asarray(ppm["fail_ppm_hi"])[i])
        ess = float(np.asarray(ppm["ess"])[i])
        layers = int(np.asarray(tail_batch.layers)[i])
        if np.isnan(est):
            print(f"  {tech:4s} @{layers:3d}L: no estimate "
                  f"(tail ESS {ess:.1f} too low — raise --mc-tail or "
                  "retune --mc-tail-shift)")
        else:
            print(f"  {tech:4s} @{layers:3d}L: {est:10.3f} ppm "
                  f"[{lo:.3f}, {hi:.3f}]  ESS {ess:.0f}")
