"""End-to-end design-space exploration — the paper's co-optimization flow.

Sweeps (technology x routing scheme x layer count), applies the paper's
feasibility rules (sense margin incl. FBE/RH, manufacturable HCB pitch),
prints the Pareto front and the selected design point, and compares it to
the D1b baseline — i.e., regenerates the substance of Table I / Fig. 9(c).

Run:  PYTHONPATH=src python examples/dram_codesign.py
"""

import numpy as np

from repro.core import calibration as cal
from repro.core.dse import best_design, full_sweep, pareto_front

print("sweeping design space (2 techs x 4 routing schemes x 9 layer "
      "counts, full transient per point)...")
pts = full_sweep()

feas = [p for p in pts if p.feasible]
print(f"\n{len(pts)} design points, {len(feas)} feasible "
      f"(margin nominal>={cal.MIN_FUNCTIONAL_MARGIN_MV:.0f} mV, "
      f"disturbed>={cal.MIN_DISTURBED_MARGIN_MV:.0f} mV, "
      f"pitch>={cal.HCB_MIN_MANUFACTURABLE_PITCH_UM} um)")

front = pareto_front(pts)
print(f"\nPareto front ({len(front)} points):")
print(f"{'tech':5s} {'scheme':10s} {'L':>4s} {'Gb/mm2':>7s} {'dV(mV)':>7s} "
      f"{'dV+dist':>8s} {'tRC(ns)':>8s} {'Erd(fJ)':>8s} {'pitch':>6s}")
for p in sorted(front, key=lambda p: -p.density_gb_mm2)[:12]:
    print(f"{p.tech:5s} {p.scheme:10s} {p.layers:4d} "
          f"{p.density_gb_mm2:7.2f} {p.margin_mv:7.0f} "
          f"{p.margin_disturbed_mv:8.0f} {p.trc_ns:8.2f} "
          f"{p.e_read_fj:8.2f} {p.hcb_pitch_um:6.2f}")

best = best_design(pts)
print(f"\nselected design (paper's rule: hit {cal.DENSITY_TARGET_GB_MM2} "
      f"Gb/mm2, min tRC):")
print(f"  {best.tech} / {best.scheme} @ {best.layers} layers -> "
      f"{best.density_gb_mm2:.2f} Gb/mm2, tRC {best.trc_ns:.2f} ns, "
      f"margin {best.margin_mv:.0f} mV ({best.margin_disturbed_mv:.0f} mV "
      f"w/ FBE+RH), E_rd {best.e_read_fj:.2f} fJ, "
      f"HCB pitch {best.hcb_pitch_um:.2f} um")

d1b = [p for p in pts if p.tech == "d1b"][0]
print(f"\nvs D1b baseline: density x{best.density_gb_mm2 / d1b.density_gb_mm2:.1f}, "
      f"tRC x{d1b.trc_ns / best.trc_ns:.2f} faster, "
      f"E_rd x{d1b.e_read_fj / best.e_read_fj:.2f} lower")
