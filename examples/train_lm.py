"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the real subsystems: synthetic-corpus data pipeline, AdamW, remat,
checkpointing every 100 steps, fault injection at step 150 (the loop
restores and continues), loss curve printed.

~100M params: olmo-1b config scaled to d_model=512, 8 layers, vocab 50304.
On a laptop-class CPU this runs ~200 steps in a few minutes.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

from repro.configs.registry import get_arch
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    base = get_arch("olmo-1b")
    cfg = dataclasses.replace(
        base, name="olmo-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, head_dim=64, d_ff=2048, attn_chunk=128,
        param_dtype="float32", compute_dtype="float32")
    n = cfg.param_count()
    print(f"training {cfg.name}: {n / 1e6:.0f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    tc = TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        ckpt_every=100, ckpt_dir="/tmp/repro_train_lm", log_every=10,
        opt=OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        failure_schedule={150: "crash"} if args.steps > 150 else {})
    out = train(cfg, tc)
    print(f"\nfinal: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"({out['restarts']} restarts survived)")
    assert out["final_loss"] < out["first_loss"], "training must improve"


if __name__ == "__main__":
    main()
