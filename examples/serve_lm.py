"""Batched serving with the selector+strap KV cache.

Compares dense decode vs StrapCache exact mode (bit-identical greedy
stream) vs gated mode (top-k straps: the paper's C_BL-reduction analogue),
reporting tokens/s and HBM traffic.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.memory.strap_cache import StrapCacheConfig
from repro.models import registry as M
from repro.serving.engine import ServeEngine

cfg = get_arch("qwen2-1.5b-smoke")
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
B, PROMPT, NEW = 4, 128, 16
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, PROMPT)), jnp.int32)
MAX = PROMPT + NEW + 16

print(f"batch={B}, prompt={PROMPT}, new_tokens={NEW}\n")
results = {}
for name, backend, top in (("dense", "dense", 0),
                           ("strap-exact", "strap", 0),
                           ("strap-gated(top4)", "strap", 4)):
    eng = ServeEngine(cfg, params, max_tokens=MAX, cache_backend=backend,
                      strap_cfg=StrapCacheConfig(page_size=16,
                                                 pages_per_strap=2,
                                                 top_straps=top))
    t0 = time.time()
    out = eng.generate(prompts, NEW)
    dt = time.time() - t0
    results[name] = np.asarray(out)
    line = f"{name:18s} {B * NEW / dt:7.1f} tok/s"
    if backend == "strap":
        line += (f"   HBM traffic vs dense: "
                 f"{100 * eng.stats.traffic_reduction:5.1f}%")
    print(line)

exact_match = (results["dense"] == results["strap-exact"]).all()
gated_match = (results["dense"] == results["strap-gated(top4)"]).mean()
print(f"\nstrap-exact == dense: {bool(exact_match)} (bit-identical greedy)")
print(f"gated token agreement: {100 * gated_match:.0f}% "
      f"(untrained weights = worst case for the selector; trained models "
      f"concentrate attention mass within few straps)")
assert exact_match
