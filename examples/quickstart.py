"""Quickstart: the whole system in ~60 seconds on CPU.

1. Reproduce the paper's headline numbers with the STCO engine.
2. Train a tiny LM for a few steps (fault-tolerant loop).
3. Serve it with the StrapCache (selector+strap) decode path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- paper --
from repro.core import dse
from repro.core.space import DesignSpace

print("== 1. Paper reproduction (selector+strap vs D1b) ==")
# One vectorized sweep of the Table-1 target points; the printed numbers
# are read straight off the DesignBatch columns.
batch = dse.sweep(DesignSpace.paper_targets())
for i, tech in enumerate(batch.tech_col):
    print(f"  {tech:4s}: C_BL={float(batch.cbl_ff[i]):5.2f} fF  "
          f"margin={float(batch.margin_mv[i]):5.0f} mV  "
          f"tRC={float(batch.trc_ns[i]):5.2f} ns")

# ---------------------------------------------------------------- train --
from repro.configs.registry import get_arch
from repro.train.loop import TrainConfig, train

print("\n== 2. Train a reduced qwen2 for 20 steps (with crash injection) ==")
cfg = get_arch("qwen2-1.5b-smoke")
out = train(cfg, TrainConfig(steps=20, batch_size=4, seq_len=64,
                             ckpt_every=8, ckpt_dir="/tmp/quickstart_ckpt",
                             log_every=5, failure_schedule={11: "crash"}))
print(f"  loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
      f"({out['restarts']} fault restart)")

# ---------------------------------------------------------------- serve --
from repro.memory.strap_cache import StrapCacheConfig
from repro.serving.engine import ServeEngine

print("\n== 3. Serve with StrapCache (exact mode == dense, verified) ==")
params = out["state"]["params"]
prompts = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (2, 32)), jnp.int32)
eng = ServeEngine(cfg, params, max_tokens=48, cache_backend="strap",
                  strap_cfg=StrapCacheConfig(page_size=8, pages_per_strap=2))
toks = eng.generate(prompts, 8)
print(f"  decoded: {np.asarray(toks)[0].tolist()}")
print(f"  strap-cache traffic vs dense: "
      f"{100 * eng.stats.traffic_reduction:.0f}%")
print("\nquickstart OK")
