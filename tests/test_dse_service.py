"""Co-design-as-a-service engine (PR 8).

Covers the serving subsystem's contract:

1. Micro-batch windows: >= 2 concurrent clients' mixed sweep/yield
   queries pack into ONE shared fused dispatch per (replica-mode) group,
   and each demuxed `DesignBatch` is bit-identical to the client calling
   `dse.sweep` directly.
2. LRU memo: hit/miss/eviction accounting, corner-hash sensitivity
   (spaces differing in any corner/MC value never collide), and
   same-key responses bit-identical to a fresh sweep.
3. Streaming: chunked partial results concat back to the monolithic
   sweep; MC spaces are rejected (draws depend on the base length).
4. Batch helpers (`slice_rows`/`concat`) and the `as_batch` adapter the
   batch-native API cleanup hangs on, plus the legacy-view
   DeprecationWarnings.
"""

import threading

import numpy as np
import pytest

from repro.core import dse, transient
from repro.core.batch import ARRAY_FIELDS, DesignBatch, DesignPoint
from repro.core.space import DesignSpace
from repro.serving.dse_service import DSEService, Query, request_key

S_A = DesignSpace.product(techs=["aos"], layers=(87, 137))
S_B = DesignSpace.product(techs=["si"], layers=(87,))
S_MC = DesignSpace.product(techs=["aos"], layers=(87,)).with_mc(
    samples=8, key=5)


def assert_batches_identical(a: DesignBatch, b: DesignBatch):
    """NaN-aware bit-identity across every array field, corner channel
    and the static aux data."""
    assert a.tech_names == b.tech_names
    assert a.scheme_names == b.scheme_names
    assert a.n_samples == b.n_samples
    # base_len 0 is the "= len" sentinel, so compare the effective value
    assert (a.base_len or len(a)) == (b.base_len or len(b))
    assert set(a.corners) == set(b.corners)

    def eq(x, y):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype.kind == "f":
            return ((x == y) | (np.isnan(x) & np.isnan(y))).all()
        return (x == y).all()

    for f in ARRAY_FIELDS:
        assert eq(getattr(a, f), getattr(b, f)), f
    for k in a.corners:
        assert eq(a.corners[k], b.corners[k]), f"corners[{k}]"


@pytest.fixture
def svc():
    return DSEService(window_ms=0.0)


@pytest.fixture
def count_dispatches(monkeypatch):
    """Count the service's packed fused dispatches (the serving seam —
    direct `dse.sweep` calls go through `simulate_row_cycle_many` and
    are not counted)."""
    calls = []
    orig = transient.row_cycle_events

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(transient, "row_cycle_events", counting)
    return calls


class TestMicroBatchWindow:
    def test_two_clients_share_one_dispatch(self, svc, count_dispatches):
        fa, fb = svc.submit(S_A), svc.submit(S_B)
        assert svc.flush() == 2
        assert len(count_dispatches) == 1
        assert_batches_identical(fa.result(timeout=0).batch, dse.sweep(S_A))
        assert_batches_identical(fb.result(timeout=0).batch, dse.sweep(S_B))

    def test_mixed_sweep_yield_one_dispatch(self, svc, count_dispatches):
        fa = svc.submit(S_A)
        fy = svc.submit(S_MC, kind="yield", spec={"margin_mv": 5.0})
        svc.flush()
        assert len(count_dispatches) == 1
        ry = fy.result(timeout=0)
        assert_batches_identical(ry.batch, dse.sweep(S_MC))
        assert "yield_frac" in ry.summary.corners
        assert len(ry.summary) == len(S_MC) // 8
        assert_batches_identical(fa.result(timeout=0).batch, dse.sweep(S_A))

    def test_replica_mode_gets_own_dispatch(self, svc, count_dispatches):
        s_rep = S_A.with_replica()
        fa, fr = svc.submit(S_A), svc.submit(s_rep)
        svc.flush()
        # replica operands interleave [replica, main] rows, so the two
        # modes cannot share a slab: one dispatch per group
        assert len(count_dispatches) == 2
        assert_batches_identical(fa.result(timeout=0).batch, dse.sweep(S_A))
        assert_batches_identical(fr.result(timeout=0).batch,
                                 dse.sweep(s_rep))

    def test_identical_queries_coalesce(self, svc, count_dispatches):
        f1, f2 = svc.submit(S_A), svc.submit(S_A)
        svc.flush()
        assert len(count_dispatches) == 1
        st = svc.stats()
        assert st["memo"]["coalesced"] == 1
        assert st["memo"]["misses"] == 1
        assert_batches_identical(f1.result(timeout=0).batch,
                                 f2.result(timeout=0).batch)

    def test_background_dispatcher_serves_threads(self):
        out = {}
        barrier = threading.Barrier(2)

        def client(name, space, service):
            barrier.wait()
            out[name] = service.sweep(space, timeout=60.0)

        with DSEService(window_ms=25.0) as service:
            threads = [threading.Thread(target=client,
                                        args=(n, s, service))
                       for n, s in (("a", S_A), ("b", S_B))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            st = service.stats()
        assert st["windows"] >= 1 and st["requests"] == 2
        assert_batches_identical(out["a"], dse.sweep(S_A))
        assert_batches_identical(out["b"], dse.sweep(S_B))

    def test_bad_request_fails_only_its_own_future(self, svc):
        bad = DesignSpace.product(techs=["aos"], layers=(87,)) \
            .with_corners(not_an_axis=(1.0,))
        fb, fa = svc.submit(bad), svc.submit(S_A)
        svc.flush()
        with pytest.raises(ValueError, match="unsupported corner axes"):
            fb.result(timeout=0)
        assert_batches_identical(fa.result(timeout=0).batch, dse.sweep(S_A))
        assert svc.stats()["errors"] == 1


class TestMemo:
    def test_repeat_answers_from_memo(self, svc, count_dispatches):
        first = svc.sweep(S_A)
        f = svc.submit(S_A)
        svc.flush()
        r = f.result(timeout=0)
        assert r.memo_hit
        assert len(count_dispatches) == 1          # no re-dispatch
        # the memoized response stays bit-identical to a fresh sweep
        assert_batches_identical(r.batch, dse.sweep(S_A))
        assert_batches_identical(r.batch, first)

    def test_corner_values_never_collide(self, svc, count_dispatches):
        base = DesignSpace.product(techs=["aos"], layers=(87,))
        c1 = base.with_corners(rh_toggles=(1e5,))
        c2 = base.with_corners(rh_toggles=(3e5,))
        assert request_key(c1) != request_key(c2)
        svc.sweep(c1)
        f = svc.submit(c2)
        svc.flush()
        r = f.result(timeout=0)
        assert not r.memo_hit
        assert len(count_dispatches) == 2
        assert np.asarray(r.batch.corners["rh_toggles"])[0] == 3e5

    def test_mc_key_and_flags_partition_the_memo(self):
        base = DesignSpace.product(techs=["aos"], layers=(87,))
        keys = {request_key(base),
                request_key(base, with_transient=False),
                request_key(base.with_replica()),
                request_key(base.with_mc(samples=8, key=0)),
                request_key(base.with_mc(samples=8, key=1)),
                request_key(base.with_mc(samples=16, key=0))}
        assert len(keys) == 6

    def test_lru_eviction(self, count_dispatches):
        service = DSEService(window_ms=0.0, memo_entries=2)
        service.sweep(S_A)
        service.sweep(S_B)
        service.sweep(S_A)                         # touch A: B becomes LRU
        s_c = DesignSpace.product(techs=["d1b"])
        service.sweep(s_c)                         # evicts B
        st = service.stats()
        assert st["memo"]["evictions"] == 1
        assert st["memo"]["entries"] == 2
        n = len(count_dispatches)
        assert service.submit(S_B) and service.flush() == 1
        assert len(count_dispatches) == n + 1      # B was evicted: re-dispatch
        # re-inserting B pushed A out (LRU after the C insert); C survived
        n = len(count_dispatches)
        f = service.submit(s_c)
        service.flush()
        assert f.result(timeout=0).memo_hit
        assert len(count_dispatches) == n
        assert service.stats()["memo"]["evictions"] == 2

    def test_memo_disabled(self, count_dispatches):
        service = DSEService(window_ms=0.0, memo_entries=0)
        service.sweep(S_A)
        service.sweep(S_A)
        assert len(count_dispatches) == 2
        assert service.stats()["memo"]["entries"] == 0


class TestStreaming:
    def test_chunks_concat_to_monolithic_sweep(self, svc):
        space = DesignSpace.product(techs=["aos", "si"], layers=(87, 137))
        chunks = list(svc.sweep_stream(space, chunk_rows=4))
        assert len(chunks) > 1
        for c in chunks:
            assert_batches_identical(c.response.batch, dse.sweep(c.space))
        merged = DesignBatch.concat([c.response.batch for c in chunks])
        assert_batches_identical(merged, dse.sweep(space))

    def test_restream_hits_memo(self, svc, count_dispatches):
        space = DesignSpace.product(techs=["aos"], layers=(87, 137))
        list(svc.sweep_stream(space, chunk_rows=2))
        n = len(count_dispatches)
        again = list(svc.sweep_stream(space, chunk_rows=2))
        assert len(count_dispatches) == n
        assert all(c.response.memo_hit for c in again)
        assert svc.stats()["chunks_streamed"] == 2 * len(again)

    def test_mc_space_rejected(self, svc):
        with pytest.raises(ValueError, match="sweep_stream cannot chunk"):
            next(iter(svc.sweep_stream(S_MC)))


class TestQueryValidation:
    def test_bad_kind(self):
        with pytest.raises(ValueError, match="unknown query kind"):
            Query.make(S_A, kind="mystery")

    def test_yield_needs_mc(self):
        with pytest.raises(ValueError, match="needs a Monte-Carlo space"):
            Query.make(S_A, kind="yield")

    def test_bad_spec_key(self):
        with pytest.raises(ValueError, match="unknown spec key"):
            Query.make(S_MC, kind="yield", spec={"margin_Mv": 5.0})

    def test_spec_only_for_yield(self):
        with pytest.raises(ValueError, match="only applies to yield"):
            Query.make(S_A, kind="sweep", spec={"margin_mv": 5.0})

    def test_space_type_checked(self):
        with pytest.raises(TypeError, match="needs a DesignSpace"):
            Query.make("aos")


class TestBatchHelpers:
    def test_slice_concat_roundtrip(self):
        batch = dse.sweep(S_A)
        parts = [batch.slice_rows(0, 3), batch.slice_rows(3, len(batch))]
        assert len(parts[0]) == 3
        merged = DesignBatch.concat(parts)
        assert_batches_identical(merged, batch)

    def test_slice_bounds_checked(self):
        batch = dse.sweep(S_A)
        with pytest.raises(ValueError):
            batch.slice_rows(0, len(batch) + 1)
        with pytest.raises(ValueError):
            batch.slice_rows(-1, 2)

    def test_concat_remaps_name_tables(self):
        a, b = dse.sweep(S_A), dse.sweep(S_B)
        merged = DesignBatch.concat([a, b])
        assert len(merged) == len(a) + len(b)
        decode = lambda bt: [bt.tech_names[i]
                             for i in np.asarray(bt.tech_idx)]
        assert decode(merged) == decode(a) + decode(b)
        schemes = lambda bt: [bt.scheme_names[i]
                              for i in np.asarray(bt.scheme_idx)]
        assert schemes(merged) == schemes(a) + schemes(b)

    def test_concat_rejects_mc_and_mismatched_corners(self):
        mc = dse.sweep(S_MC)
        with pytest.raises(ValueError, match="n_samples == 1"):
            DesignBatch.concat([mc, mc])
        plain = dse.sweep(S_A)
        cornered = dse.sweep(DesignSpace.product(techs=["aos"],
                                                 layers=(87,))
                             .with_corners(rh_toggles=(1e5,)))
        with pytest.raises(ValueError, match="corner channels"):
            DesignBatch.concat([plain, cornered])


class TestAsBatchAdapter:
    def test_passthrough_and_points(self):
        batch = dse.sweep(S_A)
        assert dse.as_batch(batch) is batch
        with pytest.warns(DeprecationWarning):
            pts = batch.to_points()
        rebuilt = dse.as_batch(pts)
        assert isinstance(rebuilt, DesignBatch)
        assert len(rebuilt) == len(batch)

    def test_pareto_front_list_in_list_out(self):
        batch = dse.sweep(S_A)
        with pytest.warns(DeprecationWarning):
            pts = batch.to_points()
        front_pts = dse.pareto_front(pts)
        front_batch = dse.pareto_front(batch)
        assert all(isinstance(p, DesignPoint) for p in front_pts)
        assert isinstance(front_batch, DesignBatch)
        assert len(front_pts) == len(front_batch)


class TestThreadStress:
    """N concurrent clients x M repeated queries against the live
    dispatcher (REPRO_CHECKS=1 via conftest): every response must stay
    bit-identical to a direct `dse.sweep`, and the `stats()` counters
    must reconcile — `requests == memo_hits + dispatched-served`
    (misses + coalesced), with nothing queued and no errors."""

    N_CLIENTS = 6
    N_ITERS = 4
    SPACES = (S_A, S_B,
              DesignSpace.product(techs=["d1b"], layers=(87,)))

    def _hammer(self, service):
        results = [[] for _ in range(self.N_CLIENTS)]
        errors = []
        barrier = threading.Barrier(self.N_CLIENTS)

        def client(i):
            try:
                barrier.wait()
                for j in range(self.N_ITERS):
                    k = (i + j) % len(self.SPACES)
                    results[i].append(
                        (k, service.sweep(self.SPACES[k], timeout=120.0)))
            except Exception as e:               # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(self.N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        return results

    def _check_identity(self, results):
        golden = [dse.sweep(s) for s in self.SPACES]
        for per_thread in results:
            assert len(per_thread) == self.N_ITERS
            for k, batch in per_thread:
                assert_batches_identical(batch, golden[k])

    def test_stress_memo_on(self):
        with DSEService(window_ms=2.0, memo_entries=64) as service:
            results = self._hammer(service)
            st = service.stats()
        self._check_identity(results)
        total = self.N_CLIENTS * self.N_ITERS
        memo = st["memo"]
        assert st["requests"] == total
        # every request is served exactly once: memo hit, dispatched as
        # a window miss, or coalesced onto a window twin
        assert memo["hits"] + memo["misses"] + memo["coalesced"] == total
        # each distinct space misses at least its first lookup
        assert memo["misses"] >= len(self.SPACES)
        assert st["queued"] == 0 and st["errors"] == 0
        assert st["windows"] >= 1 and st["dispatches"] >= 1
        assert st["rows"]["dispatched"] >= st["dispatches"]

    def test_stress_memo_off(self):
        with DSEService(window_ms=2.0, memo_entries=0) as service:
            results = self._hammer(service)
            st = service.stats()
        self._check_identity(results)
        total = self.N_CLIENTS * self.N_ITERS
        memo = st["memo"]
        assert st["requests"] == total
        assert memo["hits"] == 0 and memo["entries"] == 0
        # with no memo every request is a window miss or a coalesced twin
        assert memo["misses"] + memo["coalesced"] == total
        assert st["queued"] == 0 and st["errors"] == 0
        # all queries are nominal, so each window packs its misses into
        # one slab: never more dispatches than misses, never zero
        assert 1 <= st["dispatches"] <= memo["misses"]


class TestDeprecations:
    def test_legacy_views_warn(self):
        with pytest.warns(DeprecationWarning, match="full_sweep is deprecated"):
            dse.full_sweep(layer_grid=(87,), with_transient=False)
        with pytest.warns(DeprecationWarning,
                          match="sweep_combos is deprecated"):
            dse.sweep_combos(layer_grid=(87,))
        with pytest.warns(DeprecationWarning, match="to_points is deprecated"):
            dse.sweep(S_B).to_points()
