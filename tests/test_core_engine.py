"""Core STCO engine behaviour: transient vs closed form, routing story,
DSE feasibility logic, device models."""

import numpy as np
import jax.numpy as jnp

from repro.core import calibration as cal
from repro.core.calibration import SI
from repro.core.device_models import (AOS_ACCESS, IGO_SELECTOR, SI_ACCESS,
                                      ids_ua, retention_time_ms,
                                      subthreshold_swing_mv_dec)
from repro.core.dse import best_design, evaluate_grid, full_sweep, pareto_front
from repro.core.netlist import build_bl_ladder, effective_cbl_ff
from repro.core.routing import SCHEMES, bonding_geometry
from repro.core.sense import charge_share_mv, sense_margin_mv
from repro.kernels import ref


class TestTransientVsAnalytic:
    def test_single_rc_decay(self):
        """One node discharging through a clamp: v(t)=v0*exp(-t/RC)."""
        r, c, dt, t = 10.0, 5.0, 0.0005, 600      # dt/tau = 0.01
        cN = jnp.asarray([[c, 1e-6]])
        g = jnp.asarray([[1e-9]])                 # access branch ~open
        gc = jnp.asarray([[1.0 / r, 0.0]])
        vc = jnp.zeros((1, 2))
        v0 = jnp.asarray([[1.0, 0.0]])
        ramp = jnp.zeros((t,))
        tr = ref.rc_multistep_ref(cN, g, gc, vc, v0, ramp, dt)
        tau = r * c * 1e-3                         # ns
        ts = (np.arange(t) + 1) * dt
        expect = np.exp(-ts / tau)
        # implicit-Euler drift bound: exp(N (dt/tau)^2 / 2) ~ 3% at the tail
        np.testing.assert_allclose(np.array(tr[:, 0, 0]), expect,
                                   rtol=0.04, atol=1e-4)

    def test_charge_sharing_asymptote(self):
        """Two capacitors through a resistor settle to the weighted mean."""
        c1, c2, r = 6.6, 4.0, 50.0
        cN = jnp.asarray([[c1, c2]])
        g = jnp.asarray([[1.0 / r]])
        gc = jnp.zeros((1, 2))
        vc = jnp.zeros((1, 2))
        v0 = jnp.asarray([[0.55, 1.1]])
        ramp = jnp.ones((4000,))
        tr = ref.rc_multistep_ref(cN, g, gc, vc, v0, ramp, 0.005)
        vfinal = float(tr[-1, 0, 0])
        expect = (c1 * 0.55 + c2 * 1.1) / (c1 + c2)
        assert abs(vfinal - expect) < 1e-3
        # and the analytic charge-share margin agrees
        dv = expect - 0.55
        model = float(charge_share_mv(SI, "sel_strap",
                                      jnp.asarray([137]))[0]) / 1e3
        cbl = float(effective_cbl_ff(SI, "sel_strap", jnp.asarray([137]))[0])
        assert abs(dv - 0.55 * 4.0 / (4.0 + 6.6)) < 1e-3
        assert abs(model - 0.55 * 4.0 / (4.0 + cbl)) < 1e-4


class TestRoutingStory:
    """The paper's Fig. 3(c) narrative must emerge from the models."""

    def test_direct_lowest_cbl(self):
        L = jnp.asarray([137])
        cbls = {s: float(effective_cbl_ff(SI, s, L)[0]) for s in SCHEMES}
        assert cbls["direct"] == min(cbls.values())
        assert cbls["strap"] == max(cbls.values())
        assert cbls["strap"] > 2.5 * cbls["sel_strap"]

    def test_only_sel_strap_is_viable(self):
        L = jnp.asarray([137])
        viable = {}
        for s in SCHEMES:
            margin_ok = (float(sense_margin_mv(SI, s, L)[0])
                         >= cal.MIN_FUNCTIONAL_MARGIN_MV
                         and float(sense_margin_mv(SI, s, L, True)[0])
                         >= cal.MIN_DISTURBED_MARGIN_MV)
            pitch_ok = bool(bonding_geometry(SI, s).manufacturable)
            viable[s] = margin_ok and pitch_ok
        assert viable == {"direct": False, "strap": False,
                          "core_mux": False, "sel_strap": True}

    def test_selector_isolation_cuts_cbl(self):
        L = jnp.asarray([137])
        with_sel = float(effective_cbl_ff(SI, "sel_strap", L)[0])
        without = float(effective_cbl_ff(SI, "strap", L)[0])
        assert without / with_sel > 2.0


class TestDSE:
    def test_sweep_and_best_design(self):
        pts = full_sweep(layer_grid=np.array([64, 87, 137, 200]),
                         with_transient=False)
        best = best_design(pts)
        assert best is not None
        assert best.scheme == "sel_strap"
        assert best.density_gb_mm2 >= 2.6 - 1e-6

    def test_pareto_nonempty_and_nondominated(self):
        pts = evaluate_grid(SI, "sel_strap", np.array([64, 100, 137]),
                            with_transient=False)
        front = pareto_front(pts, require_feasible=False)
        assert front
        for p in front:
            assert not any(
                q.density_gb_mm2 >= p.density_gb_mm2
                and q.margin_disturbed_mv > p.margin_disturbed_mv
                and q.e_read_fj <= p.e_read_fj for q in front if q is not p
                if q.density_gb_mm2 > p.density_gb_mm2)


class TestDeviceModels:
    def test_igo_ion_anchor(self):
        """IGO selector: Ion > 50 uA at Vgs=2 V (paper Fig. 6)."""
        ion = float(ids_ua(IGO_SELECTOR, 2.0, 1.0))
        assert ion > 50.0

    def test_subthreshold_slopes(self):
        assert abs(float(subthreshold_swing_mv_dec(IGO_SELECTOR)) - 60) < 8
        assert abs(float(subthreshold_swing_mv_dec(AOS_ACCESS)) - 65) < 8
        assert abs(float(subthreshold_swing_mv_dec(SI_ACCESS)) - 85) < 10

    def test_aos_retention_advantage(self):
        t_aos = float(retention_time_ms(AOS_ACCESS, 4.0))
        t_si = float(retention_time_ms(SI_ACCESS, 4.0))
        assert t_aos > 1000 * t_si          # oxide channel ~1e-19 A
        assert t_aos > 64.0                 # beats the refresh window

    def test_ids_monotone_in_vgs(self):
        v = jnp.linspace(0.0, 2.0, 41)
        i = np.array(ids_ua(SI_ACCESS, v, 0.5))
        assert (np.diff(i) > 0).all()


class TestLadder:
    def test_ladder_caps_sum_to_cbl_plus_cs(self):
        L = jnp.asarray([137])
        lad = build_bl_ladder(SI, "sel_strap", L)
        total = float(lad.c.sum())
        cbl = float(effective_cbl_ff(SI, "sel_strap", L)[0])
        assert abs(total - (cbl + cal.CS_FF)) < 1e-4
