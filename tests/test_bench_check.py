"""Benchmark regression gate (`tools/bench_check.py`).

Unit-tests the gate against synthetic records — a regression beyond the
tolerance fails, runner noise inside it passes, and missing metrics or
malformed JSON fail loudly — plus the schema check that committed
baselines (and, @slow, a fresh `benchmarks/run.py --json` run) contain
only finite numeric metrics.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import bench_check  # noqa: E402  (tools/ is not a package)


def record(fused_designs_per_s=50_000.0, sharded_points_per_s=9_000.0,
           replica_designs_per_s=None, pareto_points_per_s=80_000.0,
           elastic_frac=0.25):
    # replica throughput tracks the plain fused metric (~half: 2 rows
    # per design) unless a test pins it explicitly
    if replica_designs_per_s is None:
        replica_designs_per_s = fused_designs_per_s / 2
    return {
        "meta": {"backend": "cpu"},
        "benches": {
            "fused_rc": {"batch": 1024,
                         "designs_per_s": fused_designs_per_s,
                         "replica_designs_per_s": replica_designs_per_s},
            "sharded_sweep": {
                "per_device": {"1": {"points_per_s": sharded_points_per_s}},
                "best_scaling_vs_1dev": 1.7,
                "sharded_pareto_points_per_s": pareto_points_per_s,
                "elastic_resume_overhead_frac": elastic_frac,
            },
        },
        "failed": [],
    }


def write(tmp_path, name, payload) -> Path:
    path = tmp_path / name
    path.write_text(payload if isinstance(payload, str)
                    else json.dumps(payload))
    return path


def run_main(tmp_path, current, baseline, **kw) -> int:
    cur = write(tmp_path, "current.json", current)
    base = write(tmp_path, "baseline.json", baseline)
    argv = ["--current", str(cur), "--baseline", str(base)]
    for k, v in kw.items():
        argv += [f"--{k.replace('_', '-')}", str(v)]
    return bench_check.main(argv)


class TestGate:
    def test_within_tolerance_passes(self, tmp_path, capsys):
        # a 20% dip is shared-runner noise, not a regression
        assert run_main(tmp_path, record(40_000.0, 7_500.0),
                        record()) == 0
        assert "bench_check: OK" in capsys.readouterr().out

    def test_improvement_passes_and_suggests_rebaseline(self, tmp_path,
                                                        capsys):
        assert run_main(tmp_path, record(120_000.0, 20_000.0),
                        record()) == 0
        assert "re-baselining" in capsys.readouterr().out

    def test_regression_detected(self, tmp_path, capsys):
        # >35% throughput drop on the fused engine fails, and the
        # message names the offending metric
        assert run_main(tmp_path, record(fused_designs_per_s=30_000.0),
                        record()) == 1
        err = capsys.readouterr().err
        assert "fused_rc.designs_per_s" in err
        assert "regression" in err

    def test_regression_on_replica_metric(self, tmp_path, capsys):
        # the replica variant is gated independently of the plain metric
        assert run_main(tmp_path, record(replica_designs_per_s=10_000.0),
                        record()) == 1
        assert ("fused_rc.replica_designs_per_s"
                in capsys.readouterr().err)

    def test_regression_on_sharded_metric(self, tmp_path, capsys):
        assert run_main(tmp_path, record(sharded_points_per_s=2_000.0),
                        record()) == 1
        assert ("sharded_sweep.per_device.1.points_per_s"
                in capsys.readouterr().err)

    def test_custom_tolerance(self, tmp_path):
        current = record(fused_designs_per_s=40_000.0)   # -20%
        assert run_main(tmp_path, current, record(),
                        max_regression=0.1) == 1
        assert run_main(tmp_path, current, record(),
                        max_regression=0.3) == 0

    def test_missing_metric_fails(self, tmp_path, capsys):
        broken = record()
        del broken["benches"]["fused_rc"]["designs_per_s"]
        assert run_main(tmp_path, broken, record()) == 2
        assert "missing" in capsys.readouterr().err
        # ... and a baseline bench absent from the current record too
        gone = record()
        del gone["benches"]["sharded_sweep"]
        assert run_main(tmp_path, gone, record()) == 2

    def test_all_broken_metrics_reported_at_once(self, tmp_path, capsys):
        # TWO unreadable gated metrics -> ONE aggregated error naming
        # both, so a broken record is fixed in one round trip
        broken = record()
        del broken["benches"]["fused_rc"]["designs_per_s"]
        del broken["benches"]["sharded_sweep"][
            "sharded_pareto_points_per_s"]
        assert run_main(tmp_path, broken, record()) == 2
        err = capsys.readouterr().err
        assert "fused_rc.designs_per_s" in err
        assert "sharded_sweep.sharded_pareto_points_per_s" in err
        assert "2 gated metric(s)" in err

    def test_lower_is_better_metric_gated_in_its_direction(self, tmp_path,
                                                           capsys):
        # the elastic recovery-overhead fraction regresses by RISING:
        # 0.25 -> 0.50 must fail while 0.25 -> 0.0 (an improvement a
        # higher-is-better gate would flag) must pass
        assert run_main(tmp_path, record(elastic_frac=0.50),
                        record(elastic_frac=0.25)) == 1
        assert ("sharded_sweep.elastic_resume_overhead_frac"
                in capsys.readouterr().err)
        assert run_main(tmp_path, record(elastic_frac=0.0),
                        record(elastic_frac=0.25)) == 0

    def test_zero_cost_baseline_rejects_any_cost(self, tmp_path, capsys):
        # a 0.0 lower-is-better baseline means the recovery path was
        # free; any nonzero cost is a regression, not a ratio
        assert run_main(tmp_path, record(elastic_frac=0.01),
                        record(elastic_frac=0.0)) == 1
        assert run_main(tmp_path, record(elastic_frac=0.0),
                        record(elastic_frac=0.0)) == 0

    def test_malformed_json_fails(self, tmp_path, capsys):
        cur = write(tmp_path, "current.json", "{not json")
        base = write(tmp_path, "baseline.json", record())
        assert bench_check.main(["--current", str(cur),
                                 "--baseline", str(base)]) == 2
        assert "not valid JSON" in capsys.readouterr().err

    def test_record_without_benches_fails(self, tmp_path, capsys):
        assert run_main(tmp_path, {"meta": {}}, record()) == 2
        assert "'benches'" in capsys.readouterr().err

    def test_nonfinite_current_metric_fails(self, tmp_path):
        # json.dumps happily writes NaN; the gate must still reject it
        bad = record()
        bad["benches"]["fused_rc"]["speedup_vs_phased"] = float("nan")
        assert run_main(tmp_path, bad, record()) == 2

    def test_missing_file_fails(self, tmp_path):
        base = write(tmp_path, "baseline.json", record())
        assert bench_check.main(
            ["--current", str(tmp_path / "nope.json"),
             "--baseline", str(base)]) == 2

    def test_missing_baseline_exits_2_and_names_path(self, tmp_path,
                                                     capsys):
        # a gate without a committed baseline must fail as a clean exit-2
        # diagnostic naming the expected file, never a traceback
        cur = write(tmp_path, "current.json", record())
        missing = tmp_path / "no_such_baseline.json"
        assert bench_check.main(["--current", str(cur),
                                 "--baseline", str(missing)]) == 2
        err = capsys.readouterr().err
        assert str(missing) in err
        assert "benchmarks/baselines/" in err   # re-baseline hint

    def test_default_baseline_lookup_missing_exits_2(self, tmp_path,
                                                     capsys):
        # no --baseline: the default benchmarks/baselines/<name> lookup
        # for an unknown bench name must take the same clean path
        cur = write(tmp_path, "BENCH_does_not_exist.json", record())
        assert bench_check.main(["--current", str(cur)]) == 2
        assert "BENCH_does_not_exist.json" in capsys.readouterr().err

    def test_unreadable_baseline_exits_2(self, tmp_path, capsys):
        # a directory where the baseline file should be (OSError, not
        # FileNotFoundError) must also exit 2
        cur = write(tmp_path, "current.json", record())
        bad = tmp_path / "baseline_dir.json"
        bad.mkdir()
        assert bench_check.main(["--current", str(cur),
                                 "--baseline", str(bad)]) == 2
        assert "bench_check: ERROR" in capsys.readouterr().err

    def test_baseline_with_no_gated_bench_fails(self, tmp_path):
        empty = {"meta": {}, "benches": {"roofline": {"flops": 1.0}}}
        assert run_main(tmp_path, record(), empty) == 2


class TestSchema:
    def test_helpers_reject_nonfinite(self):
        bad = record()
        bad["benches"]["fused_rc"]["designs_per_s"] = float("inf")
        with pytest.raises(bench_check.BenchCheckError, match="finite"):
            bench_check.validate_finite(bad)
        with pytest.raises(bench_check.BenchCheckError, match="no numeric"):
            bench_check.validate_finite({"benches": {}})

    def test_committed_baselines_are_finite_and_gated(self):
        baseline_dir = REPO / "benchmarks/baselines"
        paths = sorted(baseline_dir.glob("BENCH_*.json"))
        assert paths, "no committed baselines under benchmarks/baselines/"
        for path in paths:
            rec = bench_check.load_record(path)
            assert bench_check.validate_finite(rec) > 0
        # every gated metric must be readable from some committed
        # baseline, else the CI gate silently checks nothing
        merged = {"benches": {}}
        for path in paths:
            merged["benches"].update(
                bench_check.load_record(path)["benches"])
        for bench, metric_paths in bench_check.GATED_METRICS.items():
            for mpath, direction in metric_paths.items():
                value = bench_check.get_metric(merged, bench, mpath)
                assert direction in ("higher", "lower")
                # throughputs must be positive; costs merely non-negative
                assert value > 0.0 if direction == "higher" else value >= 0.0

    @pytest.mark.slow
    def test_fresh_bench_json_metrics_are_finite(self, tmp_path):
        """Schema check on a real record: every metric emitted by
        `benchmarks/run.py --json` is a finite number."""
        out = tmp_path / "bench.json"
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        env["PYTHONPATH"] = str(REPO / "src") + (
            os.pathsep + env["PYTHONPATH"] if "PYTHONPATH" in env else "")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "fused_rc",
             "--json", str(out)],
            cwd=REPO, env=env, capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        rec = bench_check.load_record(out)
        assert bench_check.validate_finite(rec) >= 5
        assert bench_check.get_metric(rec, "fused_rc",
                                      "designs_per_s") > 0.0
