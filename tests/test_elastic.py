"""Elastic restart: checkpoint written on one mesh restores onto another
(shrunk) mesh with resharding; perf-lever configs compile multi-device;
and the elastic SWEEP driver (`launch.elastic`) survives injected host
drops — re-slabbing onto the survivors' mesh and resuming from the last
completed slab with a bit-identical DesignBatch.

Runs in subprocesses with 8 forced host devices.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# multi-minute subprocess integration (8 forced host devices + XLA compiles)
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp, dataclasses
    from jax.sharding import Mesh, NamedSharding
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import context as mesh_ctx
    from repro.distributed import sharding as shard
    from repro.configs.registry import get_arch
    from repro.configs.base import input_specs
    from repro.models import registry as M
    from repro.ckpt.manager import CheckpointManager
    from repro.runtime.fault import replan_mesh
    from repro.train.optimizer import abstract_opt_state, opt_state_axes
    from repro.train.step import make_train_step

    cfg = get_arch("olmo-1b-smoke")
    out = {}

    # --- train one step on the full (2,2,2) mesh, checkpoint -------------
    mesh8 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_ctx.set_mesh(mesh8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    p_axes = M.param_axes(cfg)
    abs_p = M.abstract_params(cfg)
    specs8 = shard.tree_specs(p_axes, abs_p, mesh8)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh8, s)),
        params, specs8, is_leaf=lambda x: hasattr(x, "shape"))
    step, opt = make_train_step(cfg)
    ostate = opt.init(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    batch = {"tokens": toks, "targets": toks}
    with mesh8:
        params, ostate, m = jax.jit(step)(params, ostate, batch)
    out["loss8"] = float(m["loss"])
    d = tempfile.mkdtemp()
    cm = CheckpointManager(d)
    cm.save(1, dict(params=params))

    # --- node failure: replan to 4 devices, restore with resharding ------
    plan = replan_mesh(4, model_parallel=2)
    out["plan"] = [plan.data, plan.model]
    mesh4 = make_test_mesh((plan.data, plan.model), ("data", "model"))
    mesh_ctx.set_mesh(mesh4)
    specs4 = shard.tree_specs(p_axes, abs_p, mesh4)
    restored, step_no = cm.restore(like=dict(params=params), mesh=mesh4,
                                   specs=dict(params=specs4))
    ostate4 = opt.init(restored["params"])
    with mesh4:
        p2, o2, m2 = jax.jit(step)(restored["params"], ostate4, batch)
    out["loss4"] = float(m2["loss"])
    out["resharded"] = True

    # --- opt-level configs must also compile multi-device ----------------
    from repro.launch.optlevels import apply_opt_level
    mesh_ctx.set_mesh(mesh8)
    for arch, cell, lvl in (("mamba2-780m", "train_4k", 7),
                            ("deepseek-67b", "train_4k", 4)):
        c = apply_opt_level(get_arch(arch + "-smoke"), cell, lvl)
        ap = M.abstract_params(c)
        ps = shard.tree_specs(M.param_axes(c), ap, mesh8)
        ao = abstract_opt_state(c.optimizer, ap)
        os_ = shard.tree_specs(opt_state_axes(c.optimizer, M.param_axes(c)),
                               ao, mesh8)
        st, _ = make_train_step(c)
        bspec = shard.batch_specs(input_specs(c, "smoke"), mesh8)
        ns = lambda t: shard.named(t, mesh8)
        with mesh8:
            jax.jit(st, in_shardings=(ns(ps), ns(os_), ns(bspec)),
                    out_shardings=(ns(ps), ns(os_), None)).lower(
                ap, ao, input_specs(c, "smoke")).compile()
        out[f"opt{lvl}_{arch}"] = True
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH=SRC)
    # pin the child to CPU: with libtpu installed, an unset
    # JAX_PLATFORMS makes jax probe for TPU hardware for minutes
    # before falling back (the forced-host-device flag wants CPU anyway)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_elastic_restore_onto_smaller_mesh(result):
    assert result["resharded"]
    assert result["plan"] == [2, 2]
    # same data + restored params -> same forward loss magnitude
    import math
    assert math.isfinite(result["loss4"])


def test_opt_levels_compile_multidevice(result):
    assert result["opt7_mamba2-780m"]
    assert result["opt4_deepseek-67b"]


# ---------------------------------------------------------------------------
# Elastic SWEEP driver: injected host drop -> re-slab -> bit-identical
# ---------------------------------------------------------------------------

ELASTIC_SWEEP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import dse
    from repro.core.batch import ARRAY_FIELDS
    from repro.core.space import DesignSpace
    from repro.launch import elastic
    from repro.launch.mesh import make_sweep_mesh
    from repro.runtime.fault import FailureInjector

    space = DesignSpace.paper_grid().with_mc(samples=4, key=0)
    oracle = dse.sweep(space)

    def identical(batch):
        return bool(all(np.array_equal(np.asarray(getattr(batch, f)),
                                       np.asarray(getattr(oracle, f)))
                        for f in ARRAY_FIELDS))

    out = {}
    # one host stops heartbeating after slab 1's dispatch: detection ->
    # replan_mesh over the survivors -> resume from the checkpoint
    batch, rep = elastic.elastic_sweep(
        space, make_sweep_mesh(),
        injector=FailureInjector(schedule={1: "drop:host3"}))
    out["drop"] = {"ok": identical(batch), "restarts": rep.restarts,
                   "dropped": rep.dropped_hosts,
                   "devices": rep.device_history,
                   "frac": rep.resume_overhead_frac}
    # pile-up: crash, then a drop, then a nan, then a SECOND drop — the
    # mesh shrinks twice and the batch must still be bit-identical
    batch, rep = elastic.elastic_sweep(
        space, make_sweep_mesh(),
        injector=FailureInjector(schedule={0: "crash", 1: "drop:host0",
                                           2: "nan", 3: "drop:host5"}))
    out["multi"] = {"ok": identical(batch), "restarts": rep.restarts,
                    "dropped": rep.dropped_hosts,
                    "devices": rep.device_history}
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def elastic_sweep_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", ELASTIC_SWEEP_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_host_drop_reslab_bit_identical(elastic_sweep_result):
    rep = elastic_sweep_result["drop"]
    assert rep["ok"]                       # every column bit-identical
    assert rep["restarts"] == 1
    assert rep["dropped"] == ["host3"]
    # slab 0 ran on 8 devices; the re-dispatched slab 1 onward on 7
    assert rep["devices"][0] == 8 and rep["devices"][-1] == 7
    assert rep["frac"] == pytest.approx(0.25)   # one of four slabs redone


def test_fault_pileup_shrinks_twice_still_bit_identical(
        elastic_sweep_result):
    rep = elastic_sweep_result["multi"]
    assert rep["ok"]
    assert rep["restarts"] == 4
    assert rep["dropped"] == ["host0", "host5"]
    assert rep["devices"][0] == 8 and rep["devices"][-1] == 6
