"""Replica-bitline timing closure + crossing-detection NaN semantics.

1. Fused replica path vs the phased replica oracle: SA-enable fire time
   within one dt on every Table-1 combo (and the full paper grid @slow).
2. `_first_crossing_ns` sentinel regression: a crossing on the very last
   step is a finite T*dt; never-crossed is NaN — in BOTH engines.
3. Starved designs (WL ramp slower than the ACT window) surface as NaN
   tRC / infeasible / pareto-inert, never as a silently clamped number.
4. with_mc x replica stays ONE fused dispatch and is bit-deterministic
   under a fixed key.
5. Disabling replica keeps the nominal path bit-identical (the role
   column is inert, and legacy (B, 5) params still lower).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dse, transient
from repro.core.calibration import (SI, AOS, D1B, get_tech, register_tech,
                                    unregister_tech)
from repro.core.space import DesignSpace
from repro.core.transient import (DT_NS, T_ACT_NS, _first_crossing_ns,
                                  simulate_row_cycle,
                                  simulate_row_cycle_phased)
from repro.kernels import ops
from repro.kernels.ref import ROW_CYCLE_N_PARAMS

POINTS = (("si", "sel_strap", 137), ("aos", "sel_strap", 87),
          ("d1b", "direct", 1))


# ---------------------------------------------------------------------------
# Fused replica path vs the phased replica oracle
# ---------------------------------------------------------------------------

class TestReplicaFusedVsPhased:
    REGEN_SLACK_NS = 0.05

    def assert_match(self, tech, scheme, layers):
        f = simulate_row_cycle(tech, scheme, layers, replica=True)
        p = simulate_row_cycle_phased(tech, scheme, layers, replica=True)
        # the replica-closed SA-enable fire time: within ONE step
        d_fire = np.abs(np.asarray(f.t_fire_ns)
                        - np.asarray(p.t_fire_ns)).max()
        assert d_fire <= DT_NS + 1e-9, (tech.name, scheme, d_fire)
        d_trc = np.abs(np.asarray(f.trc_ns) - np.asarray(p.trc_ns)).max()
        assert d_trc <= 3 * DT_NS + self.REGEN_SLACK_NS, (
            tech.name, scheme, d_trc)

    def test_table1_combos(self):
        self.assert_match(SI, "sel_strap", jnp.asarray([87, 137]))
        self.assert_match(AOS, "sel_strap", jnp.asarray([87, 137]))
        self.assert_match(D1B, "direct", jnp.asarray([1]))

    @pytest.mark.slow
    def test_paper_grid(self):
        grid = jnp.asarray([32, 48, 64, 87, 100, 120, 137, 160, 200])
        for tech in (SI, AOS):
            for scheme in ("direct", "strap", "core_mux", "sel_strap"):
                self.assert_match(tech, scheme, grid)

    def test_replica_fires_earlier_than_fixed(self):
        """The ganged replica develops signal faster than the worst-case
        main bitline, so closure fires the SA strictly earlier (and tRC
        shrinks) — at a margin cost, since the main array latches before
        its own 90% point."""
        layers = jnp.asarray([137.0])
        fixed = simulate_row_cycle(SI, "sel_strap", layers)
        closed = simulate_row_cycle(SI, "sel_strap", layers, replica=True)
        assert float(closed.t_fire_ns[0]) < float(fixed.t_fire_ns[0])
        assert float(closed.trc_ns[0]) < float(fixed.trc_ns[0])
        assert float(closed.dv_sense_v[0]) < float(fixed.dv_sense_v[0])

    def test_unit_replica_approximates_fixed_timing(self):
        """replica_cells=1 + replica_store_frac=writeback_eff makes the
        replica an exact copy of the main column: closure reproduces the
        fixed own-90% timing (the null calibration case)."""
        tech = dataclasses.replace(SI, name="si_nullrep", replica_cells=1.0,
                                   replica_store_frac=SI.writeback_eff)
        layers = jnp.asarray([137.0])
        fixed = simulate_row_cycle(tech, "sel_strap", layers)
        closed = simulate_row_cycle(tech, "sel_strap", layers, replica=True)
        assert abs(float(closed.t_fire_ns[0])
                   - float(fixed.t_fire_ns[0])) <= DT_NS + 1e-9

    def test_phased_traces_include_replica(self):
        res = simulate_row_cycle(SI, "sel_strap", jnp.asarray([137.0]),
                                 traces=True, replica=True)
        assert "replica" in res.traces
        assert res.traces["replica"].shape == res.traces["act"].shape


# ---------------------------------------------------------------------------
# Crossing-detection sentinel: NaN for never-crossed, finite for last-step
# ---------------------------------------------------------------------------

class TestFirstCrossingSentinel:
    def test_last_step_crossing_is_finite(self):
        t = 5
        trace = np.zeros((t, 2), bool)
        trace[-1, 0] = True                      # crosses on the VERY last step
        out = np.asarray(_first_crossing_ns(jnp.asarray(trace), DT_NS))
        assert out[0] == pytest.approx(t * DT_NS)
        assert np.isnan(out[1])                  # never crossed -> NaN

    def test_fused_kernel_never_crossed_is_nan(self):
        """A threshold no bitline can reach: the fused engine must report
        NaN event times (both backends), not the phase window."""
        ladder_c = jnp.full((2, 6), 10.0, jnp.float32)
        ladder_g = jnp.full((2, 5), 0.5, jnp.float32)
        operands = list(transient.lower_operands(
            ladder_c, ladder_g, r_sa_drive_kohm=8.0, r_pre_kohm=8.0,
            store_v=1.0, tau_wl_ns=2.0))
        params = operands[5].at[:, 1].set(10.0)   # unreachable dv threshold
        operands[5] = params
        for backend in ("ref", "pallas"):
            evt, _ = ops.row_cycle_fused(
                *operands, DT_NS, transient.N_ACT_STEPS,
                transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                backend=backend)
            assert np.isnan(np.asarray(evt[:, 0])).all(), backend


# ---------------------------------------------------------------------------
# Starved designs: NaN tRC, infeasible, pareto-inert — never clamped
# ---------------------------------------------------------------------------

class TestStarvedDesignSurfacesInvalid:
    STARVED = "si_starved"

    @pytest.fixture()
    def starved_tech(self):
        # WL driver RC far beyond the ACT window: tau_wl = r*c*1e-3 ns
        # = 40000*50*1e-3 = 2000 ns >> 16 ns, so the access transistor
        # never opens and no crossing can occur inside the phase.
        tech = dataclasses.replace(SI, name=self.STARVED,
                                   r_wl_kohm=40_000.0)
        register_tech(tech, overwrite=True)
        yield tech
        unregister_tech(self.STARVED)

    def test_starved_point_is_nan_infeasible_and_inert(self, starved_tech):
        space = (DesignSpace.points([(self.STARVED, "sel_strap", 137)])
                 + DesignSpace.points(POINTS))
        batch = dse.sweep(space, with_transient=True)
        trc = np.asarray(batch.trc_ns)
        assert np.isnan(trc[0])                   # starved -> NaN, not clamp
        assert np.isfinite(trc[1:]).all()         # healthy rows unaffected
        assert not bool(np.asarray(batch.feasible)[0])
        # NaN tRC must never dominate a finite design out of the front
        mask = np.asarray(dse.pareto_mask(batch, require_feasible=False))
        assert mask[1:3].any()                    # si/aos survive

    def test_starved_fused_matches_phased_nan(self, starved_tech):
        f = simulate_row_cycle(starved_tech, "sel_strap",
                               jnp.asarray([137.0]))
        p = simulate_row_cycle_phased(starved_tech, "sel_strap",
                                      jnp.asarray([137.0]))
        assert np.isnan(float(f.t_fire_ns[0]))
        assert np.isnan(float(p.t_fire_ns[0]))


# ---------------------------------------------------------------------------
# DSE integration: columns, composition with MC, single dispatch
# ---------------------------------------------------------------------------

class TestReplicaDSE:
    def test_closed_timing_columns(self):
        space = DesignSpace.points(POINTS)
        fixed = dse.sweep(space)
        closed = dse.sweep(space.with_replica())
        assert len(closed) == len(fixed)          # replica rows de-interleaved
        t_fix = np.asarray(fixed.t_fire_ns)
        t_clo = np.asarray(closed.t_fire_ns)
        assert np.isfinite(t_fix).all() and np.isfinite(t_clo).all()
        assert (t_clo < t_fix).all()
        # margin at fire: finite, and below the full own-90% margin since
        # the replica fires before the main array's own crossing
        m_fire = np.asarray(closed.margin_fire_mv)
        assert np.isfinite(m_fire).all()
        assert (m_fire < np.asarray(fixed.margin_fire_mv) + 1e-6).all()

    def test_replica_off_is_bit_identical(self):
        space = DesignSpace.points(POINTS)
        a = dse.sweep(space)
        b = dse.sweep(dataclasses.replace(space, replica=False))
        for f in ("trc_ns", "t_sense_ns", "margin_mv", "t_fire_ns"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)), f)

    def test_legacy_5col_params_still_lower(self):
        """Manually-built (B, 5) params (no role column) keep working in
        both backends and match the (B, 6) role-0 lowering bit-for-bit."""
        ladder = transient.build_bl_ladder(SI, "sel_strap",
                                           jnp.asarray([100.0, 137.0]))
        operands = list(transient._fused_operands(
            ladder, SI, SI.writeback_eff * transient.cal.VDD_ARRAY))
        assert operands[5].shape[1] == ROW_CYCLE_N_PARAMS
        legacy = list(operands)
        legacy[5] = legacy[5][:, :5]
        for backend in ("ref", "pallas"):
            evt6, v6 = ops.row_cycle_fused(
                *operands, DT_NS, transient.N_ACT_STEPS,
                transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                backend=backend)
            evt5, v5 = ops.row_cycle_fused(
                *legacy, DT_NS, transient.N_ACT_STEPS,
                transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                backend=backend)
            np.testing.assert_array_equal(np.asarray(evt6),
                                          np.asarray(evt5), backend)
            np.testing.assert_array_equal(np.asarray(v6),
                                          np.asarray(v5), backend)

    def test_with_mc_replica_single_dispatch(self, monkeypatch):
        calls = []
        real = ops.row_cycle_fused

        def counting(*a, **kw):
            calls.append(a[0].shape)
            return real(*a, **kw)

        monkeypatch.setattr(transient.ops, "row_cycle_fused", counting)
        space = DesignSpace.points(POINTS).with_replica().with_mc(
            samples=16, key=0)
        batch = dse.sweep(space)
        assert len(calls) == 1                   # ONE fused dispatch
        # 3 points x 16 samples x 2 rows/pair, padded to B_ALIGN
        n_rows = 3 * 16 * 2
        expect = -(-n_rows // transient.B_ALIGN) * transient.B_ALIGN
        assert calls[0][0] == expect
        assert np.isfinite(np.asarray(batch.trc_ns)).all()

    def test_with_mc_replica_bit_deterministic(self):
        space = DesignSpace.points(POINTS).with_replica().with_mc(
            samples=16, key=7)
        a = dse.sweep(space)
        b = dse.sweep(space)
        for f in ("trc_ns", "t_fire_ns", "margin_fire_mv", "margin_mv"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)), f)

    def test_replica_mc_shares_vth_draw_with_main(self):
        """The MC Vth perturbation must hit replica and main rows alike
        (it folds into the shared parasitics), so replica-closed MC tRC
        varies across samples."""
        space = DesignSpace.points([("si", "sel_strap", 137)]) \
            .with_replica().with_mc(samples=32, key=1)
        batch = dse.sweep(space)
        t_fire = np.asarray(batch.t_fire_ns)
        assert np.unique(t_fire).size > 1        # samples actually differ

    def test_space_concat_replica_mismatch_rejected(self):
        a = DesignSpace.points(POINTS).with_replica()
        b = DesignSpace.points(POINTS)
        with pytest.raises(ValueError, match="replica"):
            _ = a + b

    def test_report_replica_table(self):
        from repro.core.report import replica_timing_table
        table = replica_timing_table()
        for tech in ("si", "aos", "d1b"):
            row = table[tech]
            assert row["trc_delta_ns"] > 0.0
            assert row["t_fire_closed_ns"] < row["t_fire_fixed_ns"]
