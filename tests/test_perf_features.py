"""Perf-lever correctness: gated decode, EP MoE, activation constraints.

Invariant: every optimization must be exact (or exactly characterized) —
gated decode with ALL straps selected == dense decode; EP MoE == baseline
MoE; constrain() is a no-op without a mesh.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_arch
from repro.models import registry as M

SRC = str(Path(__file__).resolve().parents[1] / "src")


class TestGatedDecode:
    pytestmark = pytest.mark.slow

    def _setup(self, top):
        rng = np.random.default_rng(3)
        cfg = get_arch("deepseek-67b-smoke")
        cfgG = dataclasses.replace(cfg, strap_decode=True,
                                   decode_strap_tokens=16,
                                   decode_top_straps=top)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        B, T = 2, 48
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)),
                           jnp.int32)
        _, cache = M.prefill(cfg, params, {"tokens": toks[:, :T]})
        pad = lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 16), (0, 0), (0, 0)])
        S = T + 16
        nst = S // 16
        kp = pad(cache["k"])
        ksum = kp.reshape(cfg.n_layers, B, nst, 16, cfg.n_kv_heads,
                          cfg.head_dim_).astype(jnp.float32).sum(3)
        cacheD = {k: pad(v) for k, v in cache.items()}
        cacheG = dict(k=kp, v=pad(cache["v"]), ksum=ksum)
        pos = jnp.full((B,), T, jnp.int32)
        return cfg, cfgG, params, toks, cacheD, cacheG, pos, T

    def test_all_straps_equals_exact(self):
        cfg, cfgG, params, toks, cacheD, cacheG, pos, T = self._setup(top=64)
        dl, _ = M.decode_step(cfg, params, cacheD, toks[:, T:T + 1], pos)
        dg, _ = M.decode_step(cfgG, params, cacheG, toks[:, T:T + 1], pos)
        np.testing.assert_allclose(np.array(dg), np.array(dl),
                                   rtol=1e-4, atol=1e-4)

    def test_gated_subset_runs_and_updates_cache(self):
        cfg, cfgG, params, toks, cacheD, cacheG, pos, T = self._setup(top=2)
        dg, newc = M.decode_step(cfgG, params, cacheG, toks[:, T:T + 1], pos)
        assert np.isfinite(np.array(dg)).all()
        # the new token's key must land in the cache at position T
        assert not np.allclose(np.array(newc["k"][:, :, T]),
                               np.array(cacheG["k"][:, :, T]))
        # ksum of the newest strap changed
        strap = T // 16
        assert not np.allclose(np.array(newc["ksum"][:, :, strap]),
                               np.array(cacheG["ksum"][:, :, strap]))

    def test_cache_schema_has_ksum(self):
        cfgG = dataclasses.replace(get_arch("deepseek-67b"),
                                   strap_decode=True)
        sch = M.cache_schema(cfgG, 128, 32768)
        assert "ksum" in sch
        assert sch["ksum"].shape[2] == 32768 // cfgG.decode_strap_tokens


EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp, dataclasses
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import context as mesh_ctx
    from repro.configs.registry import get_arch
    from repro.models import registry as M
    from repro.models.moe import moe_apply, moe_apply_ep

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_ctx.set_mesh(mesh)
    cfg = get_arch("phi3.5-moe-42b-a6.6b-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32, cfg.d_model)) * 0.1, jnp.float32)
    with mesh:
        y0, _ = jax.jit(lambda lp, x: moe_apply(cfg, lp, x))(lp, x)
        y1, _ = jax.jit(lambda lp, x: moe_apply_ep(cfg, lp, x))(lp, x)
    err = float(np.max(np.abs(np.array(y0) - np.array(y1))))
    # gated train step on the same mesh
    cfg5 = dataclasses.replace(cfg, moe_ep=True, shard_acts=True)
    from repro.train.step import make_train_step
    step, opt = make_train_step(cfg5)
    o = opt.init(params)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    with mesh:
        _, _, m = jax.jit(step)(params, o, {"tokens": toks, "targets": toks})
    print(json.dumps(dict(err=err, loss=float(m["loss"]))))
""")


@pytest.mark.slow
def test_ep_moe_matches_baseline_on_mesh():
    env = dict(os.environ, PYTHONPATH=SRC)
    # pin the child to CPU: with libtpu installed, an unset
    # JAX_PLATFORMS makes jax probe for TPU hardware for minutes
    # before falling back (the forced-host-device flag wants CPU anyway)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", EP_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 2e-4
    assert np.isfinite(out["loss"])


class TestConstrainNoOp:
    def test_no_mesh_no_op(self):
        from repro.models.common import constrain
        cfg = dataclasses.replace(get_arch("mamba2-780m-smoke"),
                                  shard_acts=True)
        x = jnp.ones((4, 8, 16))
        y = constrain(cfg, x, ("dp", None, "model"))
        np.testing.assert_array_equal(np.array(x), np.array(y))

    @pytest.mark.slow
    def test_shard_acts_model_still_correct(self):
        """shard_acts=True must not change numerics on a single device."""
        cfg = get_arch("mamba2-780m-smoke")
        cfgS = dataclasses.replace(cfg, shard_acts=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 64)), jnp.int32)
        y0, _ = M.forward_train(cfg, params, {"tokens": toks})
        y1, _ = M.forward_train(cfgS, params, {"tokens": toks})
        np.testing.assert_allclose(np.array(y0), np.array(y1), atol=1e-6)


@pytest.mark.slow
class TestSplitProjection:
    """opt7: shard-aligned SSM projections == fused (exact re-partition)."""

    def _split_params(self, cfg, params):
        di = cfg.d_inner
        gs = cfg.ssm_ngroups * cfg.ssm_state

        def split_layer(lp):
            w, cw, cb = lp["in_proj"], lp["conv_w"], lp["conv_b"]
            out = {k: v for k, v in lp.items()
                   if k not in ("in_proj", "conv_w", "conv_b")}
            out["in_z"] = w[..., :, :di]
            out["in_x"] = w[..., :, di:2 * di]
            out["in_B"] = w[..., :, 2 * di:2 * di + gs]
            out["in_C"] = w[..., :, 2 * di + gs:2 * di + 2 * gs]
            out["in_dt"] = w[..., :, 2 * di + 2 * gs:]
            out["conv_x_w"] = cw[..., :, :di]
            out["conv_x_b"] = cb[..., :di]
            out["conv_B_w"] = cw[..., :, di:di + gs]
            out["conv_B_b"] = cb[..., di:di + gs]
            out["conv_C_w"] = cw[..., :, di + gs:]
            out["conv_C_b"] = cb[..., di + gs:]
            return out

        ps = dict(params)
        ps["layers"] = split_layer(params["layers"])
        return ps

    def test_forward_and_decode_equivalence(self, rng):
        cfg = get_arch("mamba2-780m-smoke")
        cfgS = dataclasses.replace(cfg, ssm_split_proj=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        paramsS = self._split_params(cfg, params)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                           jnp.int32)
        y0, _ = M.forward_train(cfg, params, {"tokens": toks})
        y1, _ = M.forward_train(cfgS, paramsS, {"tokens": toks})
        np.testing.assert_allclose(np.array(y0), np.array(y1),
                                   rtol=1e-4, atol=1e-4)
        _, c0 = M.prefill(cfg, params, {"tokens": toks[:, :32]})
        _, c1 = M.prefill(cfgS, paramsS, {"tokens": toks[:, :32]})
        pos = jnp.full((2,), 32, jnp.int32)
        d0, _ = M.decode_step(cfg, params, c0, toks[:, 32:33], pos)
        d1, _ = M.decode_step(cfgS, paramsS, c1, toks[:, 32:33], pos)
        np.testing.assert_allclose(np.array(d0), np.array(d1),
                                   rtol=1e-4, atol=1e-4)
