"""Array-native DesignSpace/DesignBatch API (PR 2).

Covers the redesigned co-optimization surface:

1. `DesignBatch` is a real JAX pytree: flatten/unflatten, tree_map and
   jit round-trips preserve data AND the static name tables.
2. `dse.sweep(space).to_points()` is equivalent to the legacy scalar
   oracle (`evaluate_grid` per combo) — the old `full_sweep` contract.
3. `pareto_front`/`best_design`: vectorized dominance identical to the
   seed's O(n^2) pairwise loop, empty-feasible-set and tie-breaking
   edge cases.
4. Registries: `register_tech`/`register_scheme` sweep without editing
   any core module.
5. Sharding readiness: flat batch axis, `pad_to` + validity mask.
"""

import numpy as np
import jax
import pytest

from repro.core import calibration as cal
from repro.core import dse
from repro.core.batch import DesignBatch, DesignPoint
from repro.core.calibration import AOS, D1B, SI, register_tech, unregister_tech
from repro.core.routing import (SchemeSpec, register_scheme, scheme_spec,
                                unregister_scheme)
from repro.core.space import DEFAULT_LAYER_GRID, DesignSpace

SMALL_GRID = (87, 137)          # keeps fused-engine batches at one 64-pad


def small_batch(with_transient=False):
    return dse.sweep(DesignSpace.paper_grid(layer_grid=SMALL_GRID),
                     with_transient=with_transient)


def seed_pareto_loop(points, require_feasible=True):
    """The seed's O(n^2) pairwise dominance loop (reference semantics)."""
    cand = [p for p in points if (p.feasible or not require_feasible)]

    def dominates(a, b):
        ge = (a.density_gb_mm2 >= b.density_gb_mm2
              and a.margin_disturbed_mv >= b.margin_disturbed_mv
              and a.trc_ns <= b.trc_ns and a.e_read_fj <= b.e_read_fj)
        gt = (a.density_gb_mm2 > b.density_gb_mm2
              or a.margin_disturbed_mv > b.margin_disturbed_mv
              or a.trc_ns < b.trc_ns or a.e_read_fj < b.e_read_fj)
        return ge and gt

    return [p for p in cand
            if not any(dominates(q, p) for q in cand if q is not p)]


class TestDesignSpace:
    def test_paper_grid_row_order_and_capability_flags(self):
        sp = DesignSpace.paper_grid(layer_grid=SMALL_GRID).lower()
        # si x 4 schemes x 2 layers, aos x 4 x 2, d1b x direct x 1
        assert len(sp) == 2 * 4 * 2 + 1
        assert sp.tech_names == ("si", "aos", "d1b")
        # the 2D baseline contributes ONLY its declared scheme/layer grid
        d1b_rows = np.flatnonzero(sp.tech_idx == 2)
        assert d1b_rows.tolist() == [16]
        assert sp.layers_np[16] == 1.0
        assert sp.scheme_names[sp.scheme_idx[16]] == "direct"

    def test_product_filters_schemes_by_allowed(self):
        space = DesignSpace.product(techs=("si", "d1b"),
                                    schemes=("sel_strap",), layers=(137,))
        lowered = space.lower()
        # d1b only allows "direct" -> filtered out entirely
        assert lowered.tech_names == ("si",)

    def test_points_and_concat(self):
        space = (DesignSpace.points([("si", "sel_strap", 137)])
                 + DesignSpace.points([("d1b", "direct", 1)]))
        assert len(space) == 2
        with pytest.raises(ValueError):
            DesignSpace.points([("si", "not_a_scheme", 137)])
        with pytest.raises(KeyError):
            DesignSpace.points([("not_a_tech", "direct", 1)])

    def test_with_corners_multiplies_rows(self):
        base = DesignSpace.points([("si", "sel_strap", 137)])
        sp = base.with_corners(rh_toggles=(1e4, 3e4, 5e4)).lower()
        assert len(sp) == 3
        np.testing.assert_allclose(sp.corners["rh_toggles"],
                                   [1e4, 3e4, 5e4])
        batch = dse.sweep(base.with_corners(rh_toggles=(1e4, 5e4)),
                          with_transient=False)
        md = np.asarray(batch.margin_disturbed_mv)
        # nominal duty first; 5x RH toggles strictly worse
        nominal = dse.sweep(base, with_transient=False)
        assert md[0] == pytest.approx(
            float(nominal.margin_disturbed_mv[0]), abs=1e-4)
        assert md[1] < md[0]

    def test_unknown_corner_axis_rejected(self):
        space = DesignSpace.points([("si", "sel_strap", 137)])
        with pytest.raises(ValueError, match="unsupported corner"):
            dse.sweep(space.with_corners(vth_sigma=(0.0, 1.0)),
                      with_transient=False)

    def test_duplicate_corner_axis_rejected(self):
        space = DesignSpace.points([("si", "sel_strap", 137)])
        with pytest.raises(ValueError, match="already declared"):
            space.with_corners(rh_toggles=(1e3,)).with_corners(
                rh_toggles=(5e4,))

    def test_empty_space_rejected_with_clear_error(self):
        # product() filtering can eliminate every pair (d1b only allows
        # "direct"); lowering must fail loudly, not deep in the physics
        space = DesignSpace.product(techs=("d1b",), schemes=("sel_strap",))
        with pytest.raises(ValueError, match="empty"):
            dse.sweep(space, with_transient=False)


class TestDesignBatchPytree:
    def test_flatten_unflatten_roundtrip(self):
        batch = small_batch()
        leaves, treedef = jax.tree_util.tree_flatten(batch)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(rebuilt, DesignBatch)
        assert rebuilt.tech_names == batch.tech_names
        assert rebuilt.scheme_names == batch.scheme_names
        np.testing.assert_array_equal(np.asarray(rebuilt.margin_mv),
                                      np.asarray(batch.margin_mv))

    def test_tree_map_preserves_structure(self):
        batch = small_batch()
        doubled = jax.tree_util.tree_map(lambda x: x * 2, batch)
        assert isinstance(doubled, DesignBatch)
        np.testing.assert_allclose(np.asarray(doubled.density_gb_mm2),
                                   2 * np.asarray(batch.density_gb_mm2))
        # static tables ride through aux_data untouched
        assert doubled.tech_names == batch.tech_names

    def test_jit_roundtrip(self):
        batch = small_batch()

        @jax.jit
        def margin_shift(b):
            return jax.tree_util.tree_map(lambda x: x, b), b.margin_mv - 1.0

        out, margins = margin_shift(batch)
        assert isinstance(out, DesignBatch)
        assert out.tech_names == batch.tech_names
        np.testing.assert_allclose(np.asarray(margins),
                                   np.asarray(batch.margin_mv) - 1.0,
                                   rtol=1e-6)

    def test_pad_to_and_validity_mask(self):
        batch = small_batch()
        n = len(batch)
        padded = batch.pad_to(64)
        assert len(padded) == 64
        assert padded.n_valid == n
        # padding rows are invisible to every consumer (str() because the
        # transient-off tRC is NaN, which breaks dataclass equality)
        assert len(padded.to_points()) == n
        assert list(map(str, padded.to_points())) \
            == list(map(str, batch.to_points()))
        front_ref = [str(p) for p in dse.pareto_front(batch.to_points())]
        front_pad = [str(p) for p in dse.pareto_front(padded.to_points())]
        assert front_ref == front_pad
        mask = np.asarray(dse.pareto_mask(padded))
        assert not mask[n:].any()

    def test_device_put_preserves_batch(self):
        batch = small_batch()
        moved = batch.device_put(jax.devices()[0])
        np.testing.assert_array_equal(np.asarray(moved.layers),
                                      np.asarray(batch.layers))


class TestSweepEquivalence:
    """The vectorized sweep must reproduce the seed scalar oracle."""

    FIELDS = ("density_gb_mm2", "height_um", "cbl_ff", "margin_mv",
              "margin_disturbed_mv", "e_write_fj", "e_read_fj",
              "hcb_pitch_um", "blsa_area_um2")

    def reference(self, grid, with_transient):
        pts = []
        for tech in (SI, AOS):
            for scheme in ("direct", "strap", "core_mux", "sel_strap"):
                pts.extend(dse.evaluate_grid(tech, scheme, np.asarray(grid),
                                             with_transient=with_transient))
        pts.extend(dse.evaluate_grid(D1B, "direct", np.asarray([1]),
                                     with_transient=with_transient))
        return pts

    def assert_equivalent(self, got, ref, with_transient):
        assert len(got) == len(ref)
        for g, r in zip(got, ref):
            assert (g.tech, g.scheme, g.layers) == (r.tech, r.scheme, r.layers)
            assert g.feasible == r.feasible
            for f in self.FIELDS:
                assert getattr(g, f) == pytest.approx(getattr(r, f),
                                                      rel=1e-5, abs=1e-6), f
            if with_transient:
                assert g.trc_ns == pytest.approx(r.trc_ns, rel=1e-5)

    def test_to_points_matches_scalar_oracle(self):
        got = small_batch(with_transient=True).to_points()
        self.assert_equivalent(got, self.reference(SMALL_GRID, True), True)

    def test_full_sweep_shim_equals_sweep(self):
        grid = np.asarray(SMALL_GRID)
        shim = dse.full_sweep(layer_grid=grid, with_transient=False)
        direct = dse.sweep(DesignSpace.paper_grid(layer_grid=SMALL_GRID),
                           with_transient=False).to_points()
        assert list(map(str, shim)) == list(map(str, direct))

    @pytest.mark.slow
    def test_full_paper_grid_matches_scalar_oracle(self):
        space = DesignSpace.paper_grid()
        got = dse.sweep(space).to_points()
        ref = self.reference(DEFAULT_LAYER_GRID, True)
        self.assert_equivalent(got, ref, True)


class TestParetoAndBest:
    def test_vectorized_front_identical_to_seed_loop(self):
        pts = small_batch(with_transient=True).to_points()
        for rf in (True, False):
            assert dse.pareto_front(pts, require_feasible=rf) \
                == seed_pareto_loop(pts, require_feasible=rf)

    def test_batch_front_same_points_as_list_front(self):
        batch = small_batch(with_transient=True)
        front = dse.pareto_front(batch)
        assert isinstance(front, DesignBatch)
        assert front.to_points() == dse.pareto_front(batch.to_points())

    def test_blocked_dominance_equals_unblocked(self):
        # the memory-bounded dominator blocking must not change the front
        batch = small_batch(with_transient=True)
        full = np.asarray(dse.pareto_mask(batch))
        for block in (1, 3, 7):
            np.testing.assert_array_equal(
                np.asarray(dse.pareto_mask(batch, block=block)), full)

    def test_nan_trc_never_dominates(self):
        # with_transient=False -> tRC is NaN -> nothing dominates (seed
        # pairwise semantics); the front is every feasible candidate.
        batch = small_batch(with_transient=False)
        mask = np.asarray(dse.pareto_mask(batch))
        np.testing.assert_array_equal(mask, np.asarray(batch.feasible))

    def test_empty_feasible_set(self):
        # direct bonding is never manufacturable on si -> nothing feasible
        space = DesignSpace.product(techs=("si",), schemes=("direct",),
                                    layers=SMALL_GRID)
        batch = dse.sweep(space, with_transient=False)
        assert not bool(np.asarray(batch.feasible).any())
        front = dse.pareto_front(batch)
        assert isinstance(front, DesignBatch) and len(front) == 0
        assert front.to_points() == []
        assert dse.pareto_front(batch.to_points()) == []
        assert dse.best_design(batch) is None

    def test_best_design_unreachable_target_is_none(self):
        batch = small_batch(with_transient=False)
        assert dse.best_design(batch, density_target=1e9) is None

    def _pt(self, **kw):
        base = dict(tech="si", scheme="sel_strap", layers=137,
                    density_gb_mm2=2.6, height_um=9.6, cbl_ff=6.6,
                    margin_mv=130.0, margin_disturbed_mv=70.0, trc_ns=10.9,
                    e_write_fj=6.3, e_read_fj=1.6, hcb_pitch_um=0.75,
                    blsa_area_um2=1.12, feasible=True)
        base.update(kw)
        return DesignPoint(**base)

    def test_best_design_tie_breaking(self):
        # equal tRC -> lower read energy wins; equal both -> lower height
        pts = [self._pt(layers=1, trc_ns=10.0, e_read_fj=2.0),
               self._pt(layers=2, trc_ns=10.0, e_read_fj=1.5, height_um=9.0),
               self._pt(layers=3, trc_ns=10.0, e_read_fj=1.5, height_um=8.0),
               self._pt(layers=4, trc_ns=11.0, e_read_fj=0.1)]
        best = dse.best_design(pts)
        assert best.layers == 3
        # full tie -> first in batch order (stable, like the seed's min)
        pts = [self._pt(layers=7), self._pt(layers=7)]
        assert dse.best_design(pts) == pts[0]

    def test_best_design_respects_feasibility_and_target(self):
        pts = [self._pt(layers=1, trc_ns=5.0, feasible=False),
               self._pt(layers=2, trc_ns=9.0, density_gb_mm2=1.0),
               self._pt(layers=3, trc_ns=12.0)]
        assert dse.best_design(pts).layers == 3


class TestRegistries:
    def test_register_tech_sweeps_without_core_edits(self):
        custom = SI.with_(name="si_hd", layers_target=120,
                          c_bl_per_layer_ff=0.024)
        register_tech(custom)
        try:
            # the registered tech shows up in the default paper grid...
            space = DesignSpace.paper_grid(layer_grid=SMALL_GRID)
            assert any(t == "si_hd" for t, _, _ in space.entries)
            # ...and sweeps standalone with finite, distinct physics
            batch = dse.sweep(DesignSpace.product(
                techs=("si_hd",), layers=SMALL_GRID), with_transient=False)
            assert len(batch) == 4 * len(SMALL_GRID)
            assert np.isfinite(np.asarray(batch.margin_mv)).all()
            i_custom = batch.to_points()[0]
            i_si = dse.sweep(DesignSpace.product(
                techs=("si",), layers=SMALL_GRID),
                with_transient=False).to_points()[0]
            assert i_custom.cbl_ff < i_si.cbl_ff      # thinner BL per tier
        finally:
            unregister_tech("si_hd")
        with pytest.raises(ValueError):
            register_tech(SI)                          # duplicate name

    def test_register_scheme_sweeps_without_core_edits(self):
        spec = SchemeSpec(
            name="sel_direct", label="(e) selector, no strap sharing",
            sel_junction=True, straps_per_global=1, global_strap_metal=False,
            c_global_fixed_ff=0.0, r_sel_in_path=True, r_global_in_path=False,
            isolates_unselected=True, bond_shared=False)
        register_scheme(spec)
        try:
            assert scheme_spec("sel_direct") is spec
            batch = dse.sweep(DesignSpace.product(
                techs=("si",), schemes=("sel_direct",), layers=(137,)),
                with_transient=False)
            pt = batch.to_points()[0]
            # selector junction but no strap metal: C_BL between direct
            # and sel_strap; per-BL bond pitch like direct
            direct, sel_strap = (dse.sweep(DesignSpace.product(
                techs=("si",), schemes=(s,), layers=(137,)),
                with_transient=False).to_points()[0]
                for s in ("direct", "sel_strap"))
            assert direct.cbl_ff < pt.cbl_ff < sel_strap.cbl_ff
            assert pt.hcb_pitch_um == pytest.approx(direct.hcb_pitch_um)
        finally:
            unregister_scheme("sel_direct")

    def test_tech_capability_flags_replace_name_checks(self):
        # a registered 2D baseline (not named "d1b") behaves like one
        flat = D1B.with_(name="planar_x", fixed_c_bl_ff=22.0,
                         fixed_blsa_area_um2=0.5, baseline_label="Planar X")
        register_tech(flat)
        try:
            batch = dse.sweep(DesignSpace.product(techs=("planar_x",)),
                              with_transient=False)
            pt = batch.to_points()[0]
            assert pt.scheme == "direct" and pt.layers == 1
            assert pt.cbl_ff == pytest.approx(22.0)
            assert pt.density_gb_mm2 == pytest.approx(
                cal.D1B_BIT_DENSITY_GB_MM2)
            assert pt.hcb_pitch_um == 0.0
            # report rows use the tech's OWN tabulated values, not D1b's
            from repro.core import report
            rows = report.fig3_routing_comparison(with_transient=False)
            (row,) = [r for r in rows if r["tech"] == "planar_x"]
            assert row["label"] == "Planar X"
            assert row["blsa_area_um2"] == pytest.approx(0.5)
        finally:
            unregister_tech("planar_x")
