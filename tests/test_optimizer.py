"""Optimizer math vs a numpy reference; int8-moment variant tracks fp32."""

import numpy as np
import jax.numpy as jnp

from repro.train.optimizer import (OptConfig, abstract_opt_state,
                                   lr_schedule, make_optimizer,
                                   opt_state_axes)


def numpy_adamw(oc, params, grads, steps):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v_ = {k: np.zeros_like(v) for k, v in params.items()}
    p = {k: v.copy() for k, v in params.items()}
    for t in range(1, steps + 1):
        warm = min(t / oc.warmup_steps, 1.0)
        prog = min(max((t - oc.warmup_steps)
                       / max(oc.total_steps - oc.warmup_steps, 1), 0), 1)
        lr = oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio)
                             * 0.5 * (1 + np.cos(np.pi * prog)))
        for k in p:
            g = grads[k]
            m[k] = oc.b1 * m[k] + (1 - oc.b1) * g
            v_[k] = oc.b2 * v_[k] + (1 - oc.b2) * g * g
            mhat = m[k] / (1 - oc.b1 ** t)
            vhat = v_[k] / (1 - oc.b2 ** t)
            p[k] -= lr * (mhat / (np.sqrt(vhat) + oc.eps)
                          + oc.weight_decay * p[k])
    return p


class TestAdamW:
    def test_matches_numpy_reference(self, rng):
        oc = OptConfig(lr=1e-2, warmup_steps=2, total_steps=10)
        params = {"a": rng.normal(size=(4, 8)).astype(np.float32),
                  "b": rng.normal(size=(8,)).astype(np.float32)}
        grads = {k: rng.normal(size=v.shape).astype(np.float32)
                 for k, v in params.items()}
        opt = make_optimizer("adamw", oc)
        jp = {k: jnp.asarray(v) for k, v in params.items()}
        jg = {k: jnp.asarray(v) for k, v in grads.items()}
        state = opt.init(jp)
        for _ in range(5):
            jp, state = opt.update(jg, state, jp)
        want = numpy_adamw(oc, params, grads, 5)
        for k in params:
            np.testing.assert_allclose(np.array(jp[k]), want[k],
                                       rtol=2e-5, atol=2e-6)

    def test_schedule_shape(self):
        oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
        lrs = [float(lr_schedule(oc, jnp.asarray(s))) for s in
               [1, 5, 10, 50, 100]]
        assert lrs[0] < lrs[1] < lrs[2]          # warmup
        assert lrs[2] > lrs[3] > lrs[4]          # decay
        assert abs(lrs[4] - 0.1) < 1e-3          # floor


class TestAdamW8bit:
    def test_tracks_fp32_adamw(self, rng):
        oc = OptConfig(lr=1e-2, warmup_steps=1, total_steps=50,
                       weight_decay=0.0)
        params = {"w": rng.normal(size=(16, 64)).astype(np.float32)}
        opt32 = make_optimizer("adamw", oc)
        opt8 = make_optimizer("adamw8bit", oc)
        p32 = {k: jnp.asarray(v) for k, v in params.items()}
        p8 = {k: jnp.asarray(v) for k, v in params.items()}
        s32, s8 = opt32.init(p32), opt8.init(p8)
        for _ in range(10):
            g = {"w": jnp.asarray(
                rng.normal(size=params["w"].shape).astype(np.float32))}
            p32, s32 = opt32.update(g, s32, p32)
            p8, s8 = opt8.update(g, s8, p8)
        a, b = np.array(p32["w"]), np.array(p8["w"])
        # int8 moments: same direction, small relative deviation
        cos = (a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos > 0.999
        # int8 moments drift a few percent of parameter scale over 10 steps
        assert np.abs(a - b).max() < 0.05

    def test_state_is_int8(self, rng):
        opt8 = make_optimizer("adamw8bit", OptConfig())
        p = {"w": jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))}
        s = opt8.init(p)
        assert s["m"]["w"]["q"].dtype == jnp.int8
        assert s["v"]["w"]["q"].dtype == jnp.int8
        abstract = abstract_opt_state("adamw8bit", p)
        assert abstract["m"]["w"]["q"].dtype == jnp.int8
        # 4x memory saving vs fp32 moments (excluding scales)
        bytes8 = s["m"]["w"]["q"].size + s["m"]["w"]["s"].size * 4
        assert bytes8 < 0.3 * (p["w"].size * 4)

    def test_axes_mirror_params(self):
        ax = {"w": ("dmodel", "ff")}
        oax = opt_state_axes("adamw8bit", ax)
        assert oax["m"]["w"]["q"] == ("dmodel", "ff")
        assert oax["m"]["w"]["s"] == ("dmodel", None)
        oax32 = opt_state_axes("adamw", ax)
        assert oax32["v"]["w"] == ("dmodel", "ff")
