"""Fused row-cycle engine: phased-reference equivalence + paper anchors.

Three layers of protection for the trace-free fast path:

1. fused event times match the phased three-call reference within one dt;
2. the vectorized `full_sweep` reproduces the paper's Table 1 anchors
   (tRC, density, ~60% energy reduction) — golden-number regression;
3. `full_sweep(with_transient=True)` runs ONE batched fused evaluation,
   never a per-(tech, scheme) transient call.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dse, transient
from repro.core.calibration import AOS, D1B, SI
from repro.core.dse import best_design, full_sweep
from repro.core.transient import (DT_NS, simulate_row_cycle,
                                  simulate_row_cycle_many,
                                  simulate_row_cycle_phased)
from repro.kernels import ops


def rel(a, b):
    return abs(a - b) / abs(b)


class TestFusedVsPhased:
    """Event times from the fused engine vs the phased reference."""

    # analog slack for quantities that fold in the BLSA regeneration term,
    # which depends on dv_sense (continuous, not dt-quantized): a one-step
    # dv difference shifts t_regen by ~sa_tau * d(log dv) << 0.05 ns
    REGEN_SLACK_NS = 0.05

    def assert_match(self, tech, scheme, layers):
        f = simulate_row_cycle(tech, scheme, layers)
        p = simulate_row_cycle_phased(tech, scheme, layers)

        def diff(name):
            return np.abs(np.asarray(getattr(f, name))
                          - np.asarray(getattr(p, name))).max()

        # raw crossing events: within ONE integration step
        assert diff("t_precharge_ns") <= DT_NS + 1e-9, (
            tech.name, scheme, diff("t_precharge_ns"))
        res_dur_f = np.asarray(f.t_restore_ns) - np.asarray(f.t_sense_ns)
        res_dur_p = np.asarray(p.t_restore_ns) - np.asarray(p.t_sense_ns)
        assert np.abs(res_dur_f - res_dur_p).max() <= DT_NS + 1e-9, (
            tech.name, scheme, np.abs(res_dur_f - res_dur_p).max())
        # regen-bearing quantities: one dt per crossing + analog slack
        assert diff("t_sense_ns") <= DT_NS + self.REGEN_SLACK_NS, (
            tech.name, scheme, diff("t_sense_ns"))
        assert diff("trc_ns") <= 3 * DT_NS + self.REGEN_SLACK_NS, (
            tech.name, scheme, diff("trc_ns"))

    @pytest.mark.slow
    def test_nominal_design_points(self):
        self.assert_match(SI, "sel_strap", jnp.asarray([87, 137]))
        self.assert_match(AOS, "sel_strap", jnp.asarray([87, 137]))
        self.assert_match(D1B, "direct", jnp.asarray([1]))

    def test_fused_returns_no_traces(self):
        res = simulate_row_cycle(SI, "sel_strap", jnp.asarray([87, 137]))
        assert res.traces == {}

    @pytest.mark.slow
    def test_traces_opt_in_materializes_waveforms(self):
        res = simulate_row_cycle(SI, "sel_strap", jnp.asarray([87, 137]),
                                 traces=True)
        assert set(res.traces) == {"act", "restore", "pre"}
        assert res.traces["act"].ndim == 3

    @pytest.mark.slow
    def test_full_sweep_grid(self):
        """Every (tech, scheme) combo over the full default layer grid."""
        grid = jnp.asarray([32, 48, 64, 87, 100, 120, 137, 160, 200])
        for tech in (SI, AOS):
            for scheme in ("direct", "strap", "core_mux", "sel_strap"):
                self.assert_match(tech, scheme, grid)
        self.assert_match(D1B, "direct", jnp.asarray([1]))

    def test_many_matches_single_calls(self):
        entries = [(SI, "sel_strap", jnp.asarray([87, 137])),
                   (AOS, "sel_strap", jnp.asarray([87])),
                   (D1B, "direct", jnp.asarray([1]))]
        many = simulate_row_cycle_many(entries)
        for (tech, scheme, layers), res in zip(entries, many):
            single = simulate_row_cycle(tech, scheme, layers)
            np.testing.assert_allclose(np.asarray(res.trc_ns),
                                       np.asarray(single.trc_ns),
                                       rtol=1e-6, atol=1e-6)

    def test_chunked_equals_unchunked(self):
        # b_chunk must be a B_ALIGN multiple (smaller chunks cannot be
        # honored without padding past the caller's memory bound); the
        # B=100 grid stitches two 64-row chunks vs one 128-row dispatch
        layers = jnp.asarray(np.linspace(32, 288, 100).astype(np.float32))
        a = simulate_row_cycle(SI, "sel_strap", layers)
        b = simulate_row_cycle(SI, "sel_strap", layers, b_chunk=64)
        np.testing.assert_allclose(np.asarray(a.trc_ns),
                                   np.asarray(b.trc_ns),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.slow
    def test_chunked_equals_unchunked_large(self):
        layers = jnp.asarray(np.linspace(32, 288, 200).astype(np.float32))
        a = simulate_row_cycle(SI, "sel_strap", layers)
        b = simulate_row_cycle(SI, "sel_strap", layers, b_chunk=64)
        np.testing.assert_allclose(np.asarray(a.trc_ns),
                                   np.asarray(b.trc_ns),
                                   rtol=1e-6, atol=1e-6)


class TestPaperAnchorsViaFusedSweep:
    """Table 1 golden numbers must survive the fused sweep path."""

    @pytest.fixture(scope="class")
    def sweep(self):
        return full_sweep(layer_grid=np.array([64, 87, 137]),
                          with_transient=True)

    def test_trc_anchors(self):
        assert rel(float(transient.nominal_trc_ns(SI)), 10.9) < 0.02
        assert rel(float(transient.nominal_trc_ns(AOS)), 10.5) < 0.02
        assert rel(float(transient.nominal_trc_ns(D1B, "direct")),
                   21.3) < 0.02

    def test_best_design_hits_density_and_trc(self, sweep):
        best = best_design(sweep)
        assert best is not None
        assert best.scheme == "sel_strap"
        assert best.density_gb_mm2 >= 2.6 - 1e-6
        assert best.trc_ns < 11.0

    def test_sweep_trc_column_matches_direct_calls(self, sweep):
        for p in sweep:
            if p.tech == "si" and p.scheme == "sel_strap" and p.layers == 137:
                assert rel(p.trc_ns, 10.9) < 0.02
            if p.tech == "aos" and p.scheme == "sel_strap" and p.layers == 87:
                assert rel(p.trc_ns, 10.5) < 0.02
            if p.tech == "d1b":
                assert rel(p.trc_ns, 21.3) < 0.02

    def test_density_anchors(self, sweep):
        si_pt = [p for p in sweep if p.tech == "si" and p.layers == 137
                 and p.scheme == "sel_strap"][0]
        aos_pt = [p for p in sweep if p.tech == "aos" and p.layers == 87
                  and p.scheme == "sel_strap"][0]
        assert rel(si_pt.density_gb_mm2, 2.6) < 0.01
        assert rel(aos_pt.density_gb_mm2, 2.6) < 0.01

    def test_energy_reduction_anchor(self, sweep):
        si_pt = [p for p in sweep if p.tech == "si" and p.layers == 137
                 and p.scheme == "sel_strap"][0]
        d1b_pt = [p for p in sweep if p.tech == "d1b"][0]
        wr = 1 - si_pt.e_write_fj / d1b_pt.e_write_fj
        rd = 1 - si_pt.e_read_fj / d1b_pt.e_read_fj
        assert 0.54 < wr < 0.66 and 0.54 < rd < 0.68   # "~60% reduction"


class TestSweepIsVectorized:
    def test_full_sweep_never_calls_per_combo_transient(self, monkeypatch):
        """The batched sweep must not fall back to per-(tech, scheme)
        `simulate_row_cycle` calls."""
        def boom(*a, **kw):
            raise AssertionError("full_sweep called simulate_row_cycle "
                                 "per (tech, scheme) combo")
        monkeypatch.setattr(dse, "simulate_row_cycle", boom)
        pts = full_sweep(layer_grid=np.array([87, 137]),
                         with_transient=True)
        assert all(np.isfinite(p.trc_ns) for p in pts)

    def test_full_sweep_single_fused_dispatch(self, monkeypatch):
        """All combos fit one chunk -> exactly one fused-engine dispatch."""
        calls = []
        real = ops.row_cycle_fused

        def counting(*a, **kw):
            calls.append(a[0].shape)
            return real(*a, **kw)

        monkeypatch.setattr(transient.ops, "row_cycle_fused", counting)
        full_sweep(layer_grid=np.array([64, 87, 137]), with_transient=True)
        assert len(calls) == 1
        # 2 techs x 4 schemes x 3 layers + 1 D1b point, padded with
        # inactive rows to the B_ALIGN shape-canonicalization multiple
        n_live = 2 * 4 * 3 + 1
        expect = -(-n_live // transient.B_ALIGN) * transient.B_ALIGN
        assert calls[0][0] == expect
