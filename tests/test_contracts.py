"""Runtime contracts (`src/repro/core/contracts.py`).

The contract layer validates operand/batch invariants at the
`lower_design_operands`, `dse.sweep`, and sharded-dispatch seams — but
ONLY when `REPRO_CHECKS=1` (conftest turns it on for the whole suite).
These tests pin both directions: violations raise `ContractError` with
the seam name when enabled, and the checks are provably free when
disabled (a sentinel that explodes on any attribute access survives
`check_*`, and flipping checks on/off never retraces the fused kernel).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import contracts, dse
from repro.core.contracts import ContractError
from repro.core.space import DesignSpace
from repro.core.transient import FusedOperands
from repro.kernels import ops
from repro.kernels.row_cycle import ROLE_MAIN, ROLE_REPLICA


def make_operands(b=4, n=8, replica=False):
    f32 = jnp.float32
    role = jnp.tile(jnp.asarray([ROLE_REPLICA, ROLE_MAIN], f32), b // 2) \
        if replica else jnp.ones((b,), f32)
    params = jnp.stack([jnp.full((b,), v, f32)
                        for v in (2.0, 0.1, 1.1, 0.55, 1.0)] + [role], axis=1)
    return FusedOperands(
        c=jnp.ones((b, n), f32), g=jnp.ones((b, n - 1), f32),
        gc_res=jnp.ones((b, n), f32), gc_pre=jnp.ones((b, n), f32),
        v0=jnp.full((b, n), 0.55, f32), params=params,
        sa_tau_ns=jnp.full((b,), 0.2, f32),
        t_overhead_ns=jnp.full((b,), 1.0, f32), replica=replica)


class Bomb:
    """Raises on ANY attribute/item access — proves untouched-when-off."""

    def __getattr__(self, name):
        raise AssertionError(f"disabled contract touched .{name}")

    def __getitem__(self, key):
        raise AssertionError(f"disabled contract touched [{key!r}]")


class TestCheckOperands:
    def test_valid_operands_pass(self):
        contracts.check_operands(make_operands())
        contracts.check_operands(make_operands(replica=True))

    def test_shape_mismatch_fails(self):
        bad = make_operands()._replace(g=jnp.ones((4, 8), jnp.float32))
        with pytest.raises(ContractError, match="g must have shape"):
            contracts.check_operands(bad)

    def test_wrong_dtype_fails(self):
        # host numpy float64 sneaking past the lowering (jnp silently
        # truncates to f32 without x64, so build the bad operand in np)
        bad = make_operands()._replace(c=np.ones((4, 8), np.float64))
        with pytest.raises(ContractError, match="float32"):
            contracts.check_operands(bad)

    def test_replica_odd_batch_fails(self):
        ops_ = make_operands(b=4, replica=True)
        bad = FusedOperands(*[x[:3] for x in ops_[:6]],
                            sa_tau_ns=ops_.sa_tau_ns[:3],
                            t_overhead_ns=ops_.t_overhead_ns[:3],
                            replica=True)
        with pytest.raises(ContractError, match="even"):
            contracts.check_operands(bad)

    def test_replica_role_interleave_fails(self):
        ops_ = make_operands(b=4, replica=True)
        # swap one pair: [main, replica] instead of [replica, main]
        params = np.asarray(ops_.params).copy()
        params[0, 5], params[1, 5] = ROLE_MAIN, ROLE_REPLICA
        bad = ops_._replace(params=jnp.asarray(params))
        with pytest.raises(ContractError, match="interleaved"):
            contracts.check_operands(bad)

    def test_nonfinite_operand_fails(self):
        ops_ = make_operands()
        c = np.asarray(ops_.c).copy()
        c[1, 2] = np.nan
        with pytest.raises(ContractError, match="non-finite"):
            contracts.check_operands(ops_._replace(c=jnp.asarray(c)),
                                     where="unit")

    def test_error_names_the_seam(self):
        bad = make_operands()._replace(g=jnp.ones((4, 8), jnp.float32))
        with pytest.raises(ContractError, match=r"\[my-seam\]"):
            contracts.check_operands(bad, where="my-seam")


class TestCheckBatch:
    @pytest.fixture(scope="class")
    def batch(self):
        return dse.sweep(DesignSpace.paper_targets(), with_transient=False)

    def test_sweep_output_passes(self, batch):
        contracts.check_batch(batch)

    def test_reserved_mc_corner_key_fails(self, batch):
        b = len(np.asarray(batch.valid))
        bad = dataclasses.replace(
            batch, corners=dict(batch.corners,
                                mc_rogue=jnp.zeros((b,), jnp.float32)))
        with pytest.raises(ContractError, match="mc_rogue"):
            contracts.check_batch(bad)

    def test_corner_channel_shape_fails(self, batch):
        bad = dataclasses.replace(
            batch, corners=dict(batch.corners,
                                vdd_mult=jnp.zeros((2, 2), jnp.float32)))
        with pytest.raises(ContractError, match="vdd_mult"):
            contracts.check_batch(bad)

    def test_feasible_outside_valid_fails(self, batch):
        feasible = np.ones_like(np.asarray(batch.feasible))
        valid = np.zeros_like(np.asarray(batch.valid))
        bad = dataclasses.replace(batch, feasible=jnp.asarray(feasible),
                                  valid=jnp.asarray(valid))
        with pytest.raises(ContractError, match="subset"):
            contracts.check_batch(bad)

    def test_mc_layout_mismatch_fails(self, batch):
        bad = dataclasses.replace(batch, n_samples=7, base_len=3)
        with pytest.raises(ContractError, match="sample-major"):
            contracts.check_batch(bad)


class TestDisabledMode:
    """REPRO_CHECKS=0 must make every contract a free no-op."""

    def test_sentinel_untouched_when_disabled(self, monkeypatch):
        monkeypatch.setenv("REPRO_CHECKS", "0")
        assert contracts.check_operands(Bomb()) is None
        assert contracts.check_batch(Bomb()) is None

    def test_sentinel_explodes_when_enabled(self, monkeypatch):
        monkeypatch.setenv("REPRO_CHECKS", "1")
        with pytest.raises(AssertionError, match="touched"):
            contracts.check_operands(Bomb())

    def test_no_retrace_when_toggled(self, monkeypatch):
        """Enabling checks must not change what gets traced: the fused
        kernel's jit cache stays put when REPRO_CHECKS flips, because
        every check runs host-side outside the traced computation."""
        space = DesignSpace.paper_targets()
        dse.sweep(space, with_transient=True)          # warm the cache
        size_before = ops.row_cycle_fused._cache_size()
        assert size_before > 0
        monkeypatch.setenv("REPRO_CHECKS", "0")
        off = dse.sweep(space, with_transient=True)
        monkeypatch.setenv("REPRO_CHECKS", "1")
        on = dse.sweep(space, with_transient=True)
        assert ops.row_cycle_fused._cache_size() == size_before
        np.testing.assert_array_equal(np.asarray(off.trc_ns),
                                      np.asarray(on.trc_ns))
