"""Sharded multi-host sweep driver (PR 4) + chunker/MC-reduction fixes.

1. Sharded-vs-single-host bit-equivalence: in-process on a 1-device
   "batch" mesh, and on a real 8-forced-host-device mesh
   (`launch.mesh.make_test_mesh`) in a subprocess — nominal AND with_mc
   paths, every DesignBatch column compared exactly.
2. Chunker regression: `b_chunk` below/off the B_ALIGN grid is rejected
   instead of silently padding past the caller's memory bound, and an
   honored `b_chunk` never reaches the kernel with a larger batch.
3. `select()` clears the MC aux, so stale segment reductions raise.
4. `_segment_frac` returns NaN (not 0.0) for designs with zero valid
   samples, and `pareto_mask` NaN semantics keep such designs inert.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dse, transient
from repro.core.batch import ARRAY_FIELDS, DesignBatch
from repro.core.space import DesignSpace
from repro.kernels import ops as kernel_ops
from repro.launch import shard
from repro.launch.mesh import make_sweep_mesh

SRC = str(Path(__file__).resolve().parents[1] / "src")

POINTS = (("si", "sel_strap", 137), ("aos", "sel_strap", 87),
          ("d1b", "direct", 1))


def base_space():
    return DesignSpace.points(POINTS)


def assert_batches_identical(a, b):
    assert len(a) == len(b)
    for f in ARRAY_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    assert a.corners.keys() == b.corners.keys()
    for k in a.corners:
        np.testing.assert_array_equal(np.asarray(a.corners[k]),
                                      np.asarray(b.corners[k]), err_msg=k)
    assert (a.n_samples, a.base_len) == (b.n_samples, b.base_len)


# ---------------------------------------------------------------------------
# Sharded driver, in-process (single CPU device — the API/alignment paths)
# ---------------------------------------------------------------------------

class TestShardedSweepSingleDevice:
    def test_nominal_bit_identical_and_mesh_forms(self):
        space = base_space()
        seq = dse.sweep(space)
        mesh = make_sweep_mesh()
        assert_batches_identical(dse.sweep(space, sharding=mesh), seq)
        # a NamedSharding and the convenience wrapper hit the same path
        assert_batches_identical(
            dse.sweep(space, sharding=shard.sweep_sharding(mesh)), seq)
        assert_batches_identical(shard.sharded_sweep(space, mesh=mesh), seq)

    def test_with_mc_and_chunk_loop_bit_identical(self):
        # 144 rows at b_chunk=64 exercises the in-device chunk loop on the
        # sharded side and the sequential chunk loop on the oracle side
        space = base_space().with_mc(samples=48, key=3)
        seq = dse.sweep(space, b_chunk=64)
        assert_batches_identical(
            dse.sweep(space, sharding=make_sweep_mesh(), b_chunk=64), seq)

    def test_sharding_rejects_garbage(self):
        with pytest.raises(TypeError, match="Mesh or NamedSharding"):
            dse.sweep(base_space(), sharding="please")

    def test_sharding_with_transient_off_rejected(self):
        with pytest.raises(ValueError, match="nothing to shard"):
            dse.sweep(base_space(), with_transient=False,
                      sharding=make_sweep_mesh())

    def test_bench_child_forced_count_wins(self, monkeypatch):
        from benchmarks.bench_sharded_sweep import _child_env
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        flags = _child_env(1)["XLA_FLAGS"]
        # later duplicate wins in XLA flag parsing: ours must come last
        assert flags.endswith("--xla_force_host_platform_device_count=1")

    def test_dispatch_target_alignment(self):
        align = transient.B_ALIGN
        # identical aligned slabs per device, never below one B_ALIGN block
        assert shard._dispatch_target(73, 8, 2048) == 8 * align
        assert shard._dispatch_target(1, 8, 2048) == 8 * align
        assert shard._dispatch_target(73, 1, 2048) == 2 * align
        # slabs above b_chunk hold a whole number of chunks
        t = shard._dispatch_target(10_000, 8, 128)
        assert t % (8 * 128) == 0 and t >= 10_000


# ---------------------------------------------------------------------------
# Sharded driver, real 8-device mesh (forced host devices, subprocess)
# ---------------------------------------------------------------------------

MESH8_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import dse
    from repro.core.batch import ARRAY_FIELDS
    from repro.core.space import DesignSpace
    from repro.launch.mesh import make_test_mesh

    # multi-axis test mesh: the driver shards over the full device product
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))

    def identical(space, b_chunk):
        sh = dse.sweep(space, sharding=mesh, b_chunk=b_chunk)
        seq = dse.sweep(space, b_chunk=b_chunk)
        flds = all(np.array_equal(np.asarray(getattr(sh, f)),
                                  np.asarray(getattr(seq, f)))
                   for f in ARRAY_FIELDS)
        crns = all(np.array_equal(np.asarray(sh.corners[k]),
                                  np.asarray(seq.corners[k]))
                   for k in seq.corners)
        return bool(flds and crns)

    # a partial-axis NamedSharding must be rejected, not silently
    # replaced by the canonical full-product sharding (needs >1 device:
    # on one device every spec is equivalent)
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:
        dse.sweep(DesignSpace.points([("si", "sel_strap", 137)]),
                  sharding=NamedSharding(mesh, P("pod")))
        partial_spec_rejected = False
    except ValueError:
        partial_spec_rejected = True

    # sharded Pareto: dominator blocks distributed over the 8 devices
    # with a psum OR-reduce must stay bit-identical to the sequential
    # block loop, for block sizes off and on the device-count grid
    batch = dse.sweep(DesignSpace.paper_grid(), b_chunk=64)
    ok_pareto = all(
        np.array_equal(
            np.asarray(dse.pareto_mask(batch, sharding=mesh, block=blk)),
            np.asarray(dse.pareto_mask(batch, block=blk)))
        for blk in (4096, 17))
    # NaN objective columns must stay inert (never dominate, never be
    # dominated into oblivion) under the sharded dominance engine too
    import dataclasses
    import jax.numpy as jnp
    marg = np.asarray(batch.margin_mv).copy()
    marg[::3] = np.nan
    nan_batch = dataclasses.replace(batch, margin_mv=jnp.asarray(marg))
    ok_pareto_nan = np.array_equal(
        np.asarray(dse.pareto_mask(nan_batch, sharding=mesh,
                                   require_feasible=False)),
        np.asarray(dse.pareto_mask(nan_batch, require_feasible=False)))

    # b_chunk=64 keeps every dispatch (sharded slabs AND the sequential
    # oracle chunks) on ONE compiled shape — the subprocess stays fast
    out = {
        "ndev": jax.device_count(),
        "ok_nominal": identical(DesignSpace.paper_grid(), 64),
        "ok_mc": identical(DesignSpace.paper_grid().with_mc(samples=8,
                                                            key=0), 64),
        "ok_replica": identical(DesignSpace.paper_targets().with_replica()
                                .with_mc(samples=8, key=0), 64),
        "ok_spec_guard": partial_spec_rejected,
        "ok_pareto": bool(ok_pareto),
        "ok_pareto_nan": bool(ok_pareto_nan),
    }
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def mesh8_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    # pin the child to CPU: with libtpu installed, an unset JAX_PLATFORMS
    # makes jax probe for TPU hardware for minutes before falling back
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", MESH8_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


class TestShardedSweepMesh8:
    def test_forced_eight_devices(self, mesh8_result):
        assert mesh8_result["ndev"] == 8

    def test_nominal_bit_identical(self, mesh8_result):
        assert mesh8_result["ok_nominal"]

    def test_with_mc_bit_identical(self, mesh8_result):
        assert mesh8_result["ok_mc"]

    def test_replica_mc_bit_identical(self, mesh8_result):
        """Replica-interleaved pairs must never be split across device
        slabs: the replica-closed MC sweep is bit-identical sharded."""
        assert mesh8_result["ok_replica"]

    def test_partial_axis_spec_rejected(self, mesh8_result):
        assert mesh8_result["ok_spec_guard"]

    def test_sharded_pareto_bit_identical(self, mesh8_result):
        """Dominator blocks sharded over 8 devices + psum OR-reduce give
        the exact sequential mask, for blocks off the device grid too."""
        assert mesh8_result["ok_pareto"]

    def test_sharded_pareto_nan_inert(self, mesh8_result):
        assert mesh8_result["ok_pareto_nan"]


# ---------------------------------------------------------------------------
# Chunker regression: b_chunk must be honored, never silently exceeded
# ---------------------------------------------------------------------------

class TestBChunkHonored:
    def _operands(self, n_layers):
        space = DesignSpace.product(techs=("si",), schemes=("sel_strap",),
                                    layers=np.linspace(32, 200, n_layers))
        return transient.lower_design_operands(space.lower())

    @pytest.mark.parametrize("bad", [16, 96, 0, -64])
    def test_unaligned_b_chunk_rejected(self, bad):
        operands = self._operands(4)
        with pytest.raises(ValueError, match="B_ALIGN"):
            transient.simulate_row_cycle_lowered(operands, b_chunk=bad)
        with pytest.raises(ValueError, match="B_ALIGN"):
            shard.row_cycle_fused_sharded(operands, make_sweep_mesh(),
                                          b_chunk=bad)

    def test_requested_chunk_bounds_kernel_batch(self, monkeypatch):
        seen = []
        orig = kernel_ops.row_cycle_fused

        def recording(c, *args, **kw):
            seen.append(int(c.shape[0]))
            return orig(c, *args, **kw)

        monkeypatch.setattr(transient.ops, "row_cycle_fused", recording)
        operands = self._operands(100)
        res = transient.simulate_row_cycle_lowered(operands, b_chunk=64)
        assert seen and max(seen) <= 64          # the caller's memory bound
        # and chunking at the bound is bit-identical to one big dispatch
        res_big = transient.simulate_row_cycle_lowered(operands, b_chunk=2048)
        np.testing.assert_array_equal(np.asarray(res.trc_ns),
                                      np.asarray(res_big.trc_ns))

    def test_small_batch_not_padded_past_bound(self, monkeypatch):
        seen = []
        orig = kernel_ops.row_cycle_fused

        def recording(c, *args, **kw):
            seen.append(int(c.shape[0]))
            return orig(c, *args, **kw)

        monkeypatch.setattr(transient.ops, "row_cycle_fused", recording)
        transient.simulate_row_cycle_lowered(self._operands(3), b_chunk=64)
        assert seen == [64]        # aligned up, but capped at b_chunk


# ---------------------------------------------------------------------------
# select() must clear the MC aux (stale reductions raise)
# ---------------------------------------------------------------------------

class TestSelectClearsMCAux:
    def mc_batch(self):
        return dse.sweep(base_space().with_mc(samples=4, key=0),
                         with_transient=False)

    def test_full_selection_still_raises(self):
        batch = self.mc_batch()
        sel = batch.select(np.arange(len(batch)))    # keeps every row...
        assert sel.n_samples == 0                    # ...but the layout
        for reduce in (lambda b: b.yield_fraction(margin_mv=80.0),
                       lambda b: b.quantile(0.5, "margin_mv"),
                       lambda b: b.mc_summary(margin_mv=80.0)):
            with pytest.raises(ValueError, match="select"):
                reduce(sel)

    def test_mask_selection_raises(self):
        batch = self.mc_batch()
        mask = np.ones(len(batch), bool)
        mask[-1] = False
        with pytest.raises(ValueError, match="sample-major|select"):
            batch.select(mask).yield_fraction(margin_mv=80.0)

    def test_nominal_select_keeps_pass_map(self):
        nom = dse.sweep(base_space(), with_transient=False)
        sel = nom.select(np.asarray([0, 2]))
        got = np.asarray(sel.yield_fraction(margin_mv=80.0))
        want = (np.asarray(sel.margin_mv) >= 80.0).astype(np.float32)
        np.testing.assert_array_equal(got, want)

    def test_summary_then_select_is_the_supported_order(self):
        summ = self.mc_batch().mc_summary(margin_mv=80.0)
        front = dse.pareto_front(summ, require_feasible=False)
        assert isinstance(front, DesignBatch)
        assert front.n_samples == 1     # summary rows survive selection


# ---------------------------------------------------------------------------
# Empty-segment yield is NaN, and NaN never dominates in pareto_mask
# ---------------------------------------------------------------------------

class TestEmptySegmentYieldNaN:
    def invalidated(self, k=0):
        batch = dse.sweep(base_space().with_mc(samples=4, key=0),
                          with_transient=False)
        valid = np.asarray(batch.valid).copy()
        valid[k::batch.base_len] = False      # kill all samples of design k
        return dataclasses.replace(batch, valid=jnp.asarray(valid)), k

    def test_zero_valid_samples_yield_nan(self):
        batch, k = self.invalidated()
        yf = np.asarray(batch.yield_fraction(margin_mv=0.0))
        assert np.isnan(yf[k])
        others = np.delete(yf, k)
        assert np.all(np.isfinite(others))
        # margin_mv=0 passes every evaluated sample: true 1.0, never NaN
        np.testing.assert_array_equal(others, np.ones_like(others))

    def test_true_yield_zero_still_zero(self):
        batch, k = self.invalidated()
        yf = np.asarray(batch.yield_fraction(margin_mv=1e9))
        assert np.isnan(yf[k])                 # no estimate
        np.testing.assert_array_equal(np.delete(yf, k),
                                      np.zeros(len(POINTS) - 1))  # hard fail

    def test_mc_summary_propagates_nan_yield(self):
        batch, k = self.invalidated()
        summ = batch.mc_summary(margin_mv=0.0)
        yf = np.asarray(summ.corners["yield_frac"])
        assert np.isnan(yf[k])
        assert not bool(np.asarray(summ.feasible)[k])  # NaN frac != feasible

    def test_nan_yield_is_never_dominated(self):
        batch = two_point_batch()
        dominated = np.asarray(dse.pareto_mask(
            batch, extra_maximize=(jnp.asarray([1.0, 0.5]),)))
        np.testing.assert_array_equal(dominated, [True, False])
        # a NaN yield (zero valid samples) shields the loser: no estimate
        # means "unknown", not "worse than everything"
        shielded = np.asarray(dse.pareto_mask(
            batch, extra_maximize=(jnp.asarray([1.0, jnp.nan]),)))
        np.testing.assert_array_equal(shielded, [True, True])

    def test_nan_yield_never_dominates(self):
        batch = two_point_batch()
        # the nominal winner carries the NaN: it must not knock out the
        # loser, whose yield estimate is real
        mask = np.asarray(dse.pareto_mask(
            batch, extra_maximize=(jnp.asarray([jnp.nan, 0.5]),)))
        np.testing.assert_array_equal(mask, [True, True])


def two_point_batch():
    from repro.core.batch import DesignPoint
    mk = lambda dens, marg, trc, erd: DesignPoint(
        tech="si", scheme="sel_strap", layers=100,
        density_gb_mm2=dens, height_um=10.0, cbl_ff=30.0,
        margin_mv=marg, margin_disturbed_mv=marg, trc_ns=trc,
        e_write_fj=1.0, e_read_fj=erd, hcb_pitch_um=1.0,
        blsa_area_um2=1.0, feasible=True)
    # point 0 strictly beats point 1 on every nominal objective
    return DesignBatch.from_points([mk(8.0, 120.0, 9.0, 1.0),
                                    mk(4.0, 80.0, 12.0, 2.0)])


# ---------------------------------------------------------------------------
# Sharded Pareto + elastic driver, in-process (fast tier, 1-device mesh)
# ---------------------------------------------------------------------------

class TestShardedParetoSingleDevice:
    """`pareto_mask(..., sharding=...)` shards DOMINATOR blocks and
    OR-reduces across devices; comparisons + boolean algebra are exact,
    so the mask must be bit-identical whatever the block size.  The
    8-device distribution runs in TestShardedSweepMesh8."""

    def test_bit_identical_across_block_sizes(self):
        batch = dse.sweep(base_space().with_mc(samples=16, key=1))
        mesh = make_sweep_mesh()
        for blk in (4096, 2):
            np.testing.assert_array_equal(
                np.asarray(dse.pareto_mask(batch, sharding=mesh,
                                           block=blk)),
                np.asarray(dse.pareto_mask(batch, block=blk)),
                err_msg=f"block={blk}")

    def test_front_passthrough(self):
        batch = dse.sweep(base_space())
        front_sh = dse.pareto_front(batch, require_feasible=False,
                                    sharding=make_sweep_mesh())
        front_seq = dse.pareto_front(batch, require_feasible=False)
        assert_batches_identical(front_sh, front_seq)

    def test_nan_semantics_preserved(self):
        batch = two_point_batch()
        mesh = make_sweep_mesh()
        shielded = np.asarray(dse.pareto_mask(
            batch, sharding=mesh,
            extra_maximize=(jnp.asarray([1.0, jnp.nan]),)))
        np.testing.assert_array_equal(shielded, [True, True])
        mask = np.asarray(dse.pareto_mask(
            batch, sharding=mesh,
            extra_maximize=(jnp.asarray([jnp.nan, 0.5]),)))
        np.testing.assert_array_equal(mask, [True, True])


class TestElasticSweepFast:
    """Fast-tier elastic coverage on the 1-device mesh: the slab loop,
    checkpointing and crash/nan recovery without the multi-device drop
    machinery (that runs @slow in test_elastic.py)."""

    def test_fault_free_bit_identical(self):
        from repro.launch import elastic
        space = base_space().with_mc(samples=4, key=0)
        batch, rep = elastic.elastic_sweep(space, make_sweep_mesh(),
                                           slab_points=5)
        assert_batches_identical(batch, dse.sweep(space))
        assert (rep.restarts, rep.recomputed_points) == (0, 0)
        assert rep.resume_overhead_frac == 0.0
        assert rep.n_slabs == 3 and rep.total_points == 12

    def test_crash_and_nan_recovery_bit_identical(self):
        from repro.launch import elastic
        from repro.runtime.fault import FailureInjector
        space = base_space().with_mc(samples=4, key=0)
        batch, rep = elastic.elastic_sweep(
            space, make_sweep_mesh(), slab_points=5,
            injector=FailureInjector(schedule={1: "crash", 2: "nan"}))
        assert_batches_identical(batch, dse.sweep(space))
        assert rep.restarts == 2
        # slab 1 holds 5 points, slab 2 only 2 (12 = 5 + 5 + 2)
        assert rep.recomputed_points == 7
        assert rep.resume_overhead_frac == pytest.approx(7 / 12)

    def test_dropping_the_last_host_is_fatal(self):
        # ClusterLostError is NOT a RuntimeError on purpose: the runner
        # would otherwise restore-and-retry a sweep with no devices left
        from repro.launch import elastic
        from repro.runtime.fault import FailureInjector
        with pytest.raises(elastic.ClusterLostError,
                           match="all hosts lost"):
            elastic.elastic_sweep(
                base_space(), make_sweep_mesh(),
                injector=FailureInjector(schedule={0: "drop:host0"}))
