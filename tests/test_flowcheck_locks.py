"""flowcheck lock-discipline analyzer (`tools/flowcheck/locks`, FC3xx):
one positive + one negative fixture per rule, the interprocedural
held-lock propagation (helpers called under a lock vs thread-entry
references), the flowcheck pragma/baseline conventions, and the
acceptance checks that (a) the real serving/runtime tree is clean and
(b) the seeded lock-free stats write fails the CLI gate naming FC301.

The locks analyzer is stdlib-only (it runs in the jax-free CI lint
job), so everything here is fast-tier.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.flowcheck.common import apply_baseline, load_baseline  # noqa: E402
from tools.flowcheck.locks import DEFAULT_PATHS, LockChecker  # noqa: E402

HEADER = "import threading\n\n\n"


def svc_src(body):
    """Dedent a class-body fixture and prepend the import header."""
    return HEADER + textwrap.dedent(body)


def check(tmp_path, source, name="svc.py"):
    """Write one fixture file and run the FC3xx checker on it."""
    path = tmp_path / name
    path.write_text(source)
    pairs, suppressed, _ = LockChecker(root=tmp_path).check_paths([path])
    return pairs, suppressed


def rules_of(pairs):
    return sorted({f.rule for f, _ in pairs})


class TestFC301:
    BARE = svc_src("""\
        class Svc:
            def __init__(self):
                self._lock = threading.Lock()
                self._stats = {}

            def bump(self):
                self._stats["requests"] = self._stats.get("requests", 0) + 1
        """)

    def test_bare_access_flagged(self, tmp_path):
        pairs, _ = check(tmp_path, self.BARE)
        assert rules_of(pairs) == ["FC301"]
        f = pairs[0][0]
        assert "self._stats" in f.message and "no lock held" in f.message
        assert f.line and pairs[0][1]        # anchored to a source line

    def test_mutator_call_is_a_write(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._queue = []

                def push(self, x):
                    self._queue.append(x)
            """))
        assert rules_of(pairs) == ["FC301"]
        assert "write" in pairs[0][0].message

    def test_locked_access_clean(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._stats = {}

                def bump(self):
                    with self._lock:
                        self._stats["requests"] = 1
            """))
        assert pairs == []

    def test_immutable_config_scalar_exempt(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self, window_ms):
                    self._lock = threading.Lock()
                    self.window_ms = float(window_ms)

                def window_s(self):
                    return self.window_ms / 1e3
            """))
        assert pairs == []


class TestFC302:
    ABBA = svc_src("""\
        class Svc:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
                self._items = []

            def one(self):
                with self._a:
                    with self._b:
                        self._items.append(1)

            def two(self):
                with self._b:
                    with self._a:
                        self._items.append(2)
        """)

    def test_abba_flagged(self, tmp_path):
        pairs, _ = check(tmp_path, self.ABBA)
        assert "FC302" in rules_of(pairs)
        msg = next(f.message for f, _ in pairs if f.rule == "FC302")
        assert "ABBA" in msg

    def test_consistent_order_clean(self, tmp_path):
        pairs, _ = check(tmp_path, self.ABBA.replace(
            "with self._b:\n            with self._a:",
            "with self._a:\n            with self._b:"))
        assert pairs == []


class TestFC303:
    def test_dispatch_under_condition_flagged(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._cv = threading.Condition()
                    self._queue = []

                def serve(self, plan_sweep, space):
                    with self._cv:
                        self._queue.append(plan_sweep(space))
            """))
        assert "FC303" in rules_of(pairs)
        msg = next(f.message for f, _ in pairs if f.rule == "FC303")
        assert "plan_sweep" in msg and "self._cv" in msg

    def test_future_result_under_condition_flagged(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._cv = threading.Condition()
                    self._out = []

                def collect(self, fut):
                    with self._cv:
                        self._out.append(fut.result())
            """))
        assert "FC303" in rules_of(pairs)

    def test_dispatch_under_plain_lock_clean(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._queue = []

                def serve(self, plan_sweep, space):
                    with self._lock:
                        self._queue.append(plan_sweep(space))
            """))
        assert pairs == []


class TestFC304:
    SPLIT = svc_src("""\
        class Svc:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
                self._stats = {}

            def one(self):
                with self._a:
                    self._stats["x"] = 1

            def two(self):
                with self._b:
                    self._stats["y"] = 2
        """)

    def test_split_lock_flagged(self, tmp_path):
        pairs, _ = check(tmp_path, self.SPLIT)
        assert rules_of(pairs) == ["FC304"]
        assert "split-lock" in pairs[0][0].message

    def test_common_lock_clean(self, tmp_path):
        # both sites hold _a; the extra _b on one site is harmless
        pairs, _ = check(tmp_path, self.SPLIT.replace(
            "with self._b:\n            self._stats",
            "with self._a:\n            with self._b:\n"
            "                self._stats"))
        assert pairs == []


class TestInterprocedural:
    def test_helper_called_under_lock_clean(self, tmp_path):
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._queue = []

                def push(self, x):
                    with self._lock:
                        self._push(x)

                def _push(self, x):
                    self._queue.append(x)
            """))
        assert pairs == []

    def test_thread_target_is_fresh_entry(self, tmp_path):
        # `Thread(target=self._run)` makes _run a thread entry point with
        # nothing held, so its bare queue write must be flagged
        pairs, _ = check(tmp_path, svc_src("""\
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._queue = []
                    self._thread = None

                def start(self):
                    with self._lock:
                        self._thread = threading.Thread(target=self._run)

                def _run(self):
                    self._queue.append(1)
            """))
        assert rules_of(pairs) == ["FC301"]
        f = pairs[0][0]
        assert "_run()" in f.message and "self._queue" in f.message


class TestSuppressionAndBaseline:
    def test_flowcheck_pragma_suppresses(self, tmp_path):
        src = TestFC301.BARE.replace(
            '0) + 1', '0) + 1  # flowcheck: disable=FC301  (justified)')
        pairs, suppressed = check(tmp_path, src)
        assert pairs == [] and suppressed >= 1

    def test_repro_lint_pragma_does_not_suppress(self, tmp_path):
        # each tool's pragma tag silences only its own rules
        src = TestFC301.BARE.replace(
            '0) + 1', '0) + 1  # repro-lint: disable=FC301')
        pairs, suppressed = check(tmp_path, src)
        assert rules_of(pairs) == ["FC301"] and suppressed == 0

    def test_fingerprint_survives_line_shift(self, tmp_path):
        pairs, _ = check(tmp_path, TestFC301.BARE)
        fps = [f.fingerprint(text) for f, text in pairs]
        shifted = TestFC301.BARE.replace(
            HEADER, HEADER + "# a new header comment\nX = 1\n\n")
        pairs2, _ = check(tmp_path, shifted)
        reported, baselined = apply_baseline(pairs2, fps)
        assert reported == [] and len(baselined) == len(pairs)

    def test_committed_baseline_is_empty(self):
        fps = load_baseline(REPO / "tools/flowcheck/baseline.json")
        assert fps == [], ("the committed flowcheck baseline must stay "
                           "empty — fix or pragma findings instead")


class TestRepoClean:
    def test_default_paths_exist(self):
        for rel in DEFAULT_PATHS:
            assert (REPO / rel).is_file(), rel

    def test_serving_and_runtime_are_clean(self):
        """The lock-discipline contract documented on DSEService holds:
        no bare shared access, no ABBA nesting, no dispatch under the
        CV, no split-lock protection."""
        pairs, _, n_classes = LockChecker(root=REPO).check_paths()
        assert n_classes >= 2
        assert pairs == [], [f.render() for f, _ in pairs]


def run_cli(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.flowcheck", *args],
        cwd=cwd, env={"PYTHONPATH": str(REPO), "PATH": "/usr/bin:/bin",
                      "HOME": "/tmp", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=300)


class TestCLI:
    def test_locks_only_repo_clean(self):
        r = run_cli(["--only", "locks"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 finding(s)" in r.stdout

    def test_seeded_lock_write_fails_gate(self):
        """Acceptance check: the seeded lock-free stats write must fail
        the build naming the analyzer's rule."""
        r = run_cli(["--only", "locks", "--seed-violation", "lock-write"])
        assert r.returncode == 1, r.stdout + r.stderr
        assert "FC301" in r.stdout
        assert "seeded_service.py" in r.stdout

    def test_json_report(self, tmp_path):
        out = tmp_path / "report.json"
        r = run_cli(["--only", "locks", "--seed-violation", "lock-write",
                     "--json", str(out)])
        assert r.returncode == 1
        report = json.loads(out.read_text())
        assert report["tool"] == "flowcheck"
        assert report["analyzers"] == ["locks"]
        assert {f["rule"] for f in report["findings"]} == {"FC301"}
        assert all(f["fingerprint"] for f in report["findings"])
        assert report["stats"]["locks"]["classes_scanned"] >= 1

    def test_list_rules(self):
        r = run_cli(["--list-rules"])
        assert r.returncode == 0
        for rule in ("FC101", "FC102", "FC103", "FC104", "FC105",
                     "FC201", "FC202",
                     "FC301", "FC302", "FC303", "FC304"):
            assert rule in r.stdout, rule

    def test_unknown_analyzer_exits_2(self):
        r = run_cli(["--only", "vibes"])
        assert r.returncode == 2
        assert "unknown analyzer" in r.stderr
