import os
import sys
from pathlib import Path

# tests must see exactly ONE device (the dry-run alone forces 512)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# runtime contracts (src/repro/core/contracts.py) default ON under pytest;
# export REPRO_CHECKS=0 to time the unchecked path
os.environ.setdefault("REPRO_CHECKS", "1")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture()
def rng():
    # function-scoped: test inputs must not depend on execution order
    return np.random.default_rng(0)
