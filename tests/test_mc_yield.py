"""Monte-Carlo yield engine (PR 3).

Covers the `with_mc` fan-out through the fused row-cycle sweep:

1. Lowering: sample-major row layout, reserved mc_* channels, draw
   determinism (same key => bit-identical columns), validation.
2. Nominal equivalence: `with_mc(samples=1, sigma=0)` reproduces the
   plain sweep bit-for-bit and the `evaluate_grid` scalar oracle.
3. Physics plumbing: per-sample SA offset shifts the margins by exactly
   the drawn delta; the Vth draw moves the fused tRC monotonically.
4. Yield reductions: `yield_fraction`/`quantile` against a scalar
   per-sample oracle; `mc_summary` layout and `yield_frac` column.
5. Dispatch: a with_mc sweep still runs ONE chunked fused evaluation.
6. Selection: yield columns as Pareto/best_design objectives.
"""

import numpy as np
import pytest

from repro.core import calibration as cal
from repro.core import dse
from repro.core.space import MC_AXES, DesignSpace

POINTS = (("si", "sel_strap", 137), ("aos", "sel_strap", 87),
          ("d1b", "direct", 1))


def base_space():
    return DesignSpace.points(POINTS)


def mc_sweep(samples=32, key=0, with_transient=False, **mc_kw):
    space = base_space().with_mc(samples=samples, key=key, **mc_kw)
    return dse.sweep(space, with_transient=with_transient)


class TestMCLowering:
    def test_sample_major_layout_and_reserved_channels(self):
        sp = base_space().with_mc(samples=5, key=1).lower()
        assert sp.samples == 5
        assert len(sp) == 5 * len(POINTS)
        for name in MC_AXES:
            assert sp.corners[name].shape == (len(sp),)
        # deterministic per-point identity repeats per sample block
        np.testing.assert_array_equal(sp.tech_idx,
                                      np.tile(sp.tech_idx[:3], 5))
        np.testing.assert_array_equal(sp.layers_np,
                                      np.tile(sp.layers_np[:3], 5))

    def test_same_key_bit_identical_different_key_not(self):
        a = base_space().with_mc(samples=16, key=42).lower()
        b = base_space().with_mc(samples=16, key=42).lower()
        c = base_space().with_mc(samples=16, key=43).lower()
        for name in MC_AXES:
            np.testing.assert_array_equal(a.corners[name], b.corners[name])
        assert not np.array_equal(a.corners["mc_sa_offset_mv"],
                                  c.corners["mc_sa_offset_mv"])

    def test_jax_prng_key_accepted(self):
        import jax
        sp_int = base_space().with_mc(samples=4, key=7)
        sp_key = base_space().with_mc(samples=4, key=jax.random.PRNGKey(7))
        # both lower deterministically (not necessarily to the same draws)
        for sp in (sp_int, sp_key):
            a, b = sp.lower(), sp.lower()
            np.testing.assert_array_equal(a.corners["mc_sa_offset_mv"],
                                          b.corners["mc_sa_offset_mv"])

    def test_validation(self):
        space = base_space()
        with pytest.raises(ValueError, match="samples >= 1"):
            space.with_mc(samples=0)
        with pytest.raises(ValueError, match="already declared"):
            space.with_mc(samples=2).with_mc(samples=2)
        with pytest.raises(ValueError, match="reserved"):
            space.with_corners(mc_sa_offset_mv=(1.0,))
        with pytest.raises(ValueError, match="Monte-Carlo"):
            space.with_mc(samples=2) + space
        assert len(space.with_mc(samples=8)) == 8 * len(POINTS)

    def test_mc_composes_with_corner_axes(self):
        space = (base_space()
                 .with_corners(rh_toggles=(1e4, 5e4))
                 .with_mc(samples=3, key=0))
        sp = space.lower()
        assert len(sp) == 3 * 2 * len(POINTS)
        # corner values tile under the MC fan-out (samples outermost)
        one_sample = np.repeat([1e4, 5e4], len(POINTS))
        np.testing.assert_array_equal(sp.corners["rh_toggles"],
                                      np.tile(one_sample, 3))
        batch = dse.sweep(space, with_transient=False)
        assert batch.n_samples == 3 and batch.base_len == 2 * len(POINTS)


class TestNominalEquivalence:
    def test_samples1_sigma0_is_bit_identical_to_nominal(self):
        nom = dse.sweep(base_space(), with_transient=True)
        mc0 = dse.sweep(
            base_space().with_mc(samples=1, key=9, sa_offset_sigma_mv=0.0,
                                 vth_sigma_mv=0.0), with_transient=True)
        for f in ("margin_mv", "margin_disturbed_mv", "trc_ns",
                  "t_sense_ns", "cbl_ff", "density_gb_mm2", "e_read_fj",
                  "e_write_fj", "feasible"):
            np.testing.assert_array_equal(
                np.asarray(getattr(nom, f)), np.asarray(getattr(mc0, f)), f)

    def test_samples1_sigma0_matches_scalar_oracle(self):
        mc0 = dse.sweep(
            base_space().with_mc(samples=1, key=3, sa_offset_sigma_mv=0.0,
                                 vth_sigma_mv=0.0), with_transient=True)
        for got in mc0.to_points():
            tech = cal.get_tech(got.tech)
            (ref,) = dse.evaluate_grid(tech, got.scheme,
                                       np.asarray([got.layers]))
            assert got.margin_mv == pytest.approx(ref.margin_mv, rel=1e-5)
            assert got.trc_ns == pytest.approx(ref.trc_ns, rel=1e-5)
            assert got.feasible == ref.feasible


class TestPhysicsPlumbing:
    def test_sa_offset_delta_shifts_margin_exactly(self):
        batch = mc_sweep(samples=16, key=5)
        nom = dse.sweep(base_space(), with_transient=False)
        base = batch.base_len
        sa = np.asarray(batch.corners["mc_sa_offset_mv"], np.float32)
        for i in range(len(batch)):
            tech = cal.get_tech(batch.tech_col[i])
            expect = (float(nom.margin_mv[i % base])
                      + np.float32(tech.sa_offset_mv) - sa[i])
            assert float(batch.margin_mv[i]) == pytest.approx(expect,
                                                              abs=1e-3)

    def test_vth_draw_moves_fused_trc_monotonically(self):
        batch = mc_sweep(samples=12, key=2, with_transient=True)
        base = batch.base_len
        trc = np.asarray(batch.trc_ns).reshape(-1, base)
        dvth = np.asarray(batch.corners["mc_delta_vth_mv"]).reshape(-1, base)
        for j in range(base):
            assert trc[:, j].std() > 0.0
            order = np.argsort(dvth[:, j])
            # higher Vth -> less overdrive -> slower row cycle
            assert np.corrcoef(dvth[order, j], trc[order, j])[0, 1] > 0.9


class TestYieldReductions:
    def test_yield_fraction_matches_scalar_per_sample_oracle(self):
        batch = mc_sweep(samples=32, key=0)
        base = batch.base_len
        margin = np.asarray(batch.margin_mv).reshape(-1, base)
        margin_d = np.asarray(batch.margin_disturbed_mv).reshape(-1, base)
        for floor in (80.0, 130.0, 190.0):
            got = np.asarray(batch.yield_fraction(margin_mv=floor))
            np.testing.assert_allclose(got, (margin >= floor).mean(axis=0),
                                       atol=1e-7)
            got_d = np.asarray(batch.yield_fraction(margin_mv=floor,
                                                    disturbed=True))
            np.testing.assert_allclose(got_d,
                                       (margin_d >= floor).mean(axis=0),
                                       atol=1e-7)

    def test_yield_fraction_with_trc_spec(self):
        batch = mc_sweep(samples=8, key=1, with_transient=True)
        base = batch.base_len
        margin = np.asarray(batch.margin_mv).reshape(-1, base)
        trc = np.asarray(batch.trc_ns).reshape(-1, base)
        got = np.asarray(batch.yield_fraction(margin_mv=80.0, trc_ns=11.5))
        ref = ((margin >= 80.0) & (trc <= 11.5)).mean(axis=0)
        np.testing.assert_allclose(got, ref, atol=1e-7)

    def test_nan_trc_never_passes_a_trc_spec(self):
        batch = mc_sweep(samples=4, key=0, with_transient=False)
        got = np.asarray(batch.yield_fraction(trc_ns=1e9))
        np.testing.assert_array_equal(got, np.zeros(batch.base_len))

    def test_quantile_matches_numpy(self):
        batch = mc_sweep(samples=32, key=0)
        base = batch.base_len
        margin = np.asarray(batch.margin_mv, np.float32).reshape(-1, base)
        for q in (0.05, 0.5, 0.95):
            got = np.asarray(batch.quantile(q, "margin_mv"))
            np.testing.assert_allclose(got, np.quantile(margin, q, axis=0),
                                       rtol=1e-5)

    def test_reductions_ignore_padding_rows(self):
        batch = mc_sweep(samples=8, key=0)
        padded = batch.pad_to(64)
        assert len(padded) == 64
        np.testing.assert_array_equal(
            np.asarray(padded.yield_fraction(margin_mv=100.0)),
            np.asarray(batch.yield_fraction(margin_mv=100.0)))
        np.testing.assert_allclose(
            np.asarray(padded.quantile(0.5, "margin_mv")),
            np.asarray(batch.quantile(0.5, "margin_mv")), rtol=1e-6)

    def test_selected_batch_rejected(self):
        batch = mc_sweep(samples=4, key=0)
        broken = batch.select(np.arange(len(batch) - 2))
        with pytest.raises(ValueError, match="sample-major"):
            broken.yield_fraction(margin_mv=80.0)

    def test_nominal_batch_yield_is_pass_map(self):
        nom = dse.sweep(base_space(), with_transient=False)
        got = np.asarray(nom.yield_fraction(margin_mv=80.0))
        np.testing.assert_array_equal(
            got, (np.asarray(nom.margin_mv) >= 80.0).astype(np.float32))

    def test_same_key_bit_identical_yield_columns(self):
        a = mc_sweep(samples=32, key=11)
        b = mc_sweep(samples=32, key=11)
        np.testing.assert_array_equal(
            np.asarray(a.yield_fraction(margin_mv=80.0)),
            np.asarray(b.yield_fraction(margin_mv=80.0)))
        np.testing.assert_array_equal(np.asarray(a.margin_mv),
                                      np.asarray(b.margin_mv))


class TestSummaryAndSelection:
    def test_single_fused_dispatch(self, monkeypatch):
        calls = []
        orig = dse.simulate_row_cycle_many

        def counting(*args, **kw):
            calls.append(1)
            return orig(*args, **kw)

        monkeypatch.setattr(dse, "simulate_row_cycle_many", counting)
        dse.sweep(base_space().with_mc(samples=16, key=0))
        assert len(calls) == 1

    def test_mc_summary_layout_and_yield_column(self):
        batch = mc_sweep(samples=32, key=0)
        summ = batch.mc_summary(margin_mv=cal.MIN_FUNCTIONAL_MARGIN_MV)
        assert len(summ) == batch.base_len
        assert summ.n_samples == 1
        assert summ.tech_col == batch.tech_col[:batch.base_len]
        yf = np.asarray(summ.corners["yield_frac"])
        np.testing.assert_allclose(
            yf, np.asarray(batch.yield_fraction(
                margin_mv=cal.MIN_FUNCTIONAL_MARGIN_MV)))
        # per-sample draw channels do not survive the reduction
        assert not any(k.startswith("mc_") for k in summ.corners)
        # sampled metrics collapse to the median
        np.testing.assert_allclose(
            np.asarray(summ.margin_mv),
            np.asarray(batch.quantile(0.5, "margin_mv")), rtol=1e-6)

    def test_best_design_min_yield(self):
        batch = mc_sweep(samples=32, key=0)
        summ = batch.mc_summary(margin_mv=cal.MIN_FUNCTIONAL_MARGIN_MV)
        best = dse.best_design(summ, density_target=0.1, min_yield=0.9)
        assert best is not None
        # an impossible yield floor rejects everything
        assert dse.best_design(summ, density_target=0.1,
                               min_yield=1.1) is None
        # explicit column overrides the corners entry
        zero = np.zeros(len(summ), np.float32)
        assert dse.best_design(summ, density_target=0.1, min_yield=0.5,
                               yield_frac=zero) is None
        with pytest.raises(ValueError, match="yield column"):
            dse.best_design(dse.sweep(base_space(), with_transient=False),
                            min_yield=0.5)

    def test_pareto_front_accepts_yield_objective(self):
        batch = mc_sweep(samples=32, key=0)
        summ = batch.mc_summary(margin_mv=cal.MIN_FUNCTIONAL_MARGIN_MV)
        yf = summ.corners["yield_frac"]
        front = dse.pareto_front(summ, extra_maximize=(yf,))
        assert 0 < len(front) <= len(summ)
        # a constant extra objective changes nothing
        const = np.ones(len(summ), np.float32)
        base_mask = np.asarray(dse.pareto_mask(summ))
        np.testing.assert_array_equal(
            np.asarray(dse.pareto_mask(summ, extra_maximize=(const,))),
            base_mask)

    def test_report_yield_tables_smoke(self):
        from repro.core import report
        table = report.mc_yield_table(samples=8, key=0)
        for tech in ("si", "aos", "d1b"):
            entry = table[tech]
            assert 0.0 <= entry["yield_margin"] <= 1.0
            assert entry["margin_mv_p05"] <= entry["margin_mv_median"]
            assert entry["trc_ns_median"] <= entry["trc_ns_p95"]
        # nominal designs clear the functional floor; D1b does not
        assert table["si"]["yield_margin"] == 1.0
        assert table["d1b"]["yield_margin"] == 0.0
        rows = report.fig9b_margin_yield_vs_density(
            densities=np.asarray([1.0, 2.6]), samples=8, key=0)
        assert len(rows) == 4
        for r in rows:
            assert 0.0 <= r["yield_disturbed"] <= 1.0
