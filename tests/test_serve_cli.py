"""`repro.launch.serve` CLI: request validation, structured errors and
the 0/1/2 exit-code convention (PR 8 bugfix).

The launcher must never die with a raw traceback on malformed input:
every rejection is a machine-readable `{"error": ...}` record on stderr
plus exit code 2 (`bench_check`'s "malformed record" convention); an
engine-side failure while serving a well-formed request exits 1; a fully
served run exits 0 with one JSON summary line per request.
"""

import json

import pytest

from repro.launch import serve
from repro.launch.serve import (EXIT_BAD_REQUEST, EXIT_FAIL, EXIT_OK,
                                RequestError, parse_request)


class TestParseRequest:
    def test_minimal_defaults(self):
        kind, space, spec = parse_request({"techs": ["aos"], "layers": [87]})
        assert kind == "sweep" and spec == {}
        assert len(space) > 0

    def test_full_request(self):
        kind, space, spec = parse_request({
            "kind": "yield", "techs": ["aos"], "layers": [87, 137],
            "corners": {"rh_toggles": [1e5, 3e5]},
            "mc": {"samples": 8, "key": 3}, "replica": True,
            "spec": {"margin_mv": 5.0}})
        assert kind == "yield"
        assert space.mc is not None and space.mc.samples == 8
        assert space.replica
        assert dict(space.corner_axes)["rh_toggles"] == (1e5, 3e5)
        assert spec == {"margin_mv": 5.0}

    @pytest.mark.parametrize("obj,msg", [
        ([1, 2], "must be a JSON object"),
        ({"bogus": 1}, "unknown request key"),
        ({"techs": []}, "non-empty list"),
        ({"techs": ["not_a_tech"]}, "bad tech"),
        ({"schemes": ["not_a_scheme"]}, "bad scheme"),
        ({"layers": [0]}, "positive integers"),
        ({"layers": [4.5]}, "positive integers"),
        ({"mc": {"key": 1}}, "'samples'"),
        ({"corners": "hot"}, "'corners' must be"),
        ({"spec": ["margin_mv"]}, "'spec' must be"),
        ({"mc": {"samples": 8, "wat": 1}}, "invalid request"),
    ])
    def test_rejections(self, obj, msg):
        with pytest.raises(RequestError, match=msg):
            parse_request(obj)


class TestExitCodes:
    def test_served_ok(self, capsys):
        rc = serve.main(["--request",
                         '{"kind": "sweep", "techs": ["aos"],'
                         ' "layers": [87]}', "--stats"])
        assert rc == EXIT_OK
        lines = [json.loads(ln)
                 for ln in capsys.readouterr().out.splitlines()]
        assert lines[0]["rows"] > 0 and lines[0]["kind"] == "sweep"
        assert lines[-1]["stats"]["requests"] == 1

    def test_malformed_json_exits_2(self, capsys):
        rc = serve.main(["--request", "{not json"])
        assert rc == EXIT_BAD_REQUEST
        err = json.loads(capsys.readouterr().err.strip())
        assert err["error"]["code"] == "bad_request"

    def test_unknown_tech_exits_2(self, capsys):
        rc = serve.main(["--request", '{"techs": ["zzz"]}'])
        assert rc == EXIT_BAD_REQUEST
        err = json.loads(capsys.readouterr().err.strip())
        assert err["error"]["code"] == "bad_request"
        assert err["error"]["request"] == 0

    def test_requests_file_jsonl_and_array(self, tmp_path, capsys):
        req = {"techs": ["aos"], "layers": [87]}
        jl = tmp_path / "reqs.jsonl"
        jl.write_text(json.dumps(req) + "\n")
        assert serve.main(["--requests-file", str(jl)]) == EXIT_OK
        arr = tmp_path / "reqs.json"
        arr.write_text(json.dumps([req]))
        assert serve.main(["--requests-file", str(arr)]) == EXIT_OK
        capsys.readouterr()
        assert serve.main(["--requests-file",
                           str(tmp_path / "missing.json")]) \
            == EXIT_BAD_REQUEST

    def test_engine_failure_exits_1(self, capsys, monkeypatch):
        from repro.core import dse

        def boom(*a, **k):
            raise RuntimeError("engine fell over")

        monkeypatch.setattr(dse, "plan_sweep", boom)
        rc = serve.main(["--request", '{"techs": ["aos"], "layers": [87]}'])
        assert rc == EXIT_FAIL
        err = json.loads(capsys.readouterr().err.strip())
        assert err["error"]["code"] == "serve_failed"
        assert "engine fell over" in err["error"]["message"]

    def test_json_output_file(self, tmp_path, capsys):
        out = tmp_path / "responses.json"
        rc = serve.main(["--request", '{"techs": ["aos"], "layers": [87]}',
                         "--json", str(out)])
        assert rc == EXIT_OK
        payload = json.loads(out.read_text())
        assert payload["responses"][0]["rows"] > 0
        assert payload["stats"]["dispatches"] >= 0
