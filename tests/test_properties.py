"""Hypothesis property tests on system invariants.

Hypothesis is an optional dependency: when absent the whole module is
skipped at collection instead of erroring the tier-1 `-x` run.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.calibration import AOS, SI
from repro.core.energy import read_energy_fj, write_energy_fj
from repro.core.netlist import effective_cbl_ff
from repro.core.sense import sense_margin_mv
from repro.kernels import ref
from repro.models.common import apply_rope
from repro.models.moe import _capacity
from repro.train.optimizer import _dq8, _q8

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(l1=st.integers(16, 300), l2=st.integers(16, 300),
       tech=st.sampled_from([SI, AOS]))
def test_margin_monotone_decreasing_in_layers(l1, l2, tech):
    lo, hi = sorted((l1, l2))
    m = sense_margin_mv(tech, "sel_strap", jnp.asarray([lo, hi]))
    assert float(m[0]) >= float(m[1]) - 1e-6


@settings(**SETTINGS)
@given(layers=st.integers(16, 300), tech=st.sampled_from([SI, AOS]))
def test_energy_increases_with_cbl(layers, tech):
    L = jnp.asarray([layers, layers + 50])
    ew = write_energy_fj(tech, "sel_strap", L)
    er = read_energy_fj(tech, "sel_strap", L)
    assert float(ew[1]) > float(ew[0])
    assert float(er[1]) > float(er[0])
    cbl = effective_cbl_ff(tech, "sel_strap", L)
    assert float(cbl[1]) > float(cbl[0])


@settings(**SETTINGS)
@given(st.integers(2, 24), st.integers(1, 6), st.data())
def test_thomas_solves_diag_dominant_systems(n, b, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    d = rng.uniform(2.5, 5, (b, n))
    dl = rng.uniform(-1, 0, (b, n)); dl[:, 0] = 0
    du = rng.uniform(-1, 0, (b, n)); du[:, -1] = 0
    rhs = rng.normal(size=(b, n))
    x = np.array(ref.tridiag_solve_ref(*map(jnp.asarray, (dl, d, du, rhs))))
    for i in range(b):
        a = np.diag(d[i]) + np.diag(dl[i, 1:], -1) + np.diag(du[i, :-1], 1)
        np.testing.assert_allclose(a @ x[i], rhs[i], rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31))
def test_rc_step_is_contraction_without_sources(seed):
    """With no clamps, node voltages stay within [min(v0), max(v0)]
    (passive RC network maximum principle)."""
    rng = np.random.default_rng(seed)
    b, n, t = 3, 6, 40
    c = jnp.asarray(rng.uniform(0.5, 5, (b, n)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.01, 0.5, (b, n - 1)), jnp.float32)
    zero = jnp.zeros((b, n), jnp.float32)
    v0 = jnp.asarray(rng.uniform(0, 1.1, (b, n)), jnp.float32)
    tr = ref.rc_multistep_ref(c, g, zero, zero, v0, jnp.ones((t,)), 0.05)
    assert float(tr.max()) <= float(v0.max()) + 1e-5
    assert float(tr.min()) >= float(v0.min()) - 1e-5


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31))
def test_rc_conserves_charge(seed):
    """No clamps: total charge sum(C_i * v_i) is invariant."""
    rng = np.random.default_rng(seed)
    b, n, t = 2, 5, 60
    c = jnp.asarray(rng.uniform(0.5, 5, (b, n)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.01, 0.5, (b, n - 1)), jnp.float32)
    zero = jnp.zeros((b, n), jnp.float32)
    v0 = jnp.asarray(rng.uniform(0, 1.1, (b, n)), jnp.float32)
    tr = ref.rc_multistep_ref(c, g, zero, zero, v0, jnp.ones((t,)), 0.02)
    qt = np.array((np.array(c)[None] * np.array(tr)).sum(-1))
    np.testing.assert_allclose(qt, np.array((c * v0).sum(-1))[None].repeat(t, 0),
                               rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(1, 8), st.integers(1, 1024), st.integers(0, 2 ** 31))
def test_q8_roundtrip_error_bound(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, cols)).astype(np.float32) * rng.uniform(0.01, 10)
    q, s = _q8(jnp.asarray(x))
    back = np.array(_dq8(q, s))
    step = np.abs(x).max(-1, keepdims=True) / 127.0
    assert (np.abs(back - x) <= step * 0.5 + 1e-9).all()


@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(0, 2 ** 31))
def test_rope_preserves_norm_and_relative_angles(pos, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 4, 2, 32)).astype(np.float32))
    pos_arr = jnp.full((1, 4), pos)
    y = apply_rope(x, pos_arr, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.array(y), axis=-1),
                               np.linalg.norm(np.array(x), axis=-1),
                               rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(64, 4096), st.integers(2, 64))
def test_moe_capacity_bounds(tokens, experts):
    class C:
        top_k = 2
        n_experts = experts
        capacity_factor = 1.25
    cap = _capacity(C, tokens)
    assert cap >= C.top_k * 4
    assert cap * experts >= tokens * C.top_k          # cf>=1: no global loss


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31))
def test_softmax_attention_convexity(seed):
    """Attention output lies in the convex hull of V rows (max principle)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 4, 1, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 4, 1, 8)).astype(np.float32))
    ids = jnp.asarray([[0, 1]], jnp.int32)
    o = np.array(ref.strap_attend_ref(q, k, v, ids, 1))
    vmin = np.array(v).reshape(1, -1, 8).min(1)
    vmax = np.array(v).reshape(1, -1, 8).max(1)
    assert (o >= vmin[:, None, :] - 1e-4).all()
    assert (o <= vmax[:, None, :] + 1e-4).all()
