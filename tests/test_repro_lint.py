"""repro-lint (`tools/repro_lint`): one positive + one negative fixture
per rule, the pragma/baseline workflows, CLI exit codes + JSON report,
the registry-data sync cross-check, and the acceptance check that a
seeded RL003 violation in a scratch copy of `core/dse.py` fails the run.

Fixtures are written under tmp_path replicating the scan-root-relative
layout (`src/repro/core/...`) the rule scopes key on, and linted with
`LintEngine(root=tmp_path)` so relpaths match.
"""

import json
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.repro_lint import ALL_RULES, LintEngine  # noqa: E402
from tools.repro_lint.engine import Finding, load_baseline  # noqa: E402
from tools.repro_lint import rules as rl  # noqa: E402


def lint_files(tmp_path, files, baseline=()):
    """Write {rel: source} fixtures under tmp_path and lint them."""
    for rel, source in files.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(source))
    engine = LintEngine([cls() for cls in ALL_RULES], root=tmp_path)
    roots = sorted({rel.split("/")[0] for rel in files})
    return engine.run([tmp_path / r for r in roots], list(baseline))


def findings(tmp_path, files, rule=None):
    reported, _, _ = lint_files(tmp_path, files)
    got = [f for _, f in reported]
    return [f for f in got if f.rule == rule] if rule else got


CORE = "src/repro/core/mod.py"


class TestRL001:
    def test_eq_against_registered_name(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def pick(tech):
                if tech == "aos":
                    return 1
                return 0
            """}, rule="RL001")
        assert len(got) == 1 and "'aos'" in got[0].message

    def test_membership_against_registered_names(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def pick(scheme):
                return scheme in ("strap", "sel_strap")
            """}, rule="RL001")
        assert got

    def test_unregistered_name_and_registry_files_clean(self, tmp_path):
        got = findings(tmp_path, {
            CORE: 'MODE_OK = "fast"\ndef f(m):\n    return m == "fast"\n',
            "src/repro/core/routing.py":
                'def spec(n):\n    return n == "sel_strap"\n',
        }, rule="RL001")
        assert got == []


class TestRL002:
    def test_loop_over_batch_field(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def f(batch):
                return [x for x in batch.margin_mv]
            """}, rule="RL002")
        assert len(got) == 1 and ".margin_mv" in got[0].message

    def test_for_over_asarray(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            import numpy as np
            def f(layers):
                out = []
                for layer in np.asarray(layers):
                    out.append(layer)
                return out
            """}, rule="RL002")
        assert got

    def test_out_of_scope_and_tuple_genexp_clean(self, tmp_path):
        got = findings(tmp_path, {
            # launch/ is outside the fused-core scope
            "src/repro/launch/mod.py":
                "def f(batch):\n    return [x for x in batch.margin_mv]\n",
            # the tuple(float(x) ...) config-normalization idiom
            CORE: ("import numpy as np\n"
                   "def g(cfg):\n"
                   "    return tuple(float(x)"
                   " for x in np.asarray(cfg).reshape(-1))\n"),
        }, rule="RL002")
        assert got == []


class TestRL003:
    def test_nan_to_num_on_protected(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            import jax.numpy as jnp
            def f(trc):
                return jnp.nan_to_num(trc)
            """}, rule="RL003")
        assert len(got) == 1 and "nan_to_num" in got[0].message

    def test_where_isnan_zero_on_protected(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            import jax.numpy as jnp
            def f(margin_mv):
                return jnp.where(jnp.isnan(margin_mv), 0.0, margin_mv)
            """}, rule="RL003")
        assert got

    def test_unprotected_field_clean(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            import jax.numpy as jnp
            def f(weights):
                return jnp.nan_to_num(weights)
            """}, rule="RL003")
        assert got == []


class TestRL004:
    def test_subscript_write_outside_owner(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def f(corners, vals):
                corners["mc_extra"] = vals
            """}, rule="RL004")
        assert len(got) == 1 and "mc_*" in got[0].message

    def test_dict_literal_key(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def f(vals):
                return {"mc_sa_offset_mv": vals}
            """}, rule="RL004")
        assert got

    def test_owner_file_and_plain_key_clean(self, tmp_path):
        got = findings(tmp_path, {
            "src/repro/core/space.py":
                'def f(corners, v):\n    corners["mc_log_w"] = v\n',
            CORE: 'def g(corners, v):\n    corners["vdd_mult"] = v\n',
        }, rule="RL004")
        assert got == []


class TestRL005:
    FUSED = """
        import jax

        def simulate_row_cycle_many(operands):
            return dispatch(operands)

        def dispatch(operands):
            return jax.jit(engine)(operands)

        def engine(x):
            {body}
    """

    def test_hazard_inside_traced_path(self, tmp_path):
        src = textwrap.dedent(self.FUSED).format(body="return x.item()")
        got = findings(tmp_path, {CORE: src}, rule="RL005")
        assert len(got) == 1
        assert ".item()" in got[0].message and "'engine'" in got[0].message

    def test_python_if_on_jnp_inside_traced_path(self, tmp_path):
        src = textwrap.dedent(self.FUSED).format(
            body="if jnp.max(x) > 0:\n        return x\n    return -x")
        src = "import jax.numpy as jnp\n" + src
        got = findings(tmp_path, {CORE: src}, rule="RL005")
        assert got and "lax.cond" in got[0].message

    def test_unreachable_function_clean(self, tmp_path):
        src = textwrap.dedent(self.FUSED).format(body="return x") + (
            "\ndef host_only(batch):\n    return batch.valid.item()\n")
        got = findings(tmp_path, {CORE: src}, rule="RL005")
        assert got == []

    def test_real_repo_traced_set_is_the_fused_path(self):
        """The call graph on the real tree must reach the kernels but
        never leak into the model/serving stack (the n_valid bug)."""
        engine = LintEngine([rl.RL005TracerLeak()], root=REPO)
        rule = engine.rules[0]
        engine.run([REPO / "src"])
        traced = {f"{rel.rsplit('/', 1)[-1]}:{name}"
                  for rel, name in rule.traced_names}
        assert "ops.py:row_cycle_fused" in traced
        assert "ref.py:row_cycle_fused_ref" in traced
        assert "row_cycle.py:_row_cycle_kernel" in traced
        assert not any(rel.startswith(("src/repro/models/",
                                       "src/repro/serving/"))
                       for rel, _ in rule.traced_names)
        assert not any(name == "n_valid" for _, name in rule.traced_names)


class TestRL006:
    def test_unaligned_b_chunk_keyword(self, tmp_path):
        got = findings(tmp_path, {CORE: """
            def f(sweep, space):
                return sweep(space, b_chunk=100)
            """}, rule="RL006")
        assert len(got) == 1 and "B_ALIGN" in got[0].message

    def test_unaligned_constant_assignment(self, tmp_path):
        got = findings(tmp_path, {CORE: "MY_B_CHUNK = 1000\n"},
                       rule="RL006")
        assert got

    def test_aligned_values_and_tests_scope_clean(self, tmp_path):
        got = findings(tmp_path, {
            CORE: "def f(sweep, s):\n    return sweep(s, b_chunk=2048)\n",
            # tests/ may use tiny unaligned batches on purpose
            "tests/test_x.py": "def f(sweep, s):\n"
                               "    return sweep(s, b_chunk=100)\n",
        }, rule="RL006")
        assert got == []


class TestSuppression:
    BAD = ("import jax.numpy as jnp\n"
           "def f(trc):\n"
           "    return jnp.nan_to_num(trc)"
           "{pragma}\n")

    def test_line_pragma_suppresses(self, tmp_path):
        files = {CORE: self.BAD.format(
            pragma="  # repro-lint: disable=RL003  (justified)")}
        reported, suppressed, _ = lint_files(tmp_path, files)
        assert reported == [] and suppressed == 1

    def test_file_pragma_suppresses(self, tmp_path):
        files = {CORE: "# repro-lint: disable-file=RL003\n"
                       + self.BAD.format(pragma="")}
        reported, suppressed, _ = lint_files(tmp_path, files)
        assert reported == [] and suppressed == 1

    def test_pragma_for_other_rule_does_not(self, tmp_path):
        files = {CORE: self.BAD.format(
            pragma="  # repro-lint: disable=RL001")}
        reported, _, _ = lint_files(tmp_path, files)
        assert [f.rule for _, f in reported] == ["RL003"]

    def test_baseline_absorbs_exact_finding_only(self, tmp_path):
        files = {CORE: self.BAD.format(pragma="")}
        reported, _, _ = lint_files(tmp_path, files)
        (fp, _), = reported
        # baselined: absorbed, not reported
        reported2, _, baselined = lint_files(tmp_path, files, baseline=[fp])
        assert reported2 == [] and [b[0] for b in baselined] == [fp]
        # a different violation is NOT covered by that fingerprint
        files2 = {CORE: self.BAD.format(pragma="").replace(
            "nan_to_num(trc)", "nan_to_num(trc * 2)")}
        reported3, _, _ = lint_files(tmp_path, files2, baseline=[fp])
        assert len(reported3) == 1

    def test_baseline_survives_line_shift(self, tmp_path):
        """Fingerprints hash (rule, path, stripped line text), not line
        numbers: inserting unrelated lines above a baselined finding
        must not resurrect it."""
        files = {CORE: self.BAD.format(pragma="")}
        reported, _, _ = lint_files(tmp_path, files)
        (fp, _), = reported
        shifted = {CORE: "# an unrelated header comment\nX = 1\n\n"
                   + self.BAD.format(pragma="")}
        reported2, _, baselined = lint_files(tmp_path, shifted,
                                             baseline=[fp])
        assert reported2 == [] and [b[0] for b in baselined] == [fp]

    def test_committed_baseline_is_empty(self):
        fps = load_baseline(REPO / "tools/repro_lint/baseline.json")
        assert fps == [], ("the committed baseline must stay empty — fix "
                           "or pragma findings instead of baselining them")


class TestRegistrySync:
    """rules.py hardcodes registry data (the CI lint env has no jax);
    these cross-checks fail the suite when the model code moves."""

    def test_tech_and_scheme_names(self):
        from repro.core import calibration, routing
        assert rl.REGISTERED_TECHS == tuple(calibration.TECHS)
        assert rl.REGISTERED_SCHEMES == tuple(routing.SCHEMES)

    def test_batch_axis_fields(self):
        from repro.core import batch, transient
        assert set(batch.ARRAY_FIELDS) <= rl.BATCH_AXIS_ATTRS
        fused = set(transient.FusedOperands._fields) - {"replica"}
        assert fused <= rl.BATCH_AXIS_ATTRS
        assert rl.B_ALIGN == transient.B_ALIGN

    def test_mc_reserved_names(self):
        from repro.core import space
        assert all(k.startswith(rl.MC_RESERVED_PREFIX)
                   for k in space.MC_AXES + (space.MC_LOG_W,))

    def test_rl005_roots_exist(self):
        from repro.core import transient
        from repro.launch import shard
        assert hasattr(transient, "simulate_row_cycle_many") or hasattr(
            transient, "simulate_row_cycle_lowered")
        assert {r for r in rl.RL005TracerLeak.ROOTS} <= (
            set(dir(transient)) | set(dir(shard)))


def run_cli(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *args],
        cwd=cwd, env={"PYTHONPATH": str(REPO), "PATH": "/usr/bin:/bin",
                      "HOME": "/tmp"},
        capture_output=True, text=True, timeout=300)


class TestCLI:
    def test_repo_is_clean(self):
        r = run_cli(["src", "tests", "benchmarks", "examples"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "repro_lint: OK" in r.stdout

    def test_seeded_rl003_violation_fails(self, tmp_path):
        """Acceptance check: inject a NaN-squash into a scratch copy of
        core/dse.py and the linter must exit 1 naming RL003."""
        scratch = tmp_path / "scratch"
        shutil.copytree(REPO / "src", scratch / "src",
                        ignore=shutil.ignore_patterns("__pycache__"))
        dse = scratch / "src/repro/core/dse.py"
        dse.write_text(dse.read_text() + textwrap.dedent("""
            def _seeded_violation(trc):
                return jnp.nan_to_num(trc)
        """))
        r = run_cli(["src"], cwd=scratch)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "RL003" in r.stdout
        assert "dse.py" in r.stdout

    def test_json_report_and_exit_codes(self, tmp_path):
        scratch = tmp_path / "scratch"
        bad = scratch / "src/repro/core/mod.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("import jax.numpy as jnp\n"
                       "def f(trc):\n"
                       "    return jnp.nan_to_num(trc)\n")
        out = scratch / "report.json"
        r = run_cli(["src", "--json", str(out)], cwd=scratch)
        assert r.returncode == 1
        report = json.loads(out.read_text())
        assert [f["rule"] for f in report["findings"]] == ["RL003"]
        assert report["findings"][0]["fingerprint"]
        assert "RL003" in report["rules"]

    def test_unparseable_file_exits_2(self, tmp_path):
        scratch = tmp_path / "scratch"
        bad = scratch / "src/repro/core/mod.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("def broken(:\n")
        r = run_cli(["src"], cwd=scratch)
        assert r.returncode == 2
        assert "cannot parse" in r.stderr

    def test_list_rules(self):
        r = run_cli(["--list-rules"])
        assert r.returncode == 0
        for rule_id in ("RL001", "RL002", "RL003", "RL004", "RL005",
                        "RL006"):
            assert rule_id in r.stdout


def test_fingerprint_survives_line_drift():
    f = Finding("RL003", "src/repro/core/mod.py", 10, 4, "msg")
    g = Finding("RL003", "src/repro/core/mod.py", 99, 4, "msg")
    assert f.fingerprint("  x = 1  ") == g.fingerprint("x = 1")
    assert f.fingerprint("x = 1") != g.fingerprint("x = 2")
