"""End-to-end behaviour tests for the whole system.

1. The DSE engine selects the paper's design (selector+strap @ 2.6 Gb/mm2)
   and its headline claims hold.
2. A small-mesh (2,2,2) multi-pod dry-run lowers+compiles train and decode
   steps with the production sharding rules (subprocess: 8 host devices).
3. The full 512-device sweep results (when present) are all green.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_dse_reaches_paper_conclusion():
    from repro.core.dse import best_design, full_sweep
    pts = full_sweep(layer_grid=np.array([87, 137]), with_transient=True)
    best = best_design(pts)
    assert best is not None
    assert best.scheme == "sel_strap"
    assert best.density_gb_mm2 >= 2.6 - 1e-6
    assert best.trc_ns < 11.0
    assert best.hcb_pitch_um >= 0.5            # manufacturable


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs.base import input_specs
    from repro.configs.registry import get_arch
    from repro.distributed import sharding as shard
    from repro.launch.mesh import make_test_mesh
    from repro.models import registry as M
    from repro.train.optimizer import abstract_opt_state, opt_state_axes
    from repro.train.step import make_serve_decode, make_train_step

    results = {}
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    ns = lambda t: shard.named(t, mesh)
    for arch in ("qwen2-1.5b", "mamba2-780m"):
        cfg = get_arch(arch + "-smoke")
        abs_p = M.abstract_params(cfg)
        p_specs = shard.tree_specs(M.param_axes(cfg), abs_p, mesh)
        batch = input_specs(cfg, "smoke")
        b_specs = shard.batch_specs(batch, mesh)
        abs_o = abstract_opt_state(cfg.optimizer, abs_p)
        o_specs = shard.tree_specs(opt_state_axes(cfg.optimizer,
                                                  M.param_axes(cfg)),
                                   abs_o, mesh)
        step, _ = make_train_step(cfg)
        jt = jax.jit(step, in_shardings=(ns(p_specs), ns(o_specs),
                                         ns(b_specs)),
                     out_shardings=(ns(p_specs), ns(o_specs), None))
        with mesh:
            compiled = jt.lower(abs_p, abs_o, batch).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax returns [dict]
            ca = ca[0] if ca else {}
        flops = ca.get("flops", -1)
        # decode path too
        bsz, seq = 2, 128
        cache_abs = M.abstract_cache(cfg, bsz, seq)
        c_specs = shard.cache_specs(cfg, M.cache_axes(cfg, bsz, seq),
                                    cache_abs, mesh)
        dec = make_serve_decode(cfg)
        tok = jax.ShapeDtypeStruct((bsz, 1), jax.numpy.int32)
        pos = jax.ShapeDtypeStruct((bsz,), jax.numpy.int32)
        jd = jax.jit(dec, in_shardings=(ns(p_specs), ns(c_specs),
                                        None, None),
                     out_shardings=(None, None, ns(c_specs)))
        with mesh:
            dc = jd.lower(abs_p, cache_abs, tok, pos).compile()
        results[arch] = dict(train_flops=float(flops), ok=True)
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_mini_multipod_dryrun_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    # pin the child to CPU: with libtpu installed, an unset
    # JAX_PLATFORMS makes jax probe for TPU hardware for minutes
    # before falling back (the forced-host-device flag wants CPU anyway)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    results = json.loads(r.stdout.strip().splitlines()[-1])
    assert results["qwen2-1.5b"]["ok"] and results["mamba2-780m"]["ok"]


def test_full_dryrun_results_if_present():
    """If the full 512-device sweep has been run, every produced baseline
    cell must have compiled OK with sane metrics."""
    results_dir = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    files = sorted(results_dir.glob("*.json")) if results_dir.exists() else []
    files = [f for f in files if "opt" not in f.name]
    if not files:
        pytest.skip("full dry-run sweep not run in this environment")
    for f in files:
        d = json.loads(f.read_text())
        assert d.get("ok"), f"{f.name}: {d.get('error', '')[:200]}"
        assert d["flops_per_device"] > 0, f.name
    assert len(files) >= 64      # 32 runnable cells x 2 meshes
