"""Sharding-rule unit tests (pure logic — no multi-device needed)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_arch
from repro.distributed.sharding import dp_axes, spec_for_axes


class FakeMesh:
    """Duck-typed mesh: only .axis_names and .devices.shape are used."""
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.zeros(tuple(sizes.values()))


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestSpecForAxes:
    def test_tp_and_fsdp(self):
        s = spec_for_axes(("dmodel", "ff"), (8192, 22016), MESH1)
        assert s == P("data", "model")

    def test_indivisible_drops_to_replicated(self):
        # 12 q-heads -> qkv dim 12*128=1536 divisible; but a raw head dim of
        # 12 must NOT shard 16 ways
        s = spec_for_axes(("heads",), (12,), MESH1)
        assert s == P(None)
        s = spec_for_axes(("qkv",), (1536,), MESH1)
        assert s == P("model")

    def test_batch_multi_axis(self):
        s = spec_for_axes(("batch", None), (256, 4096), MESH2)
        assert s == P(("pod", "data"), None)
        # batch=32 divides pod*data=32 exactly
        s = spec_for_axes(("batch", None), (32, 4096), MESH2)
        assert s == P(("pod", "data"), None)
        # batch=1: replicated
        s = spec_for_axes(("batch", None), (1, 4096), MESH2)
        assert s == P(None, None)

    def test_no_double_use_of_axis(self):
        # two dims both wanting "model": only the first gets it
        s = spec_for_axes(("vocab", "ff"), (51200, 8192), MESH1)
        assert s == P("model", None)

    def test_dp_axes_fallback(self):
        assert dp_axes(MESH2, 256) == ("pod", "data")
        assert dp_axes(MESH2, 16) == ("pod",) or dp_axes(MESH2, 16) == ()
        assert dp_axes(MESH1, 16) == ("data",)


class TestParamSpecsEndToEnd:
    @pytest.mark.parametrize("arch", ["deepseek-67b", "arctic-480b",
                                      "mamba2-780m", "zamba2-7b",
                                      "whisper-tiny"])
    def test_all_params_get_specs(self, arch):
        from repro.distributed.sharding import tree_specs
        from repro.models import registry as M
        cfg = get_arch(arch)
        axes = M.param_axes(cfg)
        abs_p = M.abstract_params(cfg)
        specs = tree_specs(axes, abs_p, MESH1)
        n_sharded = 0
        total_bytes = 0
        sharded_bytes = 0
        for spec, ab in zip(jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)),
                jax.tree.leaves(abs_p)):
            assert isinstance(spec, P)
            assert len(spec) == len(ab.shape)
            nb = ab.size * ab.dtype.itemsize
            total_bytes += nb
            if any(e is not None for e in spec):
                n_sharded += 1
                sharded_bytes += nb
        assert n_sharded > 0
        # at least 99% of parameter bytes must be sharded (ZeRO discipline)
        assert sharded_bytes / total_bytes > 0.99, arch
