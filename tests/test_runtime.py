"""Checkpointing, fault tolerance, stragglers, elastic replanning, data."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import (DataLoader, LoaderConfig, MemmapSource,
                                 SyntheticSource)
from repro.runtime.fault import (ElasticPlan, FailureInjector,
                                 FaultTolerantRunner, HeartbeatMonitor,
                                 StragglerPolicy, replan_mesh)


class TestCheckpoint:
    def tree(self, rng):
        return dict(params=dict(w=jnp.asarray(rng.normal(size=(4, 8)),
                                              jnp.float32),
                                b=jnp.asarray(rng.normal(size=(8,)),
                                              jnp.bfloat16)),
                    count=jnp.asarray(7, jnp.int32))

    def test_roundtrip(self, rng, tmp_path):
        cm = CheckpointManager(tmp_path)
        t = self.tree(rng)
        cm.save(3, t)
        got, step = cm.restore(like=t)
        assert step == 3
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_async_and_gc(self, rng, tmp_path):
        cm = CheckpointManager(tmp_path, keep=2)
        t = self.tree(rng)
        for s in (1, 2, 3, 4):
            cm.save(s, t, blocking=False)
            cm.wait()
        assert cm.all_steps() == [3, 4]

    def test_restores_latest(self, rng, tmp_path):
        cm = CheckpointManager(tmp_path)
        t = self.tree(rng)
        cm.save(1, t)
        t2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t)
        cm.save(5, t2)
        got, step = cm.restore(like=t)
        assert step == 5
        np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                                   np.asarray(t2["params"]["w"]))


class TestFaultRunner:
    def test_crash_restart_resumes_correctly(self, tmp_path):
        saves = {}
        state0 = {"x": 0}

        def step_fn(state, step):
            return {"x": state["x"] + 1}, dict(loss=1.0 / (step + 1))

        def save_fn(step, state):
            saves[step] = dict(state)

        def restore_fn():
            step = max(saves)
            return dict(saves[step]), step

        inj = FailureInjector({7: "crash", 13: "nan"})
        saves[0] = dict(state0)
        r = FaultTolerantRunner(step_fn, save_fn, restore_fn, inj,
                                ckpt_every=5)
        state, log = r.run(state0, 20)
        assert r.restarts == 2
        assert state["x"] == 20              # every step eventually executed
        assert [m["step"] for m in log][-1] == 19

    def test_nan_detection(self):
        def step_fn(state, step):
            return state, dict(loss=float("nan") if step == 3 else 0.5)
        calls = {"restore": 0}
        def restore_fn():
            calls["restore"] += 1
            return {}, 4                      # skip the poisoned step
        r = FaultTolerantRunner(step_fn, lambda *a: None, restore_fn,
                                ckpt_every=100)
        r.run({}, 6)
        assert calls["restore"] == 1


class TestStraggler:
    def test_drops_only_stragglers(self):
        pol = StragglerPolicy(quorum_fraction=0.75, deadline_factor=2.0)
        durations = {f"w{i}": 1.0 for i in range(15)}
        durations["w15"] = 10.0              # straggler
        admitted, rescale = pol.admit(durations)
        assert "w15" not in admitted
        assert len(admitted) == 15
        assert abs(rescale - 16 / 15) < 1e-9

    def test_no_stragglers_keeps_all(self):
        pol = StragglerPolicy()
        durations = {f"w{i}": 1.0 + 0.01 * i for i in range(16)}
        admitted, rescale = pol.admit(durations)
        assert len(admitted) == 16 and rescale == 1.0


class TestElastic:
    def test_replan_keeps_model_parallel(self):
        p = replan_mesh(240, model_parallel=16)
        assert p == ElasticPlan(data=15, model=16)

    def test_replan_degrades_below_mp(self):
        p = replan_mesh(12, model_parallel=16)
        assert p.devices <= 12 and p.model == 8


class TestHeartbeat:
    def test_detection_by_timeout(self):
        t = {"now": 0.0}
        hb = HeartbeatMonitor(["a", "b"], timeout_s=5.0,
                              clock=lambda: t["now"])
        t["now"] = 3.0
        hb.beat("a")
        t["now"] = 7.0
        assert hb.dead() == ["b"]
        assert hb.alive() == ["a"]


class TestData:
    def test_deterministic_and_shifted(self):
        src = SyntheticSource(1000, seed=3)
        c = LoaderConfig(batch_size=2, seq_len=32, seed=3)
        dl = DataLoader(src, c)
        b1 = dl.batch_at(5)
        b2 = dl.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["tokens"][:, 1:],
                                      b2["targets"][:, :-1])
        dl.close()

    def test_shards_disjoint(self):
        src = SyntheticSource(1000, seed=0)
        a = DataLoader(src, LoaderConfig(2, 16, shard_id=0, num_shards=2))
        b = DataLoader(src, LoaderConfig(2, 16, shard_id=1, num_shards=2))
        ba, bb = a.batch_at(0), b.batch_at(0)
        assert not np.array_equal(ba["tokens"], bb["tokens"])
        a.close(); b.close()

    def test_memmap_source(self, tmp_path):
        path = tmp_path / "toks.bin"
        MemmapSource.write(path, np.arange(10_000) % 256)
        src = MemmapSource(path)
        s = src.sequence(3, 64)
        assert s.shape == (65,)
        assert (s >= 0).all()

    def test_prefetch_thread(self):
        src = SyntheticSource(100, seed=1)
        dl = DataLoader(src, LoaderConfig(1, 8, prefetch=2))
        batches = [next(dl) for _ in range(3)]
        assert all(b["tokens"].shape == (1, 8) for b in batches)
        dl.close()


class TestLoopIntegration:
    @pytest.mark.slow
    def test_train_improves_and_survives_crash(self, tmp_path):
        from repro.configs.registry import get_arch
        from repro.train.loop import TrainConfig, train
        cfg = get_arch("qwen2-1.5b-smoke")
        tc = TrainConfig(steps=25, batch_size=4, seq_len=64, ckpt_every=8,
                         ckpt_dir=str(tmp_path), log_every=100,
                         failure_schedule={12: "crash"})
        out = train(cfg, tc, verbose=False)
        assert out["restarts"] == 1
        assert out["final_loss"] < out["first_loss"]
