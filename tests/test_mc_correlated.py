"""Correlated within-die variation + importance-sampled ppm tails (PR 5).

Covers the two halves of the variation-aware MC engine:

1. Correlated draws: `with_mc(corr=...)` composes each standardized draw
   as `global_die + mat_gradient + local` via low-rank factor draws —
   `corr=0` reproduces the PR-3 i.i.d. draws bit-for-bit, `corr=1`
   applies the per-tech variance decomposition (marginal sigma
   preserved, die component shared, gradient correlation decaying with
   row distance).
2. Importance sampling: a shifted/scaled proposal on the local draws
   rides the batch as the reserved `mc_log_w` channel; the DesignBatch
   reductions become weight-aware (uniform weights bit-identical to the
   plain estimators), `ess()` diagnoses weight degeneracy, and
   `yield_ppm` estimates deep-tail failure rates with a CI that NaNs
   out when the tail ESS is too low.  The @slow oracle checks the ppm
   estimate against a brute-force large-N i.i.d. run and the analytic
   Gaussian tail.
"""

import math
from dataclasses import replace

import numpy as np
import pytest

from repro.core import calibration as cal
from repro.core import dse
from repro.core.space import (MC_AXES, MC_LOG_W, DesignSpace,
                              _gradient_basis)

POINTS = (("si", "sel_strap", 137), ("aos", "sel_strap", 87),
          ("d1b", "direct", 1))


def base_space():
    return DesignSpace.points(POINTS)


def _register(tech):
    cal.register_tech(tech, overwrite=True)
    return tech


@pytest.fixture
def die_only_tech():
    tech = _register(cal.SI.with_(name="t_die", mc_die_sigma_frac=1.0,
                                  mc_mat_sigma_frac=0.0))
    yield tech
    cal.unregister_tech(tech.name)


@pytest.fixture
def grad_only_tech():
    tech = _register(cal.SI.with_(name="t_grad", mc_die_sigma_frac=0.0,
                                  mc_mat_sigma_frac=1.0,
                                  mc_corr_length=0.2))
    yield tech
    cal.unregister_tech(tech.name)


def _iid_reference_draws(samples, key_entropy, mu_sa, sig_sa, sig_vth):
    """The PR-3 i.i.d. draw algorithm, replicated verbatim: the corr=0
    path must consume the rng stream identically."""
    rng = np.random.default_rng(key_entropy)
    z = rng.standard_normal((2, samples, len(mu_sa)))
    mc_sa = np.maximum(np.asarray(mu_sa)[None]
                       + np.asarray(sig_sa)[None] * z[0], 0.0)
    mc_dvth = np.asarray(sig_vth)[None] * z[1]
    return (mc_sa.reshape(-1).astype(np.float32),
            mc_dvth.reshape(-1).astype(np.float32))


class TestCorrelatedDraws:
    def test_corr0_bit_identical_to_iid_reference(self):
        sa_ref, dvth_ref = _iid_reference_draws(
            16, (7,), mu_sa=(25.0, 25.0, 25.0), sig_sa=(5.0, 5.0, 4.0),
            sig_vth=(25.0, 35.0, 20.0))
        for kwargs in ({}, {"corr": 0.0}):
            sp = base_space().with_mc(samples=16, key=7, **kwargs).lower()
            np.testing.assert_array_equal(sp.corners["mc_sa_offset_mv"],
                                          sa_ref)
            np.testing.assert_array_equal(sp.corners["mc_delta_vth_mv"],
                                          dvth_ref)
            assert MC_LOG_W not in sp.corners

    def test_corr1_preserves_marginal_moments(self):
        sp = base_space().with_mc(samples=2048, key=3, corr=1.0).lower()
        dvth = sp.corners["mc_delta_vth_mv"].reshape(2048, len(POINTS))
        np.testing.assert_allclose(dvth.std(axis=0), (25.0, 35.0, 20.0),
                                   rtol=0.12)
        np.testing.assert_allclose(dvth.mean(axis=0), 0.0, atol=3.0)

    def test_die_component_shared_within_a_sample(self, die_only_tech):
        space = DesignSpace.points(
            [(die_only_tech.name, "sel_strap", ell)
             for ell in (64, 100, 137)])
        sp = space.with_mc(samples=32, key=1, corr=1.0).lower()
        dvth = sp.corners["mc_delta_vth_mv"].reshape(32, 3)
        # a pure die-level component is one draw per sample, shared by
        # every base row
        np.testing.assert_allclose(dvth.std(axis=1), 0.0, atol=1e-4)
        assert dvth.std(axis=0).min() > 0.0

    def test_corr_knob_scales_shared_variance(self, die_only_tech):
        space = DesignSpace.points(
            [(die_only_tech.name, "sel_strap", ell) for ell in (64, 137)])
        sp = space.with_mc(samples=2048, key=2, corr=0.5).lower()
        dvth = sp.corners["mc_delta_vth_mv"].reshape(2048, 2)
        rho = np.corrcoef(dvth[:, 0], dvth[:, 1])[0, 1]
        # z = sqrt(0.5)*local + sqrt(0.5)*die  =>  corr between rows 0.5
        assert rho == pytest.approx(0.5, abs=0.08)

    def test_gradient_correlation_decays_with_distance(self,
                                                       grad_only_tech):
        layers = np.linspace(32, 200, 24)
        space = DesignSpace.points(
            [(grad_only_tech.name, "sel_strap", ell) for ell in layers])
        sp = space.with_mc(samples=1024, key=4, corr=1.0).lower()
        dvth = sp.corners["mc_delta_vth_mv"].reshape(1024, 24)
        rho = np.corrcoef(dvth.T)
        near = rho[0, 1]
        far = rho[0, -1]
        assert near > 0.8
        assert far < near - 0.3

    def test_gradient_basis_unit_rows_and_decay(self):
        pos = np.linspace(0.0, 1.0, 33)
        basis = _gradient_basis(pos, np.full(33, 0.15))
        np.testing.assert_allclose((basis ** 2).sum(axis=1), 1.0,
                                   rtol=1e-12)
        gram = basis @ basis.T
        assert gram[0, 1] > gram[0, -1]

    def test_validation(self):
        space = base_space()
        with pytest.raises(ValueError, match="corr"):
            space.with_mc(samples=2, corr=-0.1)
        with pytest.raises(ValueError, match="corr"):
            space.with_mc(samples=2, corr=1.5)
        with pytest.raises(ValueError, match="tail_scale"):
            space.with_mc(samples=2, tail_scale=0.0)
        with pytest.raises(ValueError, match="pair"):
            space.with_mc(samples=2, tail_shift=(1.0, 2.0, 3.0))

    def test_over_unity_fractions_raise_at_lower(self):
        tech = _register(cal.SI.with_(name="t_over",
                                      mc_die_sigma_frac=0.7,
                                      mc_mat_sigma_frac=0.5))
        try:
            space = DesignSpace.points([(tech.name, "sel_strap", 137)])
            with pytest.raises(ValueError, match="t_over"):
                space.with_mc(samples=2, corr=1.0).lower()
            # scaled down by corr they fit again
            space.with_mc(samples=2, corr=0.5).lower()
        finally:
            cal.unregister_tech(tech.name)

    def test_fraction_sum_inside_guard_tolerance_stays_finite(self):
        # the over-unity guard grants 1e-9 of float headroom; a sum
        # landing inside it must clamp the local remainder to zero, not
        # sqrt a negative number into NaN draws
        tech = _register(cal.SI.with_(name="t_edge",
                                      mc_die_sigma_frac=1.0,
                                      mc_mat_sigma_frac=1e-10))
        try:
            space = DesignSpace.points([(tech.name, "sel_strap", 137)])
            sp = space.with_mc(samples=16, key=0, corr=1.0).lower()
            for name in MC_AXES:
                assert np.isfinite(sp.corners[name]).all()
        finally:
            cal.unregister_tech(tech.name)

    def test_corr_draw_determinism(self):
        a = base_space().with_mc(samples=8, key=5, corr=1.0).lower()
        b = base_space().with_mc(samples=8, key=5, corr=1.0).lower()
        c = base_space().with_mc(samples=8, key=5, corr=0.7).lower()
        for name in MC_AXES:
            np.testing.assert_array_equal(a.corners[name], b.corners[name])
        assert not np.array_equal(a.corners["mc_delta_vth_mv"],
                                  c.corners["mc_delta_vth_mv"])


class TestImportanceWeights:
    def test_log_w_channel_gating(self):
        assert MC_LOG_W not in base_space().with_mc(4).lower().corners
        assert MC_LOG_W not in base_space().with_mc(
            4, tail_shift=0.0, tail_scale=1.0).lower().corners
        for kwargs in ({"tail_shift": 2.0}, {"tail_scale": 1.3},
                       {"tail_shift": (2.0, 0.0)}):
            sp = base_space().with_mc(4, **kwargs).lower()
            assert sp.corners[MC_LOG_W].shape == (len(sp),)

    def test_log_w_matches_density_ratio(self):
        shift, scale = (2.0, 0.5), (1.3, 1.0)
        sp = base_space().with_mc(samples=64, key=11, tail_shift=shift,
                                  tail_scale=scale).lower()
        rng = np.random.default_rng((11,))
        z0 = rng.standard_normal((2, 64, len(POINTS)))
        sh = np.asarray(shift).reshape(2, 1, 1)
        sc = np.asarray(scale).reshape(2, 1, 1)
        z = sh + sc * z0
        expect = (-0.5 * z ** 2 + 0.5 * z0 ** 2 + np.log(sc)).sum(axis=0)
        np.testing.assert_allclose(sp.corners[MC_LOG_W],
                                   expect.reshape(-1), rtol=1e-5,
                                   atol=1e-5)

    def test_uniform_log_w_matches_unweighted_reductions(self):
        batch = dse.sweep(base_space().with_mc(samples=64, key=0),
                          with_transient=False)
        uni = replace(batch, corners={**batch.corners,
                                      MC_LOG_W: np.zeros(len(batch),
                                                         np.float32)})
        np.testing.assert_allclose(
            np.asarray(uni.yield_fraction(margin_mv=120.0)),
            np.asarray(batch.yield_fraction(margin_mv=120.0)), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(uni.quantile(0.5, "margin_mv")),
            np.asarray(batch.quantile(0.5, "margin_mv")), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(uni.ess()),
                                   np.asarray(batch.ess()), rtol=1e-6)

    def test_weighted_yield_fraction_matches_numpy_oracle(self):
        batch = dse.sweep(
            base_space().with_mc(samples=256, key=3,
                                 tail_shift=(1.5, 0.0)),
            with_transient=False)
        base = batch.base_len
        w = np.exp(np.asarray(batch.corners[MC_LOG_W],
                              np.float64)).reshape(-1, base)
        margin = np.asarray(batch.margin_mv, np.float64).reshape(-1, base)
        floor = 125.0
        expect = ((w * (margin >= floor)).sum(axis=0) / w.sum(axis=0))
        got = np.asarray(batch.yield_fraction(margin_mv=floor))
        np.testing.assert_allclose(got, expect, rtol=1e-4)

    def test_weighted_bulk_yield_agrees_with_iid(self):
        floor = 128.0      # in the bulk of the si margin distribution
        space_is = base_space().with_mc(samples=2048, key=1,
                                        tail_shift=(1.0, 0.0))
        space_iid = base_space().with_mc(samples=2048, key=2)
        y_is = np.asarray(dse.sweep(space_is, with_transient=False)
                          .yield_fraction(margin_mv=floor))
        y_iid = np.asarray(dse.sweep(space_iid, with_transient=False)
                           .yield_fraction(margin_mv=floor))
        np.testing.assert_allclose(y_is, y_iid, atol=0.06)

    def test_weighted_quantile(self):
        batch = dse.sweep(
            base_space().with_mc(samples=2048, key=5,
                                 tail_shift=(1.0, 0.0)),
            with_transient=False)
        iid = dse.sweep(base_space().with_mc(samples=2048, key=6),
                        with_transient=False)
        med_w = np.asarray(batch.quantile(0.5, "margin_mv"))
        med_i = np.asarray(iid.quantile(0.5, "margin_mv"))
        np.testing.assert_allclose(med_w, med_i, atol=1.5)
        # vector q keeps the (len(q), base) contract and stays ordered
        qs = np.asarray(batch.quantile((0.05, 0.5, 0.95), "margin_mv"))
        assert qs.shape == (3, batch.base_len)
        assert (np.diff(qs, axis=0) >= 0.0).all()
        # a NaN metric (transient off) has no weighted quantile either
        assert np.isnan(np.asarray(batch.quantile(0.5, "trc_ns"))).all()

    def test_weighted_quantile_ignores_invalid_rows_values(self):
        # an invalid row's stale metric value must not become a CDF
        # knot: only its weight being zero is not enough — low-q
        # quantiles would interpolate toward it
        batch = dse.sweep(
            base_space().with_mc(samples=16, key=0,
                                 tail_shift=(1.0, 0.0)),
            with_transient=False)
        valid = np.asarray(batch.valid).copy()
        margin = np.asarray(batch.margin_mv).copy()
        valid[0:batch.base_len] = False          # invalidate sample 0
        margin[0:batch.base_len] = 0.0           # ... with garbage values
        poisoned = replace(batch, valid=valid, margin_mv=margin)
        lo_q = np.asarray(poisoned.quantile(0.005, "margin_mv"))
        ref = np.asarray(batch.margin_mv).reshape(16, -1)[1:]
        assert (lo_q >= ref.min(axis=0) - 1e-3).all()

    def test_ess_diagnostic(self):
        iid = dse.sweep(base_space().with_mc(samples=128, key=0),
                        with_transient=False)
        np.testing.assert_allclose(np.asarray(iid.ess()), 128.0)
        shifted = dse.sweep(
            base_space().with_mc(samples=128, key=0,
                                 tail_shift=(2.0, 0.0)),
            with_transient=False)
        assert (np.asarray(shifted.ess()) < 128.0).all()
        summ = shifted.mc_summary(margin_mv=80.0)
        np.testing.assert_allclose(np.asarray(summ.corners["ess"]),
                                   np.asarray(shifted.ess()), rtol=1e-5)

    def test_yield_ppm_nan_semantics(self):
        batch = dse.sweep(base_space().with_mc(samples=64, key=0),
                          with_transient=False)
        # si/aos never fail a 2.6-sigma floor in 64 draws: zero observed
        # failures -> tail ESS 0 -> NaN, never a fake 0 ppm
        ppm = batch.yield_ppm(margin_mv=80.0)
        est = np.asarray(ppm["fail_ppm"])
        assert np.isnan(est[0]) and np.isnan(est[1])
        # d1b fails the floor in bulk: a real estimate
        assert est[2] > 0.0
        assert np.asarray(ppm["ess"])[2] >= 8.0
        # an impossible ESS floor NaNs everything out
        all_nan = batch.yield_ppm(margin_mv=80.0, min_ess=1e9)
        assert np.isnan(np.asarray(all_nan["fail_ppm"])).all()
        # zero valid samples: no estimate at all (mirrors yield_fraction)
        invalid = replace(batch, valid=np.zeros(len(batch), bool))
        assert np.isnan(
            np.asarray(invalid.yield_ppm(margin_mv=80.0)["fail_ppm"])
        ).all()

    def test_yield_ppm_analytic_gaussian_tail(self):
        # the margin column is exactly  m0 - sigma * z  in the SA draw,
        # so the spec-failure probability has a closed form to test the
        # importance-sampled estimator against
        space = DesignSpace.points([("si", "sel_strap", 137)])
        m0 = float(np.asarray(
            dse.sweep(space, with_transient=False).margin_mv)[0])
        sigma, t = 5.0, 4.0
        floor = m0 - t * sigma
        p_true = 0.5 * math.erfc(t / math.sqrt(2.0)) * 1e6
        batch = dse.sweep(
            space.with_mc(samples=4096, key=1, tail_shift=(t, 0.0),
                          tail_scale=(1.2, 1.0)),
            with_transient=False)
        ppm = batch.yield_ppm(margin_mv=floor)
        est = float(np.asarray(ppm["fail_ppm"])[0])
        lo = float(np.asarray(ppm["fail_ppm_lo"])[0])
        hi = float(np.asarray(ppm["fail_ppm_hi"])[0])
        assert float(np.asarray(ppm["ess"])[0]) > 100.0
        assert est == pytest.approx(p_true, rel=0.3)
        width = hi - lo
        assert lo - width <= p_true <= hi + width

    def test_mc_summary_weighted_columns(self):
        batch = dse.sweep(
            base_space().with_mc(samples=256, key=7,
                                 tail_shift=(1.0, 0.0)),
            with_transient=False)
        summ = batch.mc_summary(margin_mv=80.0)
        assert len(summ) == batch.base_len
        assert MC_LOG_W not in summ.corners
        np.testing.assert_allclose(
            np.asarray(summ.corners["yield_frac"]),
            np.asarray(batch.yield_fraction(margin_mv=80.0)), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(summ.margin_mv),
            np.asarray(batch.quantile(0.5, "margin_mv")), rtol=1e-5)

    def test_tail_report_tables(self):
        table = report_tail_table(samples=512, key=0, tail_shift=3.0)
        for tech in ("si", "aos", "d1b"):
            entry = table[tech]
            assert set(entry) >= {"fail_ppm", "fail_ppm_lo",
                                  "fail_ppm_hi", "tail_ess"}
            est = entry["fail_ppm"]
            assert math.isnan(est) or 0.0 <= est <= 1e6
        # d1b fails the functional floor in bulk — always estimable
        assert table["d1b"]["fail_ppm"] > 1e5
        rows = report_tail_curve(floors_mv=(40.0, 60.0), samples=256,
                                 key=0, tail_shift=2.0)
        assert len(rows) == 2 * 3
        for r in rows:
            assert math.isnan(r["fail_ppm"]) or 0.0 <= r["fail_ppm"] <= 1e6


def report_tail_table(**kw):
    from repro.core import report
    return report.mc_tail_yield_table(**kw)


def report_tail_curve(**kw):
    from repro.core import report
    return report.fig_tail_probability(**kw)


@pytest.mark.slow
class TestPpmOracle:
    def test_is_tail_matches_bruteforce_iid_oracle(self):
        """Acceptance: the importance-sampled ppm estimate agrees with a
        brute-force large-N i.i.d. run within the reported confidence
        intervals (and with the analytic Gaussian tail)."""
        space = DesignSpace.points([("si", "sel_strap", 137)])
        m0 = float(np.asarray(
            dse.sweep(space, with_transient=False).margin_mv)[0])
        sigma, t = 5.0, 3.5
        floor = m0 - t * sigma
        p_true = 0.5 * math.erfc(t / math.sqrt(2.0)) * 1e6

        brute = dse.sweep(space.with_mc(samples=400_000, key=9),
                          with_transient=False)
        bf = brute.yield_ppm(margin_mv=floor)
        bf_est = float(np.asarray(bf["fail_ppm"])[0])
        bf_half = 0.5 * (float(np.asarray(bf["fail_ppm_hi"])[0])
                         - float(np.asarray(bf["fail_ppm_lo"])[0]))

        shifted = dse.sweep(
            space.with_mc(samples=8192, key=4, tail_shift=(t, 0.0),
                          tail_scale=(1.2, 1.0)),
            with_transient=False)
        is_ppm = shifted.yield_ppm(margin_mv=floor)
        is_est = float(np.asarray(is_ppm["fail_ppm"])[0])
        is_half = 0.5 * (float(np.asarray(is_ppm["fail_ppm_hi"])[0])
                         - float(np.asarray(is_ppm["fail_ppm_lo"])[0]))

        assert float(np.asarray(is_ppm["ess"])[0]) > 200.0
        assert abs(is_est - bf_est) <= is_half + bf_half
        assert abs(is_est - p_true) <= 2.0 * is_half
        # the IS run needed ~50x fewer samples for a tighter interval
        assert is_half < bf_half
