"""Strapped hierarchical collectives on a real (forced-host) 8-device mesh.

Multi-device tests run in a subprocess so the main pytest session keeps a
single CPU device.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# 8-forced-host-device subprocess with XLA compiles: minutes on CPU
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh
    from repro.distributed.collectives import (hierarchical_psum_tree,
                                               collective_matrix)

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}

    # exact mode == plain mean (each "device" holds the same replica here,
    # so the hierarchical mean over 8 devices must equal the original value)
    out, err = hierarchical_psum_tree(grads, mesh, compress=False)
    exact_w = np.array(out["w"]); exact_b = np.array(out["b"])
    ok_exact = (np.allclose(exact_w, np.array(grads["w"]), atol=1e-5)
                and np.allclose(exact_b, np.array(grads["b"]), atol=1e-5))

    # compressed mode: close to exact, error feedback bounded by quant step
    outc, errc = hierarchical_psum_tree(grads, mesh, compress=True)
    comp_w = np.array(outc["w"])
    scale = np.abs(np.array(grads["w"])).max() / 127.0
    ok_comp = np.abs(comp_w - exact_w).max() <= scale * 1.01
    # local error feedback <= half of the shard's quant step;
    # the shard is a sum over |data|=2 replicas -> one 'scale'
    ok_err = np.abs(np.array(errc["w"])).max() <= scale * 1.01

    m = collective_matrix(mesh)
    ok_matrix = (m["strap_factor"] == 2
                 and m["strapped_cross_pod_bytes_per_byte"]
                     < m["flat_cross_pod_bytes_per_byte"])

    print(json.dumps(dict(ok_exact=bool(ok_exact), ok_comp=bool(ok_comp),
                          ok_err=bool(ok_err), ok_matrix=bool(ok_matrix))))
""")


@pytest.fixture(scope="module")
def subproc_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    # pin the child to CPU: with libtpu installed, an unset
    # JAX_PLATFORMS makes jax probe for TPU hardware for minutes
    # before falling back (the forced-host-device flag wants CPU anyway)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_exact_mode_matches_plain_psum(subproc_result):
    assert subproc_result["ok_exact"]


def test_int8_compression_close_and_error_bounded(subproc_result):
    assert subproc_result["ok_comp"]
    assert subproc_result["ok_err"]


def test_cross_pod_traffic_reduction(subproc_result):
    assert subproc_result["ok_matrix"]
