"""HLO-text parsing (`repro/roofline/hlo`): the collective byte
accounting's hardened edges — unknown dtypes and `-done` async
completions warn + count instead of silently dropping — and the
generic compiled-artifact scan helpers the flowcheck dispatch auditor
builds on.  Pure text fixtures: no jax, fast tier.
"""

import warnings

import pytest

from repro.roofline import hlo

AR = ("r = f32[128] all-reduce(f32[128] p), "
      "replica_groups={{0,1}}")


class TestParseCollectives:
    def test_known_dtype_bytes_counted(self):
        out = hlo.parse_collectives(AR)
        assert out["by_type"] == {"all-reduce": 512}
        assert out["ops"] == 1
        assert out["in_pod_bytes"] == 512 and out["cross_pod_bytes"] == 0
        assert out["unknown_dtypes"] == {} and out["async_done_ops"] == 0

    def test_unknown_dtype_warns_and_counts(self):
        text = ("r = q4[64,64] all-reduce(q4[64,64] p), "
                "replica_groups={{0,1}}")
        with pytest.warns(UserWarning, match="undercount"):
            out = hlo.parse_collectives(text)
        assert "q4" in out["unknown_dtypes"]
        assert out["unknown_dtypes"]["q4"] >= 1
        assert out["by_type"]["all-reduce"] == 0    # excluded, not guessed
        assert out["ops"] == 1                      # ...but still counted

    def test_async_done_warns_and_counts(self):
        text = "\n".join([
            "s = f32[128] all-reduce-start(f32[128] p), "
            "replica_groups={{0,1}}",
            "d = f32[128] all-reduce-done(s)",
        ])
        with pytest.warns(UserWarning, match="'-start' halves"):
            out = hlo.parse_collectives(text)
        assert out["async_done_ops"] == 1
        # payload counted once, on the -start half
        assert out["by_type"] == {"all-reduce": 512}
        assert out["ops"] == 1

    def test_clean_text_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            out = hlo.parse_collectives(AR)
        assert out["total_bytes"] == 512

    def test_cross_pod_split(self):
        text = AR.replace("{{0,1}}", "{{0,256}}")
        out = hlo.parse_collectives(text, pod_size=256)
        assert out["cross_pod_bytes"] == 512 and out["in_pod_bytes"] == 0


class TestScanHelpers:
    def test_custom_call_targets(self):
        text = ('c1 = f32[8] custom-call(p), '
                'custom_call_target="lapack_sgetrf"\n'
                'c2 = f32[8] custom-call(q), '
                'custom_call_target="lapack_sgetrf"\n')
        assert hlo.scan_custom_call_targets(text) == {"lapack_sgetrf": 2}
        assert hlo.scan_custom_call_targets("add = f32[8] add(a, b)") == {}

    def test_f64_mentions_and_limit(self):
        text = "\n".join(f"x{i} = f64[4] add(a, b)" for i in range(5))
        assert len(hlo.scan_f64_mentions(text)) == 5
        assert len(hlo.scan_f64_mentions(text, limit=2)) == 2
        assert hlo.scan_f64_mentions("y = f32[64] add(a, b)") == []

    def test_constant_bytes_threshold(self):
        # 1024*32 f32 = 131072 bytes == flowcheck's CONST_BYTES_LIMIT
        text = "\n".join([
            "big = f32[1024,32] constant({...})",
            "small = f32[2] constant({1, 2})",
        ])
        got = hlo.scan_constant_bytes(text)
        assert [n for n, _ in got] == [131072, 8]   # largest first
        # the flowcheck gate uses min_bytes=LIMIT+1: an exactly-at-limit
        # constant passes, one byte more would not
        assert hlo.scan_constant_bytes(text, min_bytes=131072 + 1) == []
        assert hlo.scan_constant_bytes(text, min_bytes=131072)[0][0] \
            == 131072

    def test_host_transfer_ops(self):
        text = "\n".join([
            "i = (f32[8], token[]) infeed(tok)",
            "o = token[] outfeed(x, tok)",
            "o2 = token[] outfeed(y, tok)",
        ])
        assert hlo.scan_host_transfer_ops(text) == {"infeed": 1,
                                                    "outfeed": 2}
        assert hlo.scan_host_transfer_ops("z = f32[8] add(a, b)") == {}
