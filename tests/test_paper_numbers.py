"""The engine must reproduce every endpoint the paper reports.

These are the validation anchors of the faithful reproduction (DESIGN.md
§7.1).  Tolerances: 3% for analytic quantities, 2% for the transient tRC.
"""

import jax.numpy as jnp
import pytest

from repro.core.calibration import AOS, D1B, SI
from repro.core.density import (bit_density_gb_mm2, density_scaling_vs_d1b,
                                layers_for_density, stack_height_um)
from repro.core.energy import read_energy_fj, write_energy_fj
from repro.core.netlist import effective_cbl_ff
from repro.core.routing import bonding_geometry
from repro.core.sense import sense_margin_mv
from repro.core.transient import simulate_row_cycle

L_SI = jnp.asarray([137])
L_AOS = jnp.asarray([87])
ONE = jnp.asarray([1])


def rel(a, b):
    return abs(a - b) / abs(b)


class TestCBL:
    def test_sel_strap_si(self):
        assert rel(float(effective_cbl_ff(SI, "sel_strap", L_SI)[0]), 6.6) < 0.03

    def test_d1b(self):
        assert float(effective_cbl_ff(D1B, "direct", ONE)[0]) == pytest.approx(20.0)


class TestSenseMargin:
    def test_si_130mv(self):
        assert rel(float(sense_margin_mv(SI, "sel_strap", L_SI)[0]), 130.0) < 0.03

    def test_aos_189mv(self):
        assert rel(float(sense_margin_mv(AOS, "sel_strap", L_AOS)[0]), 189.0) < 0.03

    def test_d1b_54mv(self):
        assert rel(float(sense_margin_mv(D1B, "direct", ONE)[0]), 54.0) < 0.03

    def test_si_disturbed_70mv(self):
        got = float(sense_margin_mv(SI, "sel_strap", L_SI, with_disturb=True)[0])
        assert rel(got, 70.0) < 0.03


class TestEnergy:
    def test_write(self):
        assert rel(float(write_energy_fj(SI, "sel_strap", L_SI)[0]), 6.26) < 0.03
        assert rel(float(write_energy_fj(AOS, "sel_strap", L_AOS)[0]), 5.38) < 0.03

    def test_read(self):
        assert rel(float(read_energy_fj(SI, "sel_strap", L_SI)[0]), 1.57) < 0.03
        assert rel(float(read_energy_fj(AOS, "sel_strap", L_AOS)[0]), 1.35) < 0.03

    def test_60pct_reduction_vs_d1b(self):
        wr = 1 - float(write_energy_fj(SI, "sel_strap", L_SI)[0]
                       / write_energy_fj(D1B, "direct", ONE)[0])
        rd = 1 - float(read_energy_fj(SI, "sel_strap", L_SI)[0]
                       / read_energy_fj(D1B, "direct", ONE)[0])
        assert 0.54 < wr < 0.66 and 0.54 < rd < 0.68   # "~60% reduction"


class TestDensity:
    def test_26_gb_mm2(self):
        assert rel(float(bit_density_gb_mm2(SI, L_SI)[0]), 2.6) < 0.01
        assert rel(float(bit_density_gb_mm2(AOS, L_AOS)[0]), 2.6) < 0.01

    def test_layer_counts(self):
        assert int(layers_for_density(SI, 2.6)[()]) == 137
        assert int(layers_for_density(AOS, 2.6)[()]) == 87

    def test_stack_heights(self):
        assert rel(float(stack_height_um(SI, L_SI)[0]), 9.6) < 0.01
        assert rel(float(stack_height_um(AOS, L_AOS)[0]), 6.9) < 0.01

    def test_6x_over_d1b(self):
        assert rel(float(density_scaling_vs_d1b(SI, L_SI)[0]), 6.0) < 0.02


class TestBonding:
    def test_hcb_pitches(self):
        assert rel(float(bonding_geometry(SI, "sel_strap").hcb_pitch_um), 0.75) < 0.01
        assert rel(float(bonding_geometry(AOS, "sel_strap").hcb_pitch_um), 0.62) < 0.01
        assert rel(float(bonding_geometry(SI, "direct").hcb_pitch_um), 0.26) < 0.03
        assert rel(float(bonding_geometry(AOS, "direct").hcb_pitch_um), 0.22) < 0.01

    def test_blsa_areas(self):
        assert rel(float(bonding_geometry(SI, "sel_strap").blsa_area_um2), 1.12) < 0.01
        assert rel(float(bonding_geometry(AOS, "sel_strap").blsa_area_um2), 0.76) < 0.02

    def test_manufacturability_window(self):
        assert bool(bonding_geometry(SI, "sel_strap").manufacturable)
        assert not bool(bonding_geometry(SI, "direct").manufacturable)
        assert not bool(bonding_geometry(AOS, "core_mux").manufacturable)


class TestTRC:
    def test_si(self):
        got = float(simulate_row_cycle(SI, "sel_strap", L_SI).trc_ns[0])
        assert rel(got, 10.9) < 0.02

    def test_aos(self):
        got = float(simulate_row_cycle(AOS, "sel_strap", L_AOS).trc_ns[0])
        assert rel(got, 10.5) < 0.02

    def test_d1b(self):
        got = float(simulate_row_cycle(D1B, "direct", ONE).trc_ns[0])
        assert rel(got, 21.3) < 0.02

    def test_2x_speedup(self):
        si = float(simulate_row_cycle(SI, "sel_strap", L_SI).trc_ns[0])
        d1b = float(simulate_row_cycle(D1B, "direct", ONE).trc_ns[0])
        assert d1b / si > 1.9
