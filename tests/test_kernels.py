"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.rc_transient import rc_multistep_pallas
from repro.kernels.row_cycle import row_cycle_fused_pallas
from repro.kernels.strap_gather import strap_attend_pallas


def random_ladder(rng, b, n, dtype):
    c = rng.uniform(1, 5, (b, n)).astype(dtype)
    g = rng.uniform(0.05, 0.2, (b, n - 1)).astype(dtype)
    gc = np.zeros((b, n), dtype)
    gc[:, 0] = 0.2
    vc = np.full((b, n), 0.55, dtype)
    v0 = rng.uniform(0, 1.1, (b, n)).astype(dtype)
    return map(jnp.asarray, (c, g, gc, vc, v0))


class TestRCTransientKernel:
    @pytest.mark.parametrize(
        "b,n,t",
        [(1, 6, 16), (130, 4, 25),
         pytest.param(9, 6, 50, marks=pytest.mark.slow),
         pytest.param(64, 8, 33, marks=pytest.mark.slow),
         pytest.param(256, 6, 10, marks=pytest.mark.slow)])
    def test_shapes(self, rng, b, n, t):
        c, g, gc, vc, v0 = random_ladder(rng, b, n, np.float32)
        ramp = jnp.asarray(np.clip(np.arange(t) / 8, 0, 1), jnp.float32)
        out_ref = ref.rc_multistep_ref(c, g, gc, vc, v0, ramp, 0.02)
        out_pl = rc_multistep_pallas(c, g, gc, vc, v0, ramp, 0.02,
                                     interpret=True)
        assert out_pl.shape == (t, b, n)
        np.testing.assert_allclose(np.array(out_ref), np.array(out_pl),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, rng, dtype):
        if dtype == np.float64:
            pytest.skip("x64 disabled in test session")
        c, g, gc, vc, v0 = random_ladder(rng, 7, 6, dtype)
        ramp = jnp.ones((20,), dtype)
        out_ref = ref.rc_multistep_ref(c, g, gc, vc, v0, ramp, 0.01)
        out_pl = rc_multistep_pallas(c, g, gc, vc, v0, ramp, 0.01,
                                     interpret=True)
        np.testing.assert_allclose(np.array(out_ref), np.array(out_pl),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.slow
    def test_block_partitioning(self, rng):
        """Batch larger than one block must tile correctly (the fused
        engine's padded-tail test covers block tiling in the fast tier)."""
        c, g, gc, vc, v0 = random_ladder(rng, 300, 6, np.float32)
        ramp = jnp.ones((12,), jnp.float32)
        out_ref = ref.rc_multistep_ref(c, g, gc, vc, v0, ramp, 0.02)
        out_pl = rc_multistep_pallas(c, g, gc, vc, v0, ramp, 0.02,
                                     b_blk=128, interpret=True)
        np.testing.assert_allclose(np.array(out_ref), np.array(out_pl),
                                   rtol=1e-5, atol=1e-6)


def random_row_cycle_inputs(rng, b, n, dtype=np.float32):
    """Random fused-engine operands with realistic clamp networks."""
    c = rng.uniform(1, 5, (b, n)).astype(dtype)
    g = rng.uniform(0.05, 0.2, (b, n - 1)).astype(dtype)
    gc_res = np.zeros((b, n), dtype)
    gc_res[:, 0] = 0.125
    gc_pre = np.zeros((b, n), dtype)
    gc_pre[:, :n - 1] = 0.125
    v0 = np.full((b, n), 0.55, dtype)
    v0[:, n - 1] = 1.0
    params = np.stack([
        rng.uniform(0.5, 4.0, b),       # tau_wl
        rng.uniform(0.01, 0.2, b),      # thr_rel
        np.full(b, 1.1),                # vdd
        np.full(b, 0.55),               # vpre
        np.ones(b),                     # active
    ], axis=1).astype(dtype)
    return tuple(map(jnp.asarray, (c, g, gc_res, gc_pre, v0, params)))


class TestRowCycleFusedKernel:
    """Pallas fused ACT/RESTORE/PRE engine vs the jnp oracle."""

    DT = 0.02

    def check(self, args, n_act, n_res, n_pre, **kw):
        evt_ref, vend_ref = ref.row_cycle_fused_ref(
            *args, self.DT, n_act, n_res, n_pre)
        evt_pl, vend_pl = row_cycle_fused_pallas(
            *args, self.DT, n_act, n_res, n_pre, interpret=True, **kw)
        # event times must agree to within one integration step (usually
        # exactly; float32 noise at a threshold can flip one step); rows
        # that never cross a phase report NaN in BOTH engines
        t_ref = np.asarray(evt_ref)[:, [0, 2, 3]]
        t_pl = np.asarray(evt_pl)[:, [0, 2, 3]]
        np.testing.assert_array_equal(np.isnan(t_ref), np.isnan(t_pl))
        diff = np.where(np.isnan(t_ref), 0.0, np.abs(t_ref - t_pl))
        assert diff.max() <= self.DT + 1e-9
        np.testing.assert_allclose(np.asarray(evt_ref)[:, 1],
                                   np.asarray(evt_pl)[:, 1],
                                   rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(np.asarray(vend_ref),
                                   np.asarray(vend_pl),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("b,n,n_act,n_res,n_pre",
                             [(9, 6, 30, 15, 10), (64, 8, 18, 12, 10),
                              pytest.param(1, 6, 20, 18, 12,
                                           marks=pytest.mark.slow),
                              pytest.param(130, 4, 20, 15, 10,
                                           marks=pytest.mark.slow),
                              pytest.param(256, 6, 16, 12, 10,
                                           marks=pytest.mark.slow)])
    def test_shapes_and_phase_durations(self, rng, b, n, n_act, n_res, n_pre):
        args = random_row_cycle_inputs(rng, b, n)
        self.check(args, n_act, n_res, n_pre)

    def test_padded_batch_tail(self, rng):
        """B=150 with b_blk=64 exercises a multi-block grid with a padded
        last block; inactive padding rows must not perturb live points."""
        args = random_row_cycle_inputs(rng, 150, 6)
        self.check(args, 12, 10, 8, b_blk=64)

    def test_inactive_points_never_step(self, rng):
        """active=0 rows start DONE: zero event times, untouched state."""
        args = list(random_row_cycle_inputs(rng, 8, 6))
        params = np.array(args[5])
        params[3:, 4] = 0.0
        args[5] = jnp.asarray(params)
        evt, v_end = row_cycle_fused_pallas(*args, self.DT, 10, 10, 10,
                                            interpret=True)
        np.testing.assert_array_equal(np.asarray(evt)[3:], 0.0)
        np.testing.assert_allclose(np.asarray(v_end)[3:],
                                   np.asarray(args[4])[3:])

    def test_timeout_is_nan_not_phase_window(self, rng):
        """An uncrossable ACT threshold must report NaN — an older revision
        clamped the event to the phase window, silently aliasing timeouts
        with legitimate last-step crossings."""
        args = list(random_row_cycle_inputs(rng, 4, 6))
        params = np.array(args[5])
        params[:, 1] = 1e9                    # thr_rel no signal can reach
        args[5] = jnp.asarray(params)
        n_act = 15
        for run in (row_cycle_fused_pallas, None):
            evt, _ = (
                ref.row_cycle_fused_ref(*args, self.DT, n_act, 10, 10)
                if run is None
                else run(*args, self.DT, n_act, 10, 10, interpret=True))
            assert np.isnan(np.asarray(evt)[:, 0]).all()

    def test_last_step_crossing_stays_finite(self, rng):
        """The flip side of NaN timeouts: a crossing that lands exactly on
        the final ACT step must report the finite n_act*dt, not NaN."""
        args = list(random_row_cycle_inputs(rng, 4, 6))
        params = np.array(args[5])
        params[:, 1] = 1e-6                   # crosses on the first step
        args[5] = jnp.asarray(params)
        # find each row's natural crossing step, then shrink the window to
        # end exactly there for row 0
        evt_pl, _ = row_cycle_fused_pallas(*args, self.DT, 30, 10, 10,
                                           interpret=True)
        n_cross = int(round(float(np.asarray(evt_pl)[0, 0]) / self.DT))
        evt, _ = row_cycle_fused_pallas(*args, self.DT, n_cross, 10, 10,
                                        interpret=True)
        t0 = float(np.asarray(evt)[0, 0])
        assert np.isfinite(t0)
        np.testing.assert_allclose(t0, n_cross * self.DT, rtol=1e-6)


class TestTridiag:
    @pytest.mark.parametrize("b,n", [(1, 3), (5, 7), (16, 32)])
    def test_vs_dense_solve(self, rng, b, n):
        d = rng.uniform(2, 4, (b, n))
        dl = rng.uniform(-1, 0, (b, n)); dl[:, 0] = 0
        du = rng.uniform(-1, 0, (b, n)); du[:, -1] = 0
        rhs = rng.normal(size=(b, n))
        x = np.array(ref.tridiag_solve_ref(*map(jnp.asarray,
                                                (dl, d, du, rhs))))
        for i in range(b):
            a = np.diag(d[i]) + np.diag(dl[i, 1:], -1) + np.diag(du[i, :-1], 1)
            np.testing.assert_allclose(a @ x[i], rhs[i], rtol=1e-4,
                                       atol=1e-5)


class TestStrapAttendKernel:
    @pytest.mark.parametrize(
        "b,p,page,hkv,d,hq,g",
        [(2, 8, 16, 2, 64, 8, 2),
         pytest.param(1, 4, 8, 1, 128, 4, 4, marks=pytest.mark.slow),
         pytest.param(3, 6, 32, 3, 32, 6, 3, marks=pytest.mark.slow),
         pytest.param(2, 16, 8, 4, 64, 16, 4, marks=pytest.mark.slow),
         pytest.param(1, 8, 128, 2, 128, 2, 2, marks=pytest.mark.slow)])
    def test_shapes(self, rng, b, p, page, hkv, d, hq, g):
        s = p // g
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.float32)
        ids = np.stack([rng.permutation(p // g)[:s] for _ in range(b)])
        if s > 1:
            ids[0, -1] = -1                       # masked strap
        ids = jnp.asarray(ids, jnp.int32)
        o_ref = ref.strap_attend_ref(q, k, v, ids, g)
        o_pl = strap_attend_pallas(q, k, v, ids, g, interpret=True)
        np.testing.assert_allclose(np.array(o_ref), np.array(o_pl),
                                   rtol=3e-5, atol=3e-5)

    @pytest.mark.slow
    def test_bf16(self, rng):
        b, p, page, hkv, d, hq, g = 2, 4, 16, 2, 64, 4, 2
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.bfloat16)
        ids = jnp.asarray([[0, 1], [1, 0]], jnp.int32)
        o_ref = ref.strap_attend_ref(q, k, v, ids, g)
        o_pl = strap_attend_pallas(q, k, v, ids, g, interpret=True)
        np.testing.assert_allclose(np.array(o_ref, np.float32),
                                   np.array(o_pl, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_subset_equals_dense_subset(self, rng):
        """Gated attention over straps S == dense attention over exactly
        those tokens."""
        b, p, page, hkv, d, hq, g = 1, 8, 4, 1, 16, 2, 2
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, p, page, hkv, d)), jnp.float32)
        ids = jnp.asarray([[1, 3]], jnp.int32)
        o = np.array(ref.strap_attend_ref(q, k, v, ids, g))
        # dense oracle over tokens of straps 1,3 (pages 2,3,6,7)
        sel_pages = [2, 3, 6, 7]
        kk = np.array(k)[:, sel_pages].reshape(b, -1, hkv, d)
        vv = np.array(v)[:, sel_pages].reshape(b, -1, hkv, d)
        scale = d ** -0.5
        qq = np.array(q).reshape(b, hkv, hq // hkv, d)
        logits = np.einsum("bhgd,bshd->bhgs", qq, kk) * scale
        w = np.exp(logits - logits.max(-1, keepdims=True))
        w = w / w.sum(-1, keepdims=True)
        oo = np.einsum("bhgs,bshd->bhgd", w, vv).reshape(b, hq, d)
        np.testing.assert_allclose(o, oo, rtol=1e-5, atol=1e-5)
