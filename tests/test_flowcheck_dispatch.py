"""flowcheck dispatch/retrace auditors (`tools/flowcheck`, FC1xx/FC2xx).

Fast tier: the dispatch recorder's patching seam, the chunk-count
contract math, and the seeded FC101/FC105 violations (the FC105 check
pulls only the first finding out of `analyze_bucket`, which needs just
the trace-only pallas jaxpr — no compile).

Slow tier: the acceptance runs — the full entry-point matrix audits
clean (>= 8 configs), the retrace matrix neither forks nor re-traces
the compile cache, and each seeded violation fails the CLI gate naming
the rule.  These compile fresh jitted wrappers per shape bucket and
`audit_retrace` clears the global jit cache, so they stay out of the
budgeted fast tier.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.flowcheck import dispatch, retrace  # noqa: E402


class TestChunkMath:
    def test_chunk_dispatch_counts(self):
        assert dispatch._chunk_dispatches(64, 64) == 1
        assert dispatch._chunk_dispatches(65, 64) == 2
        assert dispatch._chunk_dispatches(128, 64) == 2
        assert dispatch._chunk_dispatches(129, 64) == 3
        assert dispatch._chunk_dispatches(2048, 2048) == 1

    def test_entry_matrix_covers_acceptance(self):
        names = [name for name, _ in dispatch.ENTRY_CONFIGS]
        assert len(names) >= 8                  # acceptance floor
        assert len(set(names)) == len(names)
        retrace_names = [name for name, _ in retrace.matrix()]
        assert len(set(retrace_names)) == len(retrace_names)


def _one_recorded_call():
    """Run the smallest real entry point under the recorder and return
    (recorder, the one EngineCall).  Uses the warm default-backend
    engine, so no fresh compile."""
    from repro.core import dse
    from repro.core.space import DesignSpace
    with dispatch.record_dispatches() as rec:
        dse.sweep(DesignSpace.product(techs=["aos"], layers=(87,)))
    assert len(rec.engine_calls) == 1 and rec.sharded_calls == []
    return rec, rec.engine_calls[0]


class TestRecorder:
    def test_counts_and_restores_the_seam(self):
        from repro.kernels import ops
        orig = ops.row_cycle_fused
        rec, call = _one_recorded_call()
        assert ops.row_cycle_fused is orig      # seam restored on exit
        assert rec.orig_engine is orig
        assert rec.total == 1
        # the bucket key is hashable and shape-complete (6 operands)
        assert len(call.shapes) == 6 and len(call.dtypes) == 6
        assert call.statics[4] in ("auto", "ref", "pallas")
        assert hash(call.key)
        b = call.shapes[0][0]
        from repro.core import transient
        assert b % transient.B_ALIGN == 0       # padding contract

    def test_bucket_name_is_stable(self):
        _, call = _one_recorded_call()
        name = dispatch._bucket_name(call)
        assert name.startswith(f"B{call.shapes[0][0]}x")
        assert f"backend={call.statics[4]}" in name


class TestSeededFast:
    def test_extra_dispatch_yields_fc101(self, monkeypatch):
        """The seeded double-sweep config must produce exactly one FC101
        naming the dispatch counts; bucket analysis is stubbed out so
        the fast tier never compiles."""
        monkeypatch.setattr(dispatch, "analyze_bucket",
                            lambda call, engine_fn=None: iter(()))
        pairs, stats = dispatch.audit_dispatch(
            configs=dispatch.SEEDED_CONFIGS["extra-dispatch"])
        assert [f.rule for f, _ in pairs] == ["FC101"]
        f = pairs[0][0]
        assert f.where == "seeded-extra-dispatch"
        assert "2 fused dispatch(es)" in f.message
        assert "contract says 1" in f.message
        cfg = stats["configs"]["seeded-extra-dispatch"]
        assert cfg == {"expected": 1, "actual": 2, "sharded": 0,
                       "scorer": 0, "pareto": 0}

    def test_double_pallas_engine_yields_fc105(self):
        """FC105 is the FIRST finding `analyze_bucket` yields and needs
        only the trace-only pallas jaxpr, so pulling one item off the
        generator stays compile-free."""
        _, call = _one_recorded_call()
        first = next(dispatch.analyze_bucket(
            call, engine_fn=dispatch.seeded_double_pallas_engine))
        assert first.rule == "FC105"
        assert "2 pallas_call" in first.message

    def test_clean_bucket_has_one_pallas_call(self):
        """Negative twin: the real engine's pallas trace is exactly one
        kernel launch, so the generator's first finding (if any) is not
        FC105.  Only the pallas trace is forced."""
        _, call = _one_recorded_call()
        gen = dispatch.analyze_bucket(call)
        first = next(gen, None)
        assert first is None or first.rule != "FC105"


@pytest.mark.slow
class TestFullAudit:
    def test_dispatch_matrix_clean(self):
        """Acceptance: every entry-point config dispatches exactly its
        contract count and every shape bucket passes FC102-FC105."""
        pairs, stats = dispatch.audit_dispatch()
        assert pairs == [], [f.render() for f, _ in pairs]
        assert len(stats["configs"]) >= 8
        for name, cfg in stats["configs"].items():
            assert cfg["actual"] == cfg["expected"], (name, cfg)
        assert stats["configs"]["sharded-default-mesh"]["sharded"] == 1
        assert stats["buckets_analyzed"]

    def test_retrace_matrix_clean(self):
        pairs, stats = retrace.audit_retrace()
        assert pairs == [], [f.render() for f, _ in pairs]
        assert stats["cache_entries"] <= stats["distinct_buckets"]

    def test_seeded_extra_dispatch_full(self):
        """With real bucket analysis the seeded config still reports
        ONLY FC101 — the bucket itself is healthy."""
        pairs, _ = dispatch.audit_dispatch(
            configs=dispatch.SEEDED_CONFIGS["extra-dispatch"])
        assert [f.rule for f, _ in pairs] == ["FC101"]

    def test_seeded_cache_fork_yields_fc201(self):
        pairs, stats = retrace.audit_retrace(
            configs=retrace.matrix()[:1]
            + retrace.SEEDED_CONFIGS["cache-fork"])
        rules = [f.rule for f, _ in pairs]
        assert "FC201" in rules
        f = next(f for f, _ in pairs if f.rule == "FC201")
        assert f.where == "seeded-bypass-dispatch"
        assert "outside the audited seam" in f.message


def run_cli(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.flowcheck", *args],
        cwd=cwd, env={"PYTHONPATH": str(REPO), "PATH": "/usr/bin:/bin",
                      "HOME": "/tmp", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)


@pytest.mark.slow
class TestCLIGate:
    def test_full_flowcheck_repo_clean(self, tmp_path):
        out = tmp_path / "report.json"
        r = run_cli(["--json", str(out)])
        assert r.returncode == 0, r.stdout + r.stderr
        report = json.loads(out.read_text())
        assert report["findings"] == []
        assert len(report["stats"]["dispatch"]["configs"]) >= 8

    def test_seeded_double_pallas_fails_gate(self):
        r = run_cli(["--only", "dispatch",
                     "--seed-violation", "double-pallas"])
        assert r.returncode == 1, r.stdout + r.stderr
        assert "FC105" in r.stdout

    def test_seeded_cache_fork_fails_gate(self):
        r = run_cli(["--only", "retrace", "--seed-violation", "cache-fork"])
        assert r.returncode == 1, r.stdout + r.stderr
        assert "FC201" in r.stdout
