"""StrapCache semantics: exact == dense, append == bulk, gating reduces
traffic, selector keeps the newest strap."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_arch
from repro.memory.strap_cache import StrapCacheConfig, StrapKVCache
from repro.models import registry as M
from repro.serving.engine import ServeEngine


def dense_attention(q, k, v):
    """(B,Hq,hd) x (B,S,Hkv,hd) oracle."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, d).astype(np.float32)
    logits = np.einsum("bhgd,bshd->bhgs", qg, k.astype(np.float32))
    logits *= d ** -0.5
    w = np.exp(logits - logits.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    o = np.einsum("bhgs,bshd->bhgd", w, v.astype(np.float32))
    return o.reshape(b, hq, d)


class TestStrapKVCache:
    def setup_method(self, _):
        self.rng = np.random.default_rng(0)

    def make(self, b=2, s=64, hkv=2, hd=16, page=8, g=2, top=0):
        cfg = StrapCacheConfig(page_size=page, pages_per_strap=g,
                               top_straps=top)
        sc = StrapKVCache.create(cfg, b, s, hkv, hd, jnp.float32)
        k = self.rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
        v = self.rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
        return sc, jnp.asarray(k), jnp.asarray(v)

    @pytest.mark.slow
    def test_bulk_equals_append(self):
        sc, k, v = self.make(s=32)
        bulk = sc.bulk_load(k, v)
        inc = sc
        for t in range(32):
            inc = inc.append(k[:, t], v[:, t])
        np.testing.assert_allclose(np.array(bulk.k_pages),
                                   np.array(inc.k_pages), atol=1e-6)
        np.testing.assert_allclose(np.array(bulk.strap_key_sum),
                                   np.array(inc.strap_key_sum), atol=1e-4)
        np.testing.assert_array_equal(np.array(bulk.length),
                                      np.array(inc.length))

    def test_exact_attend_matches_dense(self):
        sc, k, v = self.make(s=64)
        sc = sc.bulk_load(k, v)
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        out = sc.attend(q, backend="ref")
        want = dense_attention(np.array(q), np.array(k), np.array(v))
        np.testing.assert_allclose(np.array(out), want, rtol=2e-5, atol=2e-5)

    def test_gated_reduces_traffic(self):
        sc, k, v = self.make(s=256, page=8, g=2, top=4)
        sc = sc.bulk_load(k, v)
        gated, dense = sc.hbm_bytes_per_token()
        assert gated < dense / 3            # 4 straps of 16 selected
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        ids = sc.select_straps(q)
        assert ids.shape == (2, 4)
        assert (np.array(ids) >= 0).all()

    def test_selector_always_keeps_newest(self):
        sc, k, v = self.make(s=256, page=8, g=2, top=2)
        sc = sc.bulk_load(k, v)
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        ids = np.array(sc.select_straps(q))
        newest = (256 // (8 * 2)) - 1
        assert (ids == newest).any(axis=1).all()

    def test_partial_fill_masks_invalid_straps(self):
        sc, k, v = self.make(s=64)
        sc = sc.bulk_load(k[:, :24], v[:, :24])   # 24 tokens = 1.5 straps
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        ids = np.array(sc.select_straps(q))
        valid = ids[ids >= 0]
        assert valid.max() <= 1                  # straps 0 and 1 only

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_partial_fill_attend_matches_dense(self, backend):
        """24 tokens fill strap 1 only halfway: the 8 zero-padding slots
        inside it must be masked out of the softmax (their raw logit is
        q.0 = 0, which otherwise competes with real tokens)."""
        sc, k, v = self.make(s=64)
        sc = sc.bulk_load(k[:, :24], v[:, :24])
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        out = sc.attend(q, backend=backend)
        want = dense_attention(np.array(q), np.array(k[:, :24]),
                               np.array(v[:, :24]))
        np.testing.assert_allclose(np.array(out), want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_padding_garbage_never_attended(self, backend):
        """Poison every slot past `length` with huge values: if token-level
        masking is wrong ANYWHERE (selected-strap padding included), the
        poison dominates the softmax and the output explodes."""
        import dataclasses
        sc, k, v = self.make(s=64)
        sc = sc.bulk_load(k[:, :24], v[:, :24])
        kp = np.array(sc.k_pages)
        vp = np.array(sc.v_pages)
        kp.reshape(2, 64, 2, 16)[:, 24:] = 100.0
        vp.reshape(2, 64, 2, 16)[:, 24:] = 100.0
        sc = dataclasses.replace(sc, k_pages=jnp.asarray(kp),
                                 v_pages=jnp.asarray(vp))
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        out = sc.attend(q, backend=backend)
        want = dense_attention(np.array(q), np.array(k[:, :24]),
                               np.array(v[:, :24]))
        np.testing.assert_allclose(np.array(out), want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_gated_partial_fill_matches_masked_dense(self, backend):
        """Token masking composes with strap-level top-k gating: the gated
        output equals a dense oracle over exactly the selected straps'
        REAL tokens."""
        sc, k, v = self.make(s=256, page=8, g=2, top=4)
        n = 72                                    # 4.5 straps filled
        sc = sc.bulk_load(k[:, :n], v[:, :n])
        q = jnp.asarray(self.rng.normal(size=(2, 4, 16)).astype(np.float32))
        ids = np.array(sc.select_straps(q))
        out = np.array(sc.attend(q, backend=backend))
        st = sc.cfg.strap_tokens
        for b in range(2):
            tok = sorted(t for s in ids[b] if s >= 0
                         for t in range(s * st, (s + 1) * st) if t < n)
            want = dense_attention(np.array(q[b:b + 1]),
                                   np.array(k[b:b + 1, tok]),
                                   np.array(v[b:b + 1, tok]))
            np.testing.assert_allclose(out[b:b + 1], want,
                                       rtol=2e-5, atol=2e-5)


@pytest.mark.slow
class TestServeEngineStrap:
    def test_exact_strap_equals_dense_engine(self):
        cfg = get_arch("qwen2-1.5b-smoke")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                              jnp.int32)
        e1 = ServeEngine(cfg, params, max_tokens=48, cache_backend="dense")
        o1 = e1.generate(prompts, 6)
        e2 = ServeEngine(cfg, params, max_tokens=48, cache_backend="strap",
                         strap_cfg=StrapCacheConfig(page_size=8,
                                                    pages_per_strap=2))
        o2 = e2.generate(prompts, 6)
        np.testing.assert_array_equal(np.array(o1), np.array(o2))

    def test_gated_strap_traffic_reduction_reported(self):
        cfg = get_arch("qwen2-1.5b-smoke")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)),
                              jnp.int32)
        eng = ServeEngine(cfg, params, max_tokens=80, cache_backend="strap",
                          strap_cfg=StrapCacheConfig(page_size=8,
                                                     pages_per_strap=2,
                                                     top_straps=2))
        eng.generate(prompts, 4)
        assert eng.stats.traffic_reduction < 0.75
