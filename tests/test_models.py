"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step, asserting output shapes and finiteness; decode-vs-forward
consistency; SSD chunked-vs-recurrent equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import input_specs
from repro.configs.registry import ARCHS, get_arch
from repro.models import registry as M
from repro.models.ssm import ssd_chunked
from repro.train.optimizer import OptConfig
from repro.train.step import make_train_step

ALL_ARCHS = sorted(ARCHS)

# model smoke compiles are the heaviest CPU tests in the suite: the fast
# tier covers the numerics (SSD equivalences) and leaves every per-arch
# XLA compile to the slow tier
FAST_ARCHS: set = set()
_arch_params = [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
                for a in ALL_ARCHS]


def make_batch(cfg, rng, cell="smoke"):
    specs = input_specs(cfg, cell)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "targets", "token") else 8
            out[k] = jnp.asarray(rng.integers(0, hi, v.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape) * 0.02, v.dtype)
    return out


@pytest.mark.parametrize("arch", _arch_params)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_arch(arch + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    logits, aux = M.forward_train(cfg, params, batch)
    b = batch["tokens"].shape[0]
    s_expected = batch["tokens"].shape[1] + (
        batch["vision_embeds"].shape[1] if "vision_embeds" in batch else 0)
    assert logits.shape == (b, s_expected, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step_fn, opt = make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1))
    opt_state = opt.init(params)
    p2, o2, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters must actually change
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", [
    a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ["deepseek-67b", "qwen2-1.5b", "olmo-1b",
              "phi3.5-moe-42b-a6.6b", "mamba2-780m",
              "zamba2-7b", "pixtral-12b"]])
def test_decode_matches_forward(arch, rng):
    cfg = get_arch(arch + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 64
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)), jnp.int32)
    batch = {"tokens": toks}
    nv = cfg.n_vision_tokens or 0
    if nv:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, nv, cfg.d_model)) * 0.02, jnp.float32)
    full_logits, _ = M.forward_train(cfg, params, batch)
    pre = dict(batch, tokens=toks[:, :T])
    last_logits, cache = M.prefill(cfg, params, pre)
    if "k" in cache:
        def padseq(x):
            if x.ndim == 5 and x.shape[2] == T + nv:
                return jnp.pad(x, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)])
            return x
        cache = {k: padseq(v) for k, v in cache.items()}
    pos = jnp.full((B,), T + nv, jnp.int32)
    dl, _ = M.decode_step(cfg, params, cache, toks[:, T:T + 1], pos)
    ref = np.array(full_logits[:, -1])
    err = np.max(np.abs(np.array(dl) - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 2e-2, err


@pytest.mark.slow
def test_whisper_decode_runs(rng):
    cfg = get_arch("whisper-tiny-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    B, Senc, Tdec = 2, 32, 16
    enc = jnp.asarray(rng.normal(size=(B, Senc, cfg.d_model)) * 0.02,
                      jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Tdec)), jnp.int32)
    last, cache = M.prefill(cfg, params, {"enc_embeds": enc, "tokens": toks})
    assert last.shape == (B, cfg.padded_vocab)
    cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)])
                 if k in ("k", "v") else v) for k, v in cache.items()}
    dl, c2 = M.decode_step(cfg, params, cache,
                           jnp.zeros((B, 1), jnp.int32),
                           jnp.full((B,), Tdec, jnp.int32))
    assert dl.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(dl, np.float32)).all()


class TestSSD:
    def _naive_recurrence(self, x, bmat, cmat, dt, a_neg):
        """Token-by-token reference for SSD."""
        b, l, nh, hp = x.shape
        st = bmat.shape[-1]
        h = np.zeros((b, nh, hp, st), np.float64)
        ys = []
        for t in range(l):
            da = np.exp(dt[:, t] * a_neg[None, :])          # (B, nh)
            dtx = x[:, t] * dt[:, t][..., None]              # (B, nh, hp)
            h = h * da[..., None, None] + np.einsum(
                "bhp,bn->bhpn", dtx, bmat[:, t, 0])
            y = np.einsum("bhpn,bn->bhp", h, cmat[:, t, 0])
            ys.append(y)
        return np.stack(ys, 1), h

    def test_chunked_equals_recurrence(self, rng):
        from repro.configs.registry import get_arch
        cfg = get_arch("mamba2-780m-smoke")
        b, l, nh, hp, st = 2, 64, 4, 8, cfg.ssm_state
        x = rng.normal(size=(b, l, nh, hp)).astype(np.float32)
        bm = rng.normal(size=(b, l, 1, st)).astype(np.float32) * 0.5
        cm = rng.normal(size=(b, l, 1, st)).astype(np.float32) * 0.5
        dt = np.abs(rng.normal(size=(b, l, nh))).astype(np.float32) * 0.1
        a_neg = -np.abs(rng.normal(size=(nh,))).astype(np.float32)
        y, h = ssd_chunked(cfg, *map(jnp.asarray, (x, bm, cm, dt)),
                           jnp.asarray(a_neg))
        y_ref, h_ref = self._naive_recurrence(x, bm, cm, dt, a_neg)
        np.testing.assert_allclose(np.array(y), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.array(h), h_ref, rtol=2e-4, atol=2e-4)

    @pytest.mark.slow
    def test_state_carry_across_calls(self, rng):
        """ssd(x) == ssd(x2 | state from x1) concatenated."""
        from repro.configs.registry import get_arch
        cfg = get_arch("mamba2-780m-smoke")
        b, l, nh, hp, st = 1, 64, 4, 8, cfg.ssm_state
        x = rng.normal(size=(b, l, nh, hp)).astype(np.float32)
        bm = rng.normal(size=(b, l, 1, st)).astype(np.float32) * 0.5
        cm = rng.normal(size=(b, l, 1, st)).astype(np.float32) * 0.5
        dt = np.abs(rng.normal(size=(b, l, nh))).astype(np.float32) * 0.1
        a_neg = jnp.asarray(-np.abs(rng.normal(size=(nh,))).astype(np.float32))
        args = lambda sl: map(jnp.asarray, (x[:, sl], bm[:, sl], cm[:, sl],
                                            dt[:, sl]))
        y_full, h_full = ssd_chunked(cfg, *args(slice(None)), a_neg)
        y1, h1 = ssd_chunked(cfg, *args(slice(0, 32)), a_neg)
        y2, h2 = ssd_chunked(cfg, *args(slice(32, 64)), a_neg, h0=h1)
        np.testing.assert_allclose(np.array(y_full[:, 32:]), np.array(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.array(h_full), np.array(h2),
                                   rtol=2e-4, atol=2e-4)
