"""Paper Fig. 9: (a) stack height vs density, (b) sense margin vs density
with FBE+RH, (c) spec table vs D1b."""

from __future__ import annotations

from .common import emit, timeit


def main():
    from repro.core.report import (fig9a_stack_height,
                                   fig9b_margin_vs_density,
                                   fig9c_spec_table)

    dt, rows_a = timeit(fig9a_stack_height, repeats=2)
    at = [r for r in rows_a if abs(r["density_gb_mm2"] - 2.5) < 0.3]
    emit("fig9a_stack_height", dt * 1e6,
         ";".join(f"{r['tech']}@{r['density_gb_mm2']:.1f}= "
                  f"{r['layers']}L/{r['height_um']:.1f}um" for r in at[:2]))

    dt, rows_b = timeit(fig9b_margin_vs_density, repeats=2)
    print("# tech density(Gb/mm2) layers margin(mV) margin+FBE/RH(mV) func")
    for r in rows_b:
        print(f"# {r['tech']:4s} {r['density_gb_mm2']:6.2f} {r['layers']:4d} "
              f"{r['margin_mv']:7.1f} {r['margin_with_fbe_rh_mv']:7.1f} "
              f"{r['functional']}")
    si26 = [r for r in rows_b if r["tech"] == "si"
            and abs(r["density_gb_mm2"] - 2.5) < 0.3]
    emit("fig9b_margin_vs_density", dt * 1e6,
         f"si_margin_w_disturb@2.5Gb={si26[0]['margin_with_fbe_rh_mv']:.0f}mV"
         if si26 else "n/a")

    dt, spec = timeit(fig9c_spec_table, True, repeats=1, warmup=0)
    r = spec["ratios"]
    emit("fig9c_spec_table", dt * 1e6,
         f"density_x={r['density_x']:.2f};tRC_speedup={r['trc_speedup_aos']:.2f};"
         f"Ewr_red={100 * r['write_energy_reduction']:.0f}%;"
         f"Erd_red={100 * r['read_energy_reduction']:.0f}%")


if __name__ == "__main__":
    main()
