"""StrapCache HBM-traffic reduction sweep (the LM-side analogue of the
paper's C_BL table): decode traffic vs strap selectivity."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import emit, timeit


def main():
    from repro.configs.registry import get_arch
    from repro.memory.strap_cache import StrapCacheConfig, StrapKVCache

    cfg = get_arch("qwen2-1.5b-smoke")
    rng = np.random.default_rng(0)
    b, s, hkv, hd = 2, 1024, cfg.n_kv_heads, cfg.head_dim_
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, cfg.n_heads, hd)), jnp.float32)

    base = None
    for top in (0, 16, 8, 4, 2):
        sc = StrapKVCache.create(StrapCacheConfig(page_size=16,
                                                  pages_per_strap=4,
                                                  top_straps=top),
                                 b, s, hkv, hd, jnp.float32)
        sc = sc.bulk_load(k, v)
        dt, out = timeit(lambda: np.asarray(sc.attend(q, backend="ref")),
                         repeats=2)
        gated, dense = sc.hbm_bytes_per_token()
        if top == 0:
            base = np.asarray(out)
            err = 0.0
        else:
            err = float(np.max(np.abs(np.asarray(out) - base))
                        / (np.abs(base).max() + 1e-9))
        emit(f"strap_cache_top{top or 'ALL'}", dt * 1e6,
             f"traffic={100 * gated / dense:.0f}%;attn_rel_err={err:.3f}")


if __name__ == "__main__":
    main()
