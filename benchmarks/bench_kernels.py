"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle vs jit'd
oracle.  On CPU the jit'd oracle is the fast path; the Pallas numbers
validate correctness/compileability, not speed (interpret mode is a
Python interpreter — TPU is the performance target)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import emit, timeit


def main():
    from repro.kernels import ref
    from repro.kernels.rc_transient import rc_multistep_pallas
    from repro.kernels.strap_gather import strap_attend_pallas

    rng = np.random.default_rng(0)
    b, n, t = 256, 6, 400
    c = jnp.asarray(rng.uniform(1, 5, (b, n)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.05, 0.2, (b, n - 1)), jnp.float32)
    z = jnp.zeros((b, n), jnp.float32)
    v0 = jnp.asarray(rng.uniform(0, 1.1, (b, n)), jnp.float32)
    ramp = jnp.ones((t,), jnp.float32)

    jit_ref = jax.jit(lambda *a: ref.rc_multistep_ref(*a, dt=0.02))
    dt_ref, _ = timeit(lambda: jit_ref(c, g, z, z, v0, ramp).block_until_ready())
    emit("rc_multistep_jit_ref_b256_t400", dt_ref * 1e6,
         f"steps_per_s={b * t / dt_ref:,.0f}")
    dt_pl, _ = timeit(lambda: rc_multistep_pallas(c, g, z, z, v0, ramp, 0.02,
                                                  interpret=True),
                      repeats=1)
    emit("rc_multistep_pallas_interp", dt_pl * 1e6,
         f"vs_ref_x={dt_pl / dt_ref:.1f};target=TPU")

    bq, p, page, hkv, d, hq, gg = 4, 32, 64, 8, 128, 32, 4
    q = jnp.asarray(rng.normal(size=(bq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bq, p, page, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bq, p, page, hkv, d)), jnp.float32)
    ids = jnp.asarray(np.stack([rng.permutation(p // gg)[: p // gg]
                                for _ in range(bq)]), jnp.int32)
    jit_sa = jax.jit(lambda *a: ref.strap_attend_ref(*a, pages_per_strap=gg))
    dt_sa, _ = timeit(lambda: jit_sa(q, k, v, ids).block_until_ready())
    toks = p * page
    emit("strap_attend_jit_ref_2k_ctx", dt_sa * 1e6,
         f"ctx={toks};tok_reads_per_s={bq * toks / dt_sa:,.0f}")
    dt_sap, _ = timeit(lambda: strap_attend_pallas(q, k, v, ids, gg,
                                                   interpret=True), repeats=1)
    emit("strap_attend_pallas_interp", dt_sap * 1e6,
         f"vs_ref_x={dt_sap / dt_sa:.1f};target=TPU")


if __name__ == "__main__":
    main()
