"""Benchmark driver: one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (comment lines start with #).

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig8,...] \
      [--json BENCH_fused_rc.json]

``--json`` additionally writes every bench's machine-readable metrics
(benches that return a dict) plus run metadata to one JSON file — CI runs
``--only fused_rc --json BENCH_fused_rc.json`` on every PR and uploads it
as an artifact, seeding the performance trajectory across PRs.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

from . import (bench_fig3_routing, bench_fig8_transient, bench_fig9_scaling,
               bench_fused_row_cycle, bench_kernels, bench_roofline,
               bench_serve, bench_sharded_sweep, bench_strap_cache,
               bench_table1)

ALL = {
    "table1": bench_table1.main,
    "fig3": bench_fig3_routing.main,
    "fig8": bench_fig8_transient.main,
    "fused_rc": bench_fused_row_cycle.main,
    "sharded_sweep": bench_sharded_sweep.main,
    "serve": bench_serve.main,
    "fig9": bench_fig9_scaling.main,
    "kernels": bench_kernels.main,
    "strap_cache": bench_strap_cache.main,
    "roofline": bench_roofline.main,
}


def _run_meta() -> dict:
    import jax
    return {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write machine-readable metrics of the selected "
                         "benches (those returning a dict) to PATH")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(ALL)
    print("name,us_per_call,derived")
    failures = []
    metrics: dict = {}
    for name in names:
        try:
            out = ALL[name]()
            if isinstance(out, dict):
                metrics[name] = out
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json:
        payload = {"meta": _run_meta(), "benches": metrics,
                   "failed": failures}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
