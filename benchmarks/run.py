"""Benchmark driver: one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (comment lines start with #).

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig8,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_fig3_routing, bench_fig8_transient, bench_fig9_scaling,
               bench_fused_row_cycle, bench_kernels, bench_roofline,
               bench_strap_cache, bench_table1)

ALL = {
    "table1": bench_table1.main,
    "fig3": bench_fig3_routing.main,
    "fig8": bench_fig8_transient.main,
    "fused_rc": bench_fused_row_cycle.main,
    "fig9": bench_fig9_scaling.main,
    "kernels": bench_kernels.main,
    "strap_cache": bench_strap_cache.main,
    "roofline": bench_roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(ALL)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            ALL[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
