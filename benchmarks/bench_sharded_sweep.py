"""Sharded sweep throughput vs device count (forced host devices).

Measures `dse.sweep(space, sharding=mesh)` points/sec on the paper grid
fanned out with Monte-Carlo samples, at several forced-host-platform
device counts.  Each count runs in a subprocess because
`--xla_force_host_platform_device_count` must be set before the first
jax import.  The 1-device run is the baseline; the scaling record
(`best_scaling_vs_1dev`) is what CI tracks in BENCH_sharded_sweep.json.

On shared CPU runners the devices are threads over a few cores, so the
interesting signal is "does sharding beat the sequential chunk loop at
all" (>1x), not linear scaling — real meshes (one accelerator per
device, multi-host) are where the slab-per-device dispatch pays off.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

DEVICE_COUNTS = (1, 2, 4, 8)
MC_SAMPLES = 64

_CHILD = """
import json, time
import jax
from repro.core import dse
from repro.core.space import DesignSpace
from repro.launch.mesh import make_sweep_mesh

space = DesignSpace.paper_grid().with_mc(samples=%d, key=0)
mesh = make_sweep_mesh()
run = lambda: dse.sweep(space, sharding=mesh)
batch = run()                                    # compile
jax.block_until_ready(batch.trc_ns)
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    jax.block_until_ready(run().trc_ns)
    ts.append(time.perf_counter() - t0)
pareto = lambda: jax.block_until_ready(dse.pareto_mask(batch, sharding=mesh))
pareto()                                         # compile
pts = []
for _ in range(3):
    t0 = time.perf_counter()
    pareto()
    pts.append(time.perf_counter() - t0)
print(json.dumps({"ndev": jax.device_count(), "points": len(space),
                  "wall_s": min(ts), "pareto_wall_s": min(pts)}))
"""

# the elastic driver's deterministic recovery cost: one injected host
# drop at slab 1 of 4 recomputes exactly one slab -> 0.25, whatever the
# hardware — a CORRECTNESS-OF-RECOVERY gate (lower is better), not a
# throughput number
_ELASTIC_CHILD = """
import json
import jax
from repro.core.space import DesignSpace
from repro.launch import elastic
from repro.launch.mesh import make_sweep_mesh
from repro.runtime.fault import FailureInjector

space = DesignSpace.paper_grid().with_mc(samples=%d, key=0)
batch, report = elastic.elastic_sweep(
    space, make_sweep_mesh(),
    injector=FailureInjector(schedule={1: "drop:host0"}))
print(json.dumps({"ndev": jax.device_count(),
                  "resume_overhead_frac": report.resume_overhead_frac,
                  "restarts": report.restarts,
                  "device_history": report.device_history}))
"""


def _child_env(ndev: int) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"     # never probe for TPU hardware
    # our forced count goes LAST: with duplicated flags the later one
    # wins, so a pre-existing forced count must not override the bench's
    env["XLA_FLAGS"] = " ".join(
        [env.get("XLA_FLAGS", ""),
         f"--xla_force_host_platform_device_count={ndev}"]).strip()
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if "PYTHONPATH" in env else "")
    return env


def main() -> dict:
    per_device: dict = {}
    for ndev in DEVICE_COUNTS:
        r = subprocess.run([sys.executable, "-c", _CHILD % MC_SAMPLES],
                           capture_output=True, text=True,
                           env=_child_env(ndev), timeout=600)
        if r.returncode != 0:
            raise RuntimeError(f"sharded bench child (ndev={ndev}) failed:\n"
                               f"{r.stderr[-2000:]}")
        rec = json.loads(r.stdout.strip().splitlines()[-1])
        assert rec["ndev"] == ndev, rec
        pts_per_s = rec["points"] / rec["wall_s"]
        rec["points_per_s"] = pts_per_s
        rec["pareto_points_per_s"] = rec["points"] / rec["pareto_wall_s"]
        per_device[str(ndev)] = rec
        emit(f"sharded_sweep_d{ndev}", rec["wall_s"] * 1e6,
             f"points_per_s={pts_per_s:,.0f}")

    base = per_device["1"]["points_per_s"]
    best_ndev = max(per_device, key=lambda k: per_device[k]["points_per_s"])
    scaling = per_device[best_ndev]["points_per_s"] / base
    emit("sharded_sweep_scaling", 0.0,
         f"best={best_ndev}dev;vs_1dev={scaling:.2f}x")

    # the gated pareto throughput is the widest mesh's (the config the
    # sharded dominance engine exists for)
    max_ndev = str(max(DEVICE_COUNTS))
    pareto_pts_per_s = per_device[max_ndev]["pareto_points_per_s"]
    emit(f"sharded_pareto_d{max_ndev}",
         per_device[max_ndev]["pareto_wall_s"] * 1e6,
         f"points_per_s={pareto_pts_per_s:,.0f}")

    r = subprocess.run(
        [sys.executable, "-c", _ELASTIC_CHILD % MC_SAMPLES],
        capture_output=True, text=True,
        env=_child_env(max(DEVICE_COUNTS)), timeout=600)
    if r.returncode != 0:
        raise RuntimeError(f"elastic bench child failed:\n"
                           f"{r.stderr[-2000:]}")
    erec = json.loads(r.stdout.strip().splitlines()[-1])
    emit("elastic_resume_overhead", 0.0,
         f"frac={erec['resume_overhead_frac']:.2f};"
         f"restarts={erec['restarts']}")

    return {
        "mc_samples": MC_SAMPLES,
        "points": per_device["1"]["points"],
        "device_counts": list(DEVICE_COUNTS),
        "per_device": per_device,
        "best_device_count": int(best_ndev),
        "best_scaling_vs_1dev": scaling,
        "sharded_pareto_points_per_s": pareto_pts_per_s,
        "elastic_resume_overhead_frac": erec["resume_overhead_frac"],
        "elastic_restarts": erec["restarts"],
        "elastic_device_history": erec["device_history"],
    }


if __name__ == "__main__":
    main()
