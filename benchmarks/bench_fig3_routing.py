"""Paper Fig. 3(c): quantitative comparison of the four BL routing schemes
(+ D1b reference), including the full transient tRC per scheme."""

from __future__ import annotations

from .common import emit, timeit


def main():
    from repro.core.report import fig3_routing_comparison
    dt, rows = timeit(fig3_routing_comparison, True, repeats=1, warmup=0)
    n = len(rows)
    print("# tech scheme CBL(fF) margin(mV) pitch(um) BLSA(um2) manuf tRC(ns)")
    for r in rows:
        print(f"# {r['tech']:4s} {r['scheme']:9s} {r['cbl_ff']:7.2f} "
              f"{r['margin_mv']:8.1f} {r['hcb_pitch_um']:7.3f} "
              f"{r['blsa_area_um2']:7.3f} {str(r['manufacturable']):5s} "
              f"{r['trc_ns']:6.2f}")
    sel = {r["scheme"]: r for r in rows if r["tech"] == "si"}
    derived = (f"si_sel_strap_cbl={sel['sel_strap']['cbl_ff']:.2f}fF;"
               f"margin={sel['sel_strap']['margin_mv']:.0f}mV;"
               f"pitch={sel['sel_strap']['hcb_pitch_um']:.2f}um")
    emit("fig3_routing_comparison", dt / n * 1e6, derived)


if __name__ == "__main__":
    main()
