"""Paper Table I: the "This Work" column — cell/array/architecture summary
plus the DSE run that selects it."""

from __future__ import annotations

import numpy as np

from .common import emit, timeit


def main():
    from repro.core.dse import best_design, full_sweep
    from repro.core.report import table1_summary

    dt, summary = timeit(table1_summary, repeats=1, warmup=0)
    m = summary["sense_margin_mv"]
    t = summary["trc_ns"]
    emit("table1_summary", dt * 1e6,
         f"{summary['bit_density']};margin_si={m['si']:.0f}mV;"
         f"tRC_si={t['si']:.1f}ns;tRC_d1b={t['d1b']:.1f}ns")

    dt, pts = timeit(full_sweep, np.array([64, 87, 137, 200]), True,
                     repeats=1, warmup=0)
    best = best_design(pts)
    emit("table1_dse_sweep", dt / len(pts) * 1e6,
         f"points={len(pts)};best={best.tech}/{best.scheme}@{best.layers}L;"
         f"feasible={sum(p.feasible for p in pts)}")


if __name__ == "__main__":
    main()
