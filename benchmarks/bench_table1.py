"""Paper Table I: the "This Work" column — cell/array/architecture summary
plus the DSE run that selects it."""

from __future__ import annotations

import numpy as np

from .common import emit, timeit


def main():
    from repro.core.dse import best_design, sweep
    from repro.core.report import table1_summary
    from repro.core.space import DesignSpace

    dt, summary = timeit(table1_summary, repeats=1, warmup=0)
    m = summary["sense_margin_mv"]
    t = summary["trc_ns"]
    emit("table1_summary", dt * 1e6,
         f"{summary['bit_density']};margin_si={m['si']:.0f}mV;"
         f"tRC_si={t['si']:.1f}ns;tRC_d1b={t['d1b']:.1f}ns")

    space = DesignSpace.paper_grid(layer_grid=(64, 87, 137, 200))
    dt, batch = timeit(sweep, space, repeats=1, warmup=0)
    best = best_design(batch)
    feasible = int(np.asarray(batch.feasible & batch.valid).sum())
    emit("table1_dse_sweep", dt / len(batch) * 1e6,
         f"points={len(batch)};best={best.tech}/{best.scheme}@{best.layers}L;"
         f"feasible={feasible}")


if __name__ == "__main__":
    main()
