"""Co-design-service throughput: queries/sec through a warm DSEService.

Drives `serving.dse_service.DSEService` with a mixed pool of distinct
sweep/yield queries, twice:

  cold epoch : every query is a memo miss; the whole epoch is queued
               first and flushed as micro-batch windows, so the number
               is the packed-dispatch serving rate (compile cost is paid
               beforehand by an untimed shape warm-up + `memo_clear`);
  memo epoch : the same queries again — every one answers from the LRU
               memo without touching the engine.

CI gates `queries_per_s` (both epochs / total wall) via
BENCH_serve.json; `cold_queries_per_s` and `memo_queries_per_s` record
the two regimes separately, and the memo/rows stats expose hit rate and
slab occupancy for the trajectory artifact.
"""

from __future__ import annotations

import time

from .common import emit


def _query_pool():
    from repro.core.space import DesignSpace

    return [
        (DesignSpace.product(techs=["aos"], layers=(4, 8, 16, 32)),
         "sweep", None),
        (DesignSpace.product(techs=["si"], layers=(8, 16, 32, 64)),
         "sweep", None),
        (DesignSpace.product(techs=["d1b"]), "sweep", None),
        (DesignSpace.product(techs=["aos"], layers=(8, 16))
         .with_corners(rh_toggles=(1e5, 3e5)), "sweep", None),
        (DesignSpace.paper_targets().with_replica(), "sweep", None),
        (DesignSpace.paper_targets().with_mc(samples=32, key=0),
         "yield", {"margin_mv": 5.0}),
    ]


def _epoch(svc, pool) -> float:
    """Queue the whole pool, flush as micro-batch windows, wait for
    every response; returns wall seconds."""
    t0 = time.perf_counter()
    futures = [svc.submit(space, kind=kind, spec=spec)
               for space, kind, spec in pool]
    svc.flush()
    for f in futures:
        f.result(timeout=0)
    return time.perf_counter() - t0


def main() -> dict:
    from repro.serving.dse_service import DSEService

    pool = _query_pool()
    svc = DSEService(window_ms=0.0)
    svc.warm()
    _epoch(svc, pool)       # untimed: compile every slab shape
    svc.memo_clear()        # results gone, compiled shapes stay cached

    cold_s = _epoch(svc, pool)
    memo_s = _epoch(svc, pool)

    n = len(pool)
    cold_qps = n / cold_s
    memo_qps = n / memo_s
    total_qps = (2 * n) / (cold_s + memo_s)
    stats = svc.stats()
    occupancy = (stats["rows"]["requested"] / stats["rows"]["dispatched"]
                 if stats["rows"]["dispatched"] else 0.0)

    emit("serve_cold", cold_s / n * 1e6, f"queries_per_s={cold_qps:,.1f}")
    emit("serve_memo", memo_s / n * 1e6, f"queries_per_s={memo_qps:,.1f}")
    emit("serve_total", (cold_s + memo_s) / (2 * n) * 1e6,
         f"queries_per_s={total_qps:,.1f};"
         f"hit_rate={stats['memo']['hit_rate']:.2f}")

    return {
        "queries": n,
        "rows_per_epoch": sum(len(space) for space, _, _ in pool),
        "queries_per_s": total_qps,
        "cold_queries_per_s": cold_qps,
        "memo_queries_per_s": memo_qps,
        "memo_hit_rate": stats["memo"]["hit_rate"],
        "dispatches": stats["dispatches"],
        "windows": stats["windows"],
        "slab_occupancy": occupancy,
    }


if __name__ == "__main__":
    main()
