"""Roofline table from the dry-run artifacts (§Roofline of EXPERIMENTS.md
is generated from this)."""

from __future__ import annotations

from .common import emit, timeit


def main():
    from repro.roofline.analyze import interesting_cells, load_all, table

    dt, rows = timeit(load_all, repeats=1, warmup=0)
    if not rows:
        emit("roofline_table", 0.0, "no-dryrun-results")
        return
    print("\n".join("# " + l for l in table(rows).splitlines()))
    picks = interesting_cells(rows)
    emit("roofline_table", dt / max(len(rows), 1) * 1e6,
         f"cells={len(rows)};"
         + ";".join(f"{k}={v.arch}/{v.cell}" for k, v in picks.items() if v))

    # multi-pod collective check: strapped hierarchy on the pod axis
    multi = load_all(mesh="multi")
    if multi:
        cross = sum(r.cross_pod_bytes for r in multi)
        tot = sum(r.coll_bytes_total for r in multi) or 1
        emit("roofline_multi_pod", 0.0,
             f"cells={len(multi)};cross_pod_share={100 * cross / tot:.1f}%")


if __name__ == "__main__":
    main()
