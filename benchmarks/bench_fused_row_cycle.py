"""Fused vs phased row-cycle engine on a DSE-sized design batch.

The fused engine runs all three row-cycle phases in one kernel with
in-kernel crossing detection (O(B) outputs, early exit when every design
point is done); the phased reference materializes three (T, B, N) traces
and scans them for crossings.  Emits both wall-clocks, the speedup, and
the worst-case tRC disagreement in units of the integration step.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import emit, timeit

# DSE scale: the sweeps this engine exists for span thousands of design
# points (tech x scheme x layers); small batches under-utilize the
# vectorized solver and are gated by per-step dispatch overhead.
BATCH = 1024


def main() -> dict:
    from repro.core.calibration import SI
    from repro.core.transient import (DT_NS, simulate_row_cycle,
                                      simulate_row_cycle_phased)

    layers = jnp.asarray(np.linspace(32, 288, BATCH).astype(np.float32))
    run_fused = lambda: jax.block_until_ready(
        simulate_row_cycle(SI, "sel_strap", layers).trc_ns)
    run_phased = lambda: jax.block_until_ready(
        simulate_row_cycle_phased(SI, "sel_strap", layers).trc_ns)

    dt_fused, trc_fused = timeit(run_fused, repeats=3)
    dt_phased, trc_phased = timeit(run_phased, repeats=2)
    err_dt = float(jnp.max(jnp.abs(trc_fused - trc_phased))) / DT_NS

    # replica-closed timing variant: every design point carries an extra
    # replica row through the same fused dispatch (2B kernel rows), so the
    # throughput cost of timing closure is visible in the trajectory.
    run_replica = lambda: jax.block_until_ready(
        simulate_row_cycle(SI, "sel_strap", layers, replica=True).trc_ns)
    dt_replica, _ = timeit(run_replica, repeats=3)

    emit("fused_row_cycle_b%d" % BATCH, dt_fused * 1e6,
         f"designs_per_s={BATCH / dt_fused:,.0f};max_trc_err_dt={err_dt:.2f}")
    emit("phased_row_cycle_b%d" % BATCH, dt_phased * 1e6,
         f"designs_per_s={BATCH / dt_phased:,.0f}")
    emit("fused_vs_phased_speedup", (dt_phased - dt_fused) * 1e6,
         f"speedup={dt_phased / dt_fused:.1f}x")
    emit("fused_replica_row_cycle_b%d" % BATCH, dt_replica * 1e6,
         f"designs_per_s={BATCH / dt_replica:,.0f}")

    # machine-readable record for the CI benchmark trajectory
    # (benchmarks/run.py --json collects these into BENCH_fused_rc.json)
    return {
        "batch": BATCH,
        "fused_wall_s": dt_fused,
        "phased_wall_s": dt_phased,
        "fused_us_per_call": dt_fused * 1e6,
        "designs_per_s": BATCH / dt_fused,
        "speedup_vs_phased": dt_phased / dt_fused,
        "max_trc_err_dt": err_dt,
        "replica_wall_s": dt_replica,
        "replica_designs_per_s": BATCH / dt_replica,
    }


if __name__ == "__main__":
    main()
