"""Paper Fig. 8: full row-cycle transient waveforms (SPICE analogue) +
solver throughput on a DSE-sized batch of design points.

The waveform rows exercise the phased engine (``traces=True`` — the path
that materializes the Fig. 8 (T, B, N) waveforms); the batch-throughput
row uses the default fused trace-free engine the DSE sweeps run on.  See
``bench_fused_row_cycle`` for the head-to-head comparison."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import emit, timeit


def main():
    from repro.core.calibration import AOS, D1B, SI
    from repro.core.transient import simulate_row_cycle

    # waveform fidelity row (single design point each, full traces)
    for tech, scheme in ((SI, "sel_strap"), (AOS, "sel_strap"),
                         (D1B, "direct")):
        L = jnp.asarray([tech.layers_target])
        dt, res = timeit(simulate_row_cycle, tech, scheme, L,
                         traces=True, repeats=2)
        emit(f"fig8_transient_{tech.name}", dt * 1e6,
             f"tRC={float(res.trc_ns[0]):.2f}ns;"
             f"sense={float(res.t_sense_ns[0]):.2f};"
             f"restore={float(res.t_restore_ns[0]):.2f};"
             f"pre={float(res.t_precharge_ns[0]):.2f};engine=phased")

    # batched DSE throughput: 256 design points through the fused engine
    layers = jnp.asarray(np.linspace(32, 288, 256).astype(np.float32))
    dt, res = timeit(simulate_row_cycle, SI, "sel_strap", layers, repeats=2)
    per = dt / 256 * 1e6
    emit("fig8_transient_batch256", per,
         f"designs_per_s={256 / dt:,.0f};engine=fused;dt=0.02ns")


if __name__ == "__main__":
    main()
