"""Compare dry-run artifacts across opt levels: the §Perf iteration viewer.

  PYTHONPATH=src python tools/compare_opt.py arctic-480b train_4k single
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.roofline.analyze import analyze_one  # noqa: E402

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main():
    arch, cell, mesh = sys.argv[1:4]
    base = f"{arch}__{cell}__{mesh}"
    rows = []
    for f in sorted(RESULTS.glob(base + "*.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            continue
        r = analyze_one(d)
        he = d.get("hlo_exact", {})
        rows.append((d.get("opt_level", 0), r, he))
    print(f"{'opt':>4s} {'comp(ms)':>10s} {'mem(ms)':>9s} {'coll(ms)':>10s} "
          f"{'cross-pod B':>12s} {'dominant':>10s} {'useful':>7s} {'MFU':>6s}")
    for lvl, r, he in sorted(rows):
        print(f"{lvl:4d} {1e3 * r.t_compute:10.1f} {1e3 * r.t_memory:9.1f} "
              f"{1e3 * r.t_collective:10.1f} {r.cross_pod_bytes:12.3e} "
              f"{r.dominant:>10s} {r.useful_ratio:7.3f} {r.mfu_bound:6.3f}")
        if he.get("collective_bytes_by_type"):
            parts = ", ".join(f"{k}={v:.2e}" for k, v in
                              sorted(he["collective_bytes_by_type"].items()))
            print(f"     {parts}")


if __name__ == "__main__":
    main()
