"""Benchmark regression gate: compare a fresh `benchmarks/run.py --json`
record against a committed baseline.

    python tools/bench_check.py --current BENCH_fused_rc.json
    python tools/bench_check.py --current BENCH_sharded_sweep.json \
        --baseline benchmarks/baselines/BENCH_sharded_sweep.json \
        --max-regression 0.35

Each benchmark gates on its metrics (`GATED_METRICS`, dotted paths into
the record's `benches` section, each tagged "higher" or "lower" for the
better direction): the gate FAILS when a fresh metric lands more than
`--max-regression` (default 35%) worse than the committed baseline —
loose enough to tolerate shared-runner noise, tight enough to catch a
real hot-path regression.  Metrics missing from either record, or
malformed records, fail loudly — and every unreadable gated metric is
reported in ONE error, not just the first, so a broken record is fixed
in one round trip.

Baselines live in `benchmarks/baselines/` and are committed on purpose:
re-baseline (re-run `benchmarks/run.py --only <name> --json` and commit
the new file) only in a PR that intentionally changes performance, and
say so in the PR description — see ROADMAP.md conventions.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

# bench name (key under the record's "benches") -> {dotted metric path:
# better direction}.  "higher": a throughput, gate fails when the fresh
# value drops too far below baseline.  "lower": a cost (e.g. the elastic
# recovery's recomputed-work fraction), gate fails when it rises too far
# above baseline.
GATED_METRICS = {
    "fused_rc": {"designs_per_s": "higher",
                 "replica_designs_per_s": "higher"},
    "sharded_sweep": {"per_device.1.points_per_s": "higher",
                      "sharded_pareto_points_per_s": "higher",
                      "elastic_resume_overhead_frac": "lower"},
    "serve": {"queries_per_s": "higher"},
}

DEFAULT_MAX_REGRESSION = 0.35
BASELINE_DIR = Path(__file__).resolve().parents[1] / "benchmarks/baselines"


class BenchCheckError(Exception):
    """A malformed record or a metric the gate cannot read."""


def load_record(path) -> dict:
    """Read one `benchmarks/run.py --json` record, failing loudly on
    malformed JSON or a record without a `benches` section."""
    path = Path(path)
    try:
        record = json.loads(path.read_text())
    except OSError as e:
        raise BenchCheckError(f"benchmark record {path} cannot be read: "
                              f"{e}") from None
    except json.JSONDecodeError as e:
        raise BenchCheckError(f"benchmark record {path} is not valid "
                              f"JSON: {e}") from e
    if not isinstance(record, dict) or "benches" not in record:
        raise BenchCheckError(f"benchmark record {path} has no 'benches' "
                              "section — was it written by "
                              "benchmarks/run.py --json?")
    return record


def get_metric(record: dict, bench: str, path: str) -> float:
    """Resolve a dotted metric path inside one bench's metrics dict."""
    node = record["benches"].get(bench)
    if node is None:
        raise BenchCheckError(
            f"bench {bench!r} is missing from the record (found: "
            f"{sorted(record['benches'])})")
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            raise BenchCheckError(
                f"metric {bench}.{path} is missing from the record")
        node = node[part]
    if not isinstance(node, (int, float)) or isinstance(node, bool) \
            or not math.isfinite(node):
        raise BenchCheckError(
            f"metric {bench}.{path} is not a finite number: {node!r}")
    return float(node)


def iter_metrics(record: dict):
    """Yield (dotted_name, value) for every scalar leaf under `benches`."""
    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in sorted(node.items()):
                yield from walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                yield from walk(f"{prefix}[{i}]", v)
        elif isinstance(node, (int, float)) and not isinstance(node, bool):
            yield prefix, node
    yield from walk("", record.get("benches", {}))


def validate_finite(record: dict) -> int:
    """Check every numeric metric in the record is finite; return the
    metric count (raises BenchCheckError on NaN/inf or zero metrics)."""
    metrics = list(iter_metrics(record))
    for name, value in metrics:
        if not math.isfinite(value):
            raise BenchCheckError(f"metric {name} is not finite: {value!r}")
    if not metrics:
        raise BenchCheckError("record contains no numeric metrics")
    return len(metrics)


def check(current: dict, baseline: dict,
          max_regression: float = DEFAULT_MAX_REGRESSION) -> list[dict]:
    """Compare every gated metric present in the BASELINE record against
    the current one.  Returns one result dict per metric; a result with
    `ok=False` is a regression beyond the tolerance.  Unreadable gated
    metrics are collected and raised as ONE aggregated BenchCheckError
    naming every failure, not just the first."""
    results = []
    errors = []
    gated = [(bench, path, direction)
             for bench, paths in GATED_METRICS.items()
             for path, direction in paths.items()
             if bench in baseline["benches"]]
    if not gated:
        raise BenchCheckError(
            "baseline record holds none of the gated benches "
            f"({sorted(GATED_METRICS)}); nothing to compare")
    for bench, path, direction in gated:
        try:
            base = get_metric(baseline, bench, path)
            cur = get_metric(current, bench, path)
        except BenchCheckError as e:
            errors.append(str(e))
            continue
        if direction == "higher":
            if base <= 0.0:
                errors.append(f"baseline metric {bench}.{path} is not "
                              f"positive ({base}); re-baseline it")
                continue
            ratio = cur / base
            ok = ratio >= 1.0 - max_regression
        else:   # "lower": a cost — regression means it ROSE past baseline
            if base < 0.0:
                errors.append(f"baseline metric {bench}.{path} is negative "
                              f"({base}); re-baseline it")
                continue
            if base > 0.0:
                ratio = cur / base
                ok = cur <= base * (1.0 + max_regression)
            else:
                # zero-cost baseline: any nonzero cost is a regression
                ratio = math.inf if cur > 0.0 else 1.0
                ok = cur <= 0.0
        results.append({
            "metric": f"{bench}.{path}",
            "direction": direction,
            "baseline": base,
            "current": cur,
            "ratio": ratio,
            "ok": ok,
        })
    if errors:
        raise BenchCheckError(
            f"{len(errors)} gated metric(s) unreadable/invalid:\n  "
            + "\n  ".join(errors))
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when a fresh benchmark record regresses >35% "
                    "below its committed baseline")
    ap.add_argument("--current", required=True,
                    help="fresh benchmarks/run.py --json record")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline record (default: "
                         "benchmarks/baselines/<basename of --current>)")
    ap.add_argument("--max-regression", type=float,
                    default=DEFAULT_MAX_REGRESSION, metavar="FRAC",
                    help="tolerated fractional throughput drop "
                         "(default %(default)s)")
    args = ap.parse_args(argv)
    baseline_path = (Path(args.baseline) if args.baseline
                     else BASELINE_DIR / Path(args.current).name)
    try:
        current = load_record(args.current)
        if not Path(baseline_path).is_file():
            raise BenchCheckError(
                f"baseline record {baseline_path} is missing — commit one "
                "(re-run benchmarks/run.py --json and add the file under "
                "benchmarks/baselines/, see ROADMAP.md conventions)")
        baseline = load_record(baseline_path)
        validate_finite(current)
        results = check(current, baseline, args.max_regression)
    except BenchCheckError as e:
        print(f"bench_check: ERROR - {e}", file=sys.stderr)
        return 2

    failed = [r for r in results if not r["ok"]]
    for r in results:
        verdict = "OK" if r["ok"] else "REGRESSED"
        arrow = "higher=better" if r["direction"] == "higher" \
            else "lower=better"
        print(f"bench_check: {verdict} {r['metric']}: "
              f"{r['current']:,.4g} vs baseline {r['baseline']:,.4g} "
              f"({r['ratio']:.2f}x, {arrow})")
        improved = (r["ratio"] >= 1.0 + args.max_regression
                    if r["direction"] == "higher"
                    else r["ratio"] <= 1.0 - args.max_regression)
        if improved:
            print(f"bench_check: note - {r['metric']} improved to "
                  f"{r['ratio']:.2f}x of the baseline; consider "
                  "re-baselining (see ROADMAP.md conventions)")
    if failed:
        names = ", ".join(r["metric"] for r in failed)
        print(f"bench_check: FAIL - regression beyond "
              f"{args.max_regression:.0%} tolerance on: {names} "
              f"(re-run locally; if the change is intentional, "
              f"re-baseline per ROADMAP.md)", file=sys.stderr)
        return 1
    print(f"bench_check: OK ({len(results)} metric(s) within "
          f"{args.max_regression:.0%} of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
