"""CLI: python -m tools.flowcheck [--only dispatch,retrace,locks] ...

Exit codes (same contract as tools/repro_lint):
  0  clean (or everything suppressed/baselined)
  1  live findings — the CI gate fails, naming analyzer + rule
  2  usage or internal error (an analyzer crashing must not read as OK)
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import traceback
from pathlib import Path

from .common import apply_baseline, load_baseline

ANALYZERS = ("dispatch", "retrace", "locks")

SEEDS = ("extra-dispatch", "double-pallas", "cache-fork", "lock-write")

_SEEDED_LOCK_SOURCE = '''\
import threading


class SeededService:
    def __init__(self):
        self._lock = threading.Lock()
        self._stats = {}

    def bump(self):
        self._stats["requests"] = self._stats.get("requests", 0) + 1
'''


def _ensure_importable(root: Path) -> None:
    """dispatch/retrace import repro.* (src layout) and tools.*."""
    for p in (str(root), str(root / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def _list_rules() -> int:
    from . import dispatch, locks, retrace
    lock_rules = {
        "FC301": "shared mutable attribute accessed with no lock held",
        "FC302": "lock-order inversion (ABBA deadlock)",
        "FC303": "blocking dispatch while holding a condition variable",
        "FC304": "split-lock protection with no common lock",
    }
    del locks  # rules are stable contract strings, module import is the check
    for rule, desc in sorted({**dispatch.RULES, **retrace.RULES,
                              **lock_rules}.items()):
        print(f"{rule}  {desc}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.flowcheck",
        description="compiled-artifact dispatch/retrace audits + "
                    "lock-discipline analysis (docs/lint.md)")
    parser.add_argument("--only", default=None,
                        help="comma list of analyzers to run "
                             f"(default: all of {','.join(ANALYZERS)})")
    parser.add_argument("--paths", nargs="*", default=None,
                        help="override the locks analyzer's file set")
    parser.add_argument("--json", dest="json_out", default=None,
                        help="write the machine-readable report here")
    parser.add_argument("--baseline", default=None,
                        help="fingerprint baseline (default: "
                             "tools/flowcheck/baseline.json under --root)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the committed baseline")
    parser.add_argument("--update-baseline", action="store_true",
                        help="absorb current findings into the baseline")
    parser.add_argument("--list-rules", action="store_true")
    parser.add_argument("--root", default=".",
                        help="repo root (default: cwd)")
    parser.add_argument("--seed-violation", choices=SEEDS, default=None,
                        help="self-test: inject a known violation and "
                             "prove the gate fails with the rule named")
    args = parser.parse_args(argv)

    if args.list_rules:
        return _list_rules()

    root = Path(args.root).resolve()
    _ensure_importable(root)
    selected = (tuple(s.strip() for s in args.only.split(",") if s.strip())
                if args.only else ANALYZERS)
    bad = [s for s in selected if s not in ANALYZERS]
    if bad:
        print(f"unknown analyzer(s) {bad}; choose from {ANALYZERS}",
              file=sys.stderr)
        return 2

    findings_with_text = []   # (Finding, line_text-or-"")
    stats: dict = {}
    suppressed = 0
    try:
        if "locks" in selected:
            from .locks import LockChecker
            paths = args.paths
            if args.seed_violation == "lock-write":
                tmp = Path(tempfile.mkdtemp(prefix="flowcheck-seed-"))
                seeded = tmp / "seeded_service.py"
                seeded.write_text(_SEEDED_LOCK_SOURCE)
                paths = (paths or []) + [str(seeded)]
            pairs, sup, n_classes = LockChecker(root=root).check_paths(paths)
            findings_with_text.extend(pairs)
            suppressed += sup
            stats["locks"] = {"classes_scanned": n_classes}
        if "dispatch" in selected:
            from . import dispatch as dmod
            configs, engine_fn = None, None
            if args.seed_violation == "extra-dispatch":
                configs = dmod.SEEDED_CONFIGS["extra-dispatch"]
            elif args.seed_violation == "double-pallas":
                configs = dmod.ENTRY_CONFIGS[:1]
                engine_fn = dmod.seeded_double_pallas_engine
            pairs, dstats = dmod.audit_dispatch(configs=configs,
                                                engine_fn=engine_fn)
            findings_with_text.extend(pairs)
            stats["dispatch"] = dstats
        if "retrace" in selected:
            from . import retrace as rmod
            configs = None
            if args.seed_violation == "cache-fork":
                configs = (rmod.matrix()[:1]
                           + rmod.SEEDED_CONFIGS["cache-fork"])
            pairs, rstats = rmod.audit_retrace(configs=configs)
            findings_with_text.extend(pairs)
            stats["retrace"] = rstats
    except Exception:
        traceback.print_exc()
        print("flowcheck: internal error (see traceback above)",
              file=sys.stderr)
        return 2

    baseline_path = (Path(args.baseline) if args.baseline
                     else root / "tools" / "flowcheck" / "baseline.json")
    baseline_fps = [] if args.no_baseline else load_baseline(baseline_path)
    reported, baselined = apply_baseline(findings_with_text, baseline_fps)

    if args.update_baseline:
        payload = {
            "comment": ("grandfathered flowcheck findings (fingerprints); "
                        "see docs/lint.md — intentional keeps belong in "
                        "`# flowcheck: disable=` pragmas, not here"),
            "findings": sorted(fp for fp, _ in reported + baselined),
        }
        baseline_path.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"baseline updated: {len(reported) + len(baselined)} "
              f"fingerprint(s) -> {baseline_path}")

    if args.json_out:
        report = {
            "tool": "flowcheck",
            "analyzers": list(selected),
            "findings": [dict(f.as_dict(), fingerprint=fp)
                         for fp, f in reported],
            "baselined": len(baselined),
            "suppressed": suppressed,
            "stats": stats,
        }
        Path(args.json_out).write_text(json.dumps(report, indent=2) + "\n")

    for _, finding in reported:
        print(finding.render())
    n = len(reported)
    extras = []
    if suppressed:
        extras.append(f"{suppressed} suppressed by pragma")
    if baselined:
        extras.append(f"{len(baselined)} baselined")
    tail = f" ({', '.join(extras)})" if extras else ""
    print(f"flowcheck[{','.join(selected)}]: "
          f"{n} finding(s){tail}")
    if args.update_baseline:
        return 0
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
