"""Shared plumbing for the flowcheck analyzers: findings, pragmas,
baseline, and report assembly.

flowcheck is the second-generation static-analysis suite next to
`tools/repro_lint`: where repro-lint inspects *source text* (AST
heuristics over what the code says), flowcheck verifies the *compiled
artifact* (jaxpr / HLO of every fused dispatch), the *compile cache*
(retrace behavior over the key space) and the *thread interactions*
(lock discipline of the serving fabric).  It reuses repro-lint's
engine conventions — same-line pragmas, a committed fingerprint
baseline, 0/1/2 exit codes, `--json` reports — with its own pragma tag
(`# flowcheck: disable=FC301`) so each tool's pragmas silence only its
own rules.

Finding identity:

- lock-discipline findings anchor to a source line; their fingerprint
  hashes (rule, path, stripped line text) exactly like repro-lint, so
  baselined entries survive line drift but die with the offending code;
- dispatch/retrace findings anchor to an entry-point *config* (there is
  no source line for "the compiled sweep issued two dispatches"); their
  fingerprint hashes (rule, config name, stable detail key).
"""

from __future__ import annotations

import dataclasses
import hashlib
import re

# stdlib-only import: the locks analyzer (and this module) must run in
# the jax-free CI lint job, exactly like tools/repro_lint
from tools.repro_lint.engine import (  # noqa: F401  (re-exported)
    FileContext, iter_py_files, load_baseline, write_baseline)

PRAGMA_RE = re.compile(
    r"#\s*flowcheck:\s*(disable|disable-file)=([A-Z0-9, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    where: str         # file path (locks) or entry-point config name
    line: int          # 1-indexed source line; 0 for config findings
    col: int
    message: str
    key: str = ""      # stable fingerprint detail for config findings

    def fingerprint(self, line_text: str = "") -> str:
        detail = line_text.strip() if self.line else self.key
        raw = f"{self.rule}:{self.where}:{detail}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.where}:{self.line}:{self.col}" if self.line \
            else self.where
        return f"{loc}: {self.rule} {self.message}"


def flow_context(path, rel: str, source: str) -> FileContext:
    """A `FileContext` whose pragmas use the flowcheck tag."""
    return FileContext(path, rel, source, pragma_re=PRAGMA_RE)


def apply_baseline(findings_with_ctx, baseline_fps):
    """Split (finding, line_text) pairs into live vs baselined.

    Mirrors repro-lint's budgeted absorption: each baseline fingerprint
    absorbs at most as many findings as it occurs in the baseline list.
    """
    budget = {}
    for fp in baseline_fps:
        budget[fp] = budget.get(fp, 0) + 1
    reported, baselined = [], []
    for finding, line_text in findings_with_ctx:
        fp = finding.fingerprint(line_text)
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
            baselined.append((fp, finding))
        else:
            reported.append((fp, finding))
    return reported, baselined
