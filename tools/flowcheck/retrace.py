"""FC2xx — compile-cache retrace auditing of the fused engine.

The warm serving engine's economics rest on ONE property: every sweep
configuration that *should* share a compiled executable *does*.  The
padding contract (B_ALIGN multiples up to `b_chunk`) makes nominal,
replica, MC and mixed-width batches collapse onto a handful of shapes —
but nothing in jit enforces it.  Weak-type drift (a Python-scalar
operand giving a `weak_type=True` aval), dtype wobble, or a Python value
baked per-call can silently fork the cache, and every fork is a full
engine re-trace + re-compile on the dispatch path.

The audit enumerates the declared key space — backend (auto/ref) x
b_chunk (64/default) x replica x MC x params width (5/6) — and compares
`ops.row_cycle_fused._cache_size()` against the number of *distinct*
(shapes, dtypes, statics) buckets actually dispatched:

- **FC201** — the cache holds MORE entries than distinct dispatch
  buckets after a config runs: something forked a compiled shape the
  recorder could not distinguish (the recorder's key deliberately
  excludes `weak_type`, so drift shows up as excess entries), or a
  dispatch bypassed the audited seam entirely.
- **FC202** — re-running the whole matrix against a warm cache grows it:
  a per-call retrace (Python object identity in a static arg, per-call
  baked scalars) that the first pass could not see.

Requires jax + repro importable; jax imports are function-local.
"""

from __future__ import annotations

from .common import Finding
from .dispatch import record_dispatches

RULES = {
    "FC201": "compile cache holds more entries than distinct dispatch "
             "buckets (weak-type drift or unaudited dispatch)",
    "FC202": "warm re-run of the config matrix re-traced the engine",
}


def _cfg_sweep(space_fn, **kw):
    def thunk(rec):
        from repro.core import dse
        dse.sweep(space_fn(), **kw)
    return thunk


def _space_targets():
    from repro.core.space import DesignSpace
    return DesignSpace.paper_targets()


def _space_grid():
    from repro.core.space import DesignSpace
    return DesignSpace.paper_grid()


def _thunk_params5(rec):
    """Legacy 5-column params width: its own compiled shape, exactly one."""
    from repro.core import dse, transient
    from repro.kernels import ops
    plan = dse.plan_sweep(_space_targets())
    core = transient._pad_operands(
        plan.operands[:6],
        (-int(plan.operands.c.shape[0])) % transient.B_ALIGN)
    c, g, gc_res, gc_pre, v0, params = [x[:transient.B_ALIGN] for x in core]
    ops.row_cycle_fused(c, g, gc_res, gc_pre, v0, params[:, :5],
                        transient.DT_NS, transient.N_ACT_STEPS,
                        transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                        backend="ref")


def matrix():
    """The declared compile-cache key space, as (name, thunk(rec)) pairs.

    Ordered so shared-shape collapses are exercised: the repeats and the
    replica/MC variants after their nominal twins must NOT add entries
    when the padding contract holds.
    """
    return (
        ("auto-targets", _cfg_sweep(_space_targets)),
        ("auto-targets-repeat", _cfg_sweep(_space_targets)),
        ("ref-targets", _cfg_sweep(_space_targets, backend="ref")),
        ("auto-grid", _cfg_sweep(_space_grid)),
        ("auto-grid-chunk64", _cfg_sweep(_space_grid, b_chunk=64)),
        ("auto-targets-replica",
         _cfg_sweep(lambda: _space_targets().with_replica())),
        ("auto-targets-mc",
         _cfg_sweep(lambda: _space_targets().with_mc(samples=4, key=0))),
        ("auto-targets-replica-mc",
         _cfg_sweep(lambda: _space_targets().with_replica()
                    .with_mc(samples=4, key=0))),
        ("ref-params5-direct", _thunk_params5),
    )


def audit_retrace(configs=None):
    """Run the matrix cold, tracking cache size vs distinct buckets per
    config (FC201); then re-run it warm (FC202).  Returns
    (findings_with_line_text, stats_dict)."""
    import jax

    from repro.kernels import ops

    configs = matrix() if configs is None else tuple(configs)
    jax.clear_caches()
    findings = []
    expected_keys = set()
    per_config = {}
    for name, thunk in configs:
        with record_dispatches() as rec:
            thunk(rec)
        expected_keys.update(call.key for call in rec.engine_calls)
        actual = ops.row_cycle_fused._cache_size()
        per_config[name] = {"cache": actual, "buckets": len(expected_keys)}
        if actual > len(expected_keys):
            findings.append(Finding(
                "FC201", name, 0, 0,
                f"after this config the engine cache holds {actual} "
                f"entries but only {len(expected_keys)} distinct "
                "(shapes, dtypes, statics) buckets were dispatched — "
                "weak-type drift or a dispatch outside the audited seam "
                "forked the compile cache", key="cache-fork"))
            # resync so one fork doesn't cascade into every later config
            while len(expected_keys) < actual:
                expected_keys.add(("resync", len(expected_keys)))

    warm_size = ops.row_cycle_fused._cache_size()
    for name, thunk in configs:
        with record_dispatches() as rec:
            thunk(rec)
        grown = ops.row_cycle_fused._cache_size()
        if grown > warm_size:
            findings.append(Finding(
                "FC202", name, 0, 0,
                f"warm re-run re-traced the engine: cache grew "
                f"{warm_size} -> {grown} on a config already compiled — "
                "a per-call-baked Python value is defeating the jit "
                "cache", key="warm-retrace"))
            warm_size = grown

    stats = {"configs": per_config, "cache_entries": warm_size,
             "distinct_buckets": len(expected_keys)}
    return [(f, "") for f in findings], stats


# ---------------------------------------------------------------------------
# Seeded violation: a dispatch that bypasses the audited seam (FC201)
# ---------------------------------------------------------------------------

def _thunk_seeded_bypass(rec):
    """Calls the UNPATCHED engine directly on a fresh shape, so the cache
    gains an entry the recorder never saw — the audit must flag it."""
    from repro.core import dse, transient
    plan = dse.plan_sweep(_space_targets())
    core = transient._pad_operands(
        plan.operands[:6],
        (-int(plan.operands.c.shape[0])) % transient.B_ALIGN)
    chunk = [x[:transient.B_ALIGN] for x in core]
    rec.orig_engine(*chunk, transient.DT_NS, transient.N_ACT_STEPS,
                    transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                    backend="ref")


SEEDED_CONFIGS = {
    "cache-fork": (("seeded-bypass-dispatch", _thunk_seeded_bypass),),
}
