"""FC1xx — dispatch auditing on the compiled artifact.

The repo's core invariant — every design corner flows through ONE fused
row-cycle evaluation — is enforced here on the *compiled* form, not the
source text.  Each entry-point config in `ENTRY_CONFIGS` executes a real
public code path (`dse.sweep`, `plan_sweep`+`row_cycle_events`+
`finalize_sweep`, `simulate_row_cycle_many`, the sharded `launch/shard`
driver, the micro-batching `DSEService` window, replica and `with_mc`
variants) under a dispatch recorder, then the distinct engine shape
buckets it exercised are traced/compiled and audited:

- **FC101** — the entry point issued a different number of fused engine
  dispatches than its contract declares (a second dispatch sneaking into
  a "one fused evaluation" path, or a fan-out that stopped chunking).
- **FC102** — a host callback / host transfer primitive inside the
  jitted dispatch region (jaxpr callback primitives, HLO infeed/outfeed
  and non-allowlisted custom-calls): silent device<->host sync on every
  sweep.
- **FC103** — silent f64 promotion in the dispatch (jaxpr eqn avals or
  `f64[` in compiled HLO): doubles bandwidth on an engine calibrated in
  f32.
- **FC104** — an oversized folded constant baked into the dispatch
  (closed-jaxpr consts or HLO `constant(...)` instructions above
  `CONST_BYTES_LIMIT`): operand data leaking into the compiled artifact
  makes every distinct value a fresh compile.
- **FC105** — the dispatch group does not lower to exactly ONE
  `pallas_call` when traced with `backend="pallas"` (trace-only, so the
  audit runs on CPU too).

Requires jax + the repro package importable; the CLI adds `src/` to
`sys.path`.  All jax imports are function-local so `--list-rules` and
the stdlib-only locks analyzer never pay them.
"""

from __future__ import annotations

import contextlib
import dataclasses

from .common import Finding

RULES = {
    "FC101": "entry point issued an unexpected number of fused dispatches",
    "FC102": "host callback / host transfer inside the jitted dispatch",
    "FC103": "silent f64 promotion in the fused dispatch",
    "FC104": "oversized folded constant baked into the dispatch",
    "FC105": "dispatch group does not lower to exactly one pallas_call",
}

# one folded constant bigger than this is operand data, not a parameter
CONST_BYTES_LIMIT = 128 * 1024

# jaxpr primitives that call back into Python / transfer to host
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
    "device_put",
})

# custom-call targets XLA:CPU/TPU legitimately emits for the fused engine
# (none today: the engine is pure lax/while lowering; extend deliberately)
CUSTOM_CALL_ALLOWLIST = frozenset()


@dataclasses.dataclass(frozen=True)
class EngineCall:
    """One concrete fused-engine invocation seen by the recorder."""
    shapes: tuple
    dtypes: tuple
    statics: tuple      # (dt, n_act, n_res, n_pre, backend)

    @property
    def key(self) -> tuple:
        return (self.shapes, self.dtypes, self.statics)


class DispatchRecorder:
    """Counts fused-engine and sharded-engine dispatches while patched in."""

    def __init__(self):
        self.engine_calls: list[EngineCall] = []
        self.sharded_calls: list[tuple] = []
        self.scorer_calls: list[tuple] = []   # device-side rollup+score
        self.pareto_calls: list[tuple] = []   # sharded dominance engine
        self.orig_engine = None      # unpatched ops.row_cycle_fused

    @property
    def total(self) -> int:
        return (len(self.engine_calls) + len(self.sharded_calls)
                + len(self.scorer_calls) + len(self.pareto_calls))


@contextlib.contextmanager
def record_dispatches():
    """Patch the two dispatch seams and yield a `DispatchRecorder`.

    Seams: `ops.row_cycle_fused` (every sequential/chunked/serving path
    funnels through this module attribute) and the three lru-cached
    jit(shard_map) engines of `launch.shard` — `_sharded_engine` (fused
    kernel), `_sharded_scorer` (device-side rollup+score) and
    `_sharded_pareto_engine` (distributed dominance) — whose per-call
    wrappers count invocations even when the cached engine is reused.
    Tracer-valued calls — the sharded engine re-entering the patched op
    during its own trace — are not dispatches and are skipped.
    """
    import jax

    from repro.kernels import ops
    from repro.launch import shard

    rec = DispatchRecorder()
    orig = ops.row_cycle_fused
    rec.orig_engine = orig

    def counted(c, g, gc_res, gc_pre, v0, params, dt, n_act, n_res, n_pre,
                backend="auto"):
        if not isinstance(c, jax.core.Tracer):
            arrays = (c, g, gc_res, gc_pre, v0, params)
            rec.engine_calls.append(EngineCall(
                shapes=tuple(tuple(x.shape) for x in arrays),
                dtypes=tuple(str(x.dtype) for x in arrays),
                statics=(float(dt), int(n_act), int(n_res), int(n_pre),
                         str(backend))))
        return orig(c, g, gc_res, gc_pre, v0, params, dt, n_act, n_res,
                    n_pre, backend=backend)

    orig_sharded = shard._sharded_engine
    orig_scorer = shard._sharded_scorer
    orig_pareto = shard._sharded_pareto_engine

    def counted_sharded(mesh, backend, b_chunk):
        inner = orig_sharded(mesh, backend, b_chunk)

        def run(*args):
            rec.sharded_calls.append(
                (tuple(mesh.shape.items()), str(backend), int(b_chunk)))
            return inner(*args)
        return run

    def counted_scorer(mesh):
        inner = orig_scorer(mesh)

        def run(*args):
            rec.scorer_calls.append((tuple(mesh.shape.items()),))
            return inner(*args)
        return run

    def counted_pareto(mesh, block):
        inner = orig_pareto(mesh, block)

        def run(*args):
            rec.pareto_calls.append((tuple(mesh.shape.items()), int(block)))
            return inner(*args)
        return run

    ops.row_cycle_fused = counted
    shard._sharded_engine = counted_sharded
    shard._sharded_scorer = counted_scorer
    shard._sharded_pareto_engine = counted_pareto
    try:
        yield rec
    finally:
        ops.row_cycle_fused = orig
        shard._sharded_engine = orig_sharded
        shard._sharded_scorer = orig_scorer
        shard._sharded_pareto_engine = orig_pareto


# ---------------------------------------------------------------------------
# Entry-point configs: name -> runner(recorder) -> expected dispatch count
# ---------------------------------------------------------------------------

def _chunk_dispatches(n_rows: int, b_chunk: int) -> int:
    """Dispatch count of `_row_cycle_fused_chunked` for an n_rows batch."""
    if n_rows <= b_chunk:
        return 1
    return -(-n_rows // b_chunk)


def _run_sweep_targets(rec):
    from repro.core import dse
    from repro.core.space import DesignSpace
    dse.sweep(DesignSpace.paper_targets())
    return 1


def _run_sweep_paper_grid(rec):
    from repro.core import dse
    from repro.core.space import DesignSpace
    dse.sweep(DesignSpace.paper_grid())
    return 1


def _run_sweep_mc(rec):
    from repro.core import dse
    from repro.core.space import DesignSpace
    dse.sweep(DesignSpace.paper_targets().with_mc(samples=8, key=0))
    return 1


def _run_sweep_replica(rec):
    from repro.core import dse
    from repro.core.space import DesignSpace
    dse.sweep(DesignSpace.paper_targets().with_replica())
    return 1


def _run_sweep_replica_mc(rec):
    from repro.core import dse
    from repro.core.space import DesignSpace
    dse.sweep(DesignSpace.paper_targets().with_replica()
              .with_mc(samples=8, key=0))
    return 1


def _run_sweep_chunked(rec):
    """paper grid through b_chunk=64: the chunk loop must fan out to
    exactly ceil(padded/64) dispatches — no more (double dispatch), no
    fewer (silent chunk merge past the caller's memory bound)."""
    from repro.core import dse
    from repro.core.space import DesignSpace
    space = DesignSpace.paper_grid()
    plan = dse.plan_sweep(space)
    n = int(plan.operands.c.shape[0])
    dse.sweep(space, b_chunk=64)
    padded = -(-n // 64) * 64
    return _chunk_dispatches(padded if n > 64 else n, 64)


def _run_events_seam(rec):
    """The serving seam by hand: plan -> row_cycle_events -> rollup ->
    finalize, exactly one engine dispatch."""
    from repro.core import dse, transient
    from repro.core.space import DesignSpace
    plan = dse.plan_sweep(DesignSpace.paper_targets())
    evt = transient.row_cycle_events(plan.operands)
    res = transient.result_from_events(plan.operands, evt)
    dse.finalize_sweep(plan, res)
    return 1


def _run_many_entries(rec):
    """simulate_row_cycle_many over a 2-entry combo list: one flattened
    batch, one dispatch — never one per combo."""
    import jax.numpy as jnp
    from repro.core import transient
    from repro.core.calibration import TECHS
    tech = next(iter(TECHS.values()))
    layers = jnp.asarray([32.0, 64.0])
    transient.simulate_row_cycle_many(
        [(tech, "sel_strap", layers), (tech, "direct", layers)])
    return 1


def _run_service_window(rec):
    """One DSEService micro-batch window over 3 queries (2 distinct + 1
    coalesced duplicate), all nominal: one packed slab, one dispatch."""
    from repro.core.space import DesignSpace
    from repro.serving.dse_service import DSEService
    svc = DSEService(memo_entries=0)
    s_a = DesignSpace.paper_targets()
    s_b = DesignSpace.paper_grid()
    futs = [svc.submit(s_a), svc.submit(s_b), svc.submit(s_a)]
    svc.flush()
    for f in futs:
        f.result(timeout=60)
    return 1


def _run_service_mixed_replica(rec):
    """A window mixing nominal and replica queries: the packer groups by
    replica mode, so exactly TWO dispatches — one per group."""
    from repro.core.space import DesignSpace
    from repro.serving.dse_service import DSEService
    svc = DSEService(memo_entries=0)
    s_a = DesignSpace.paper_targets()
    futs = [svc.submit(s_a), svc.submit(s_a.with_replica())]
    svc.flush()
    for f in futs:
        f.result(timeout=60)
    return 2


def _run_sharded(rec):
    """Full sharded fabric: one engine dispatch + one device-side scorer
    dispatch for the sweep, then one sharded dominance dispatch for the
    Pareto mask — exactly three, never a host-side fallback."""
    from repro.core import dse
    from repro.core.space import DesignSpace
    from repro.launch.mesh import make_sweep_mesh
    mesh = make_sweep_mesh()
    batch = dse.sweep(DesignSpace.paper_targets(), sharding=mesh)
    dse.pareto_mask(batch, sharding=mesh)
    return 3


def _run_legacy_params5(rec):
    """Direct engine call with the legacy 5-column params layout (no role
    column) — still one dispatch, and its bucket is audited like any
    other."""
    from repro.core import dse, transient
    from repro.core.space import DesignSpace
    from repro.kernels import ops
    plan = dse.plan_sweep(DesignSpace.paper_targets())
    core = transient._pad_operands(
        plan.operands[:6],
        (-int(plan.operands.c.shape[0])) % transient.B_ALIGN)
    c, g, gc_res, gc_pre, v0, params = [x[:transient.B_ALIGN] for x in core]
    ops.row_cycle_fused(c, g, gc_res, gc_pre, v0, params[:, :5],
                        transient.DT_NS, transient.N_ACT_STEPS,
                        transient.N_RESTORE_STEPS, transient.N_PRE_STEPS,
                        backend="ref")
    return 1


ENTRY_CONFIGS = (
    ("sweep-targets", _run_sweep_targets),
    ("sweep-paper-grid", _run_sweep_paper_grid),
    ("sweep-mc", _run_sweep_mc),
    ("sweep-replica", _run_sweep_replica),
    ("sweep-replica-mc", _run_sweep_replica_mc),
    ("sweep-chunked-64", _run_sweep_chunked),
    ("events-seam", _run_events_seam),
    ("many-entries", _run_many_entries),
    ("service-window", _run_service_window),
    ("service-mixed-replica", _run_service_mixed_replica),
    ("sharded-default-mesh", _run_sharded),
    ("legacy-params5", _run_legacy_params5),
)


# ---------------------------------------------------------------------------
# Bucket analysis: jaxpr + compiled-HLO invariants per distinct shape bucket
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    """Every eqn in a jaxpr, recursing into sub-jaxprs in eqn params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            yield from _iter_param_eqns(v)


def _iter_param_eqns(v):
    inner = getattr(v, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        yield from _iter_eqns(inner)
    elif hasattr(v, "eqns"):
        yield from _iter_eqns(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_param_eqns(x)


def _bucket_name(call: EngineCall) -> str:
    b, n = call.shapes[0]
    return (f"B{b}xN{n}/params{call.shapes[5][1]}"
            f"/backend={call.statics[4]}")


def analyze_bucket(call: EngineCall, engine_fn=None):
    """Audit one engine shape bucket: trace (pallas + dispatched backend)
    and compile (dispatched backend) the engine over ShapeDtypeStructs,
    then check FC102-FC105.  Yields `Finding`s."""
    import jax
    import numpy as np

    from repro.kernels import ops
    from repro.roofline import hlo as hlomod

    fn = engine_fn if engine_fn is not None else ops.row_cycle_fused
    where = _bucket_name(call)
    args = [jax.ShapeDtypeStruct(s, d)
            for s, d in zip(call.shapes, call.dtypes)]
    dt, n_act, n_res, n_pre, backend = call.statics

    def traced(bk):
        return jax.make_jaxpr(
            lambda *a: fn(*a, dt, n_act, n_res, n_pre, backend=bk))(*args)

    # FC105: the pallas lowering of this bucket must be ONE kernel launch
    closed_p = traced("pallas")
    n_pallas = sum(1 for eqn in _iter_eqns(closed_p.jaxpr)
                   if eqn.primitive.name == "pallas_call")
    if n_pallas != 1:
        yield Finding(
            "FC105", where, 0, 0,
            f"backend='pallas' trace contains {n_pallas} pallas_call "
            "primitives; the fused engine must lower to exactly ONE "
            "kernel launch per dispatch group", key="pallas-count")

    # FC102/FC103/FC104 on the backend this bucket actually dispatched
    closed = traced(backend)
    prims = {eqn.primitive.name for eqn in _iter_eqns(closed.jaxpr)}
    callbacks = sorted(prims & CALLBACK_PRIMITIVES)
    if callbacks:
        yield Finding(
            "FC102", where, 0, 0,
            f"jaxpr contains host callback/transfer primitive(s) "
            f"{callbacks} inside the jitted dispatch region",
            key="jaxpr-callback")
    f64_eqns = sorted({
        eqn.primitive.name for eqn in _iter_eqns(closed.jaxpr)
        for var in eqn.outvars
        if str(getattr(getattr(var, "aval", None), "dtype", "")) == "float64"
    })
    if f64_eqns:
        yield Finding(
            "FC103", where, 0, 0,
            f"jaxpr eqn(s) {f64_eqns} produce float64 values — silent "
            "f64 promotion in an f32-calibrated engine", key="jaxpr-f64")
    big_consts = [(int(np.asarray(c).nbytes), type(c).__name__)
                  for c in closed.consts
                  if hasattr(c, "shape")
                  and int(np.asarray(c).nbytes) > CONST_BYTES_LIMIT]
    if big_consts:
        yield Finding(
            "FC104", where, 0, 0,
            f"closed jaxpr folds {len(big_consts)} constant(s) over "
            f"{CONST_BYTES_LIMIT} bytes (largest "
            f"{max(b for b, _ in big_consts)}); operand data baked into "
            "the trace recompiles per value", key="jaxpr-const")

    hlo_text = jax.jit(
        lambda *a: fn(*a, dt, n_act, n_res, n_pre, backend=backend)
    ).lower(*args).compile().as_text()
    bad_calls = {t: n for t, n in
                 hlomod.scan_custom_call_targets(hlo_text).items()
                 if t not in CUSTOM_CALL_ALLOWLIST}
    host_ops = hlomod.scan_host_transfer_ops(hlo_text)
    if bad_calls or host_ops:
        yield Finding(
            "FC102", where, 0, 0,
            f"compiled HLO contains host-interaction ops: custom-calls "
            f"{sorted(bad_calls)} / host transfers {sorted(host_ops)}",
            key="hlo-host")
    f64_lines = hlomod.scan_f64_mentions(hlo_text, limit=3)
    if f64_lines:
        yield Finding(
            "FC103", where, 0, 0,
            f"compiled HLO mentions f64 shapes, e.g. {f64_lines[0][:120]}",
            key="hlo-f64")
    big = hlomod.scan_constant_bytes(hlo_text, min_bytes=CONST_BYTES_LIMIT + 1)
    if big:
        yield Finding(
            "FC104", where, 0, 0,
            f"compiled HLO holds {len(big)} constant instruction(s) over "
            f"{CONST_BYTES_LIMIT} bytes (largest {big[0][0]})",
            key="hlo-const")


def audit_dispatch(configs=None, engine_fn=None):
    """Run every entry-point config, then audit the distinct shape
    buckets.  Returns (findings_with_line_text, stats_dict); line text is
    always "" (config findings fingerprint on their stable `key`).

    `configs` / `engine_fn` exist for the seeded-violation self-tests:
    a config may issue an extra dispatch, and `engine_fn` substitutes the
    traced engine (e.g. one that launches two pallas kernels).
    """
    configs = ENTRY_CONFIGS if configs is None else tuple(configs)
    findings = []
    buckets: dict[tuple, EngineCall] = {}
    per_config = {}
    for name, runner in configs:
        with record_dispatches() as rec:
            expected = runner(rec)
        per_config[name] = {"expected": expected, "actual": rec.total,
                            "sharded": len(rec.sharded_calls),
                            "scorer": len(rec.scorer_calls),
                            "pareto": len(rec.pareto_calls)}
        if rec.total != expected:
            findings.append(Finding(
                "FC101", name, 0, 0,
                f"entry point issued {rec.total} fused dispatch(es) "
                f"(engine {len(rec.engine_calls)} + sharded "
                f"{len(rec.sharded_calls)} + scorer "
                f"{len(rec.scorer_calls)} + pareto "
                f"{len(rec.pareto_calls)}), contract says {expected}",
                key="dispatch-count"))
        for call in rec.engine_calls:
            buckets.setdefault(call.key, call)
    for call in buckets.values():
        findings.extend(analyze_bucket(call, engine_fn=engine_fn))
    stats = {
        "configs": per_config,
        "buckets_analyzed": [_bucket_name(c) for c in buckets.values()],
    }
    return [(f, "") for f in findings], stats


# ---------------------------------------------------------------------------
# Seeded violations (self-test / --seed-violation): prove the gate fails
# ---------------------------------------------------------------------------

def _run_seeded_double_dispatch(rec):
    """Dispatches the targets sweep TWICE while declaring one — FC101."""
    from repro.core import dse
    from repro.core.space import DesignSpace
    space = DesignSpace.paper_targets()
    dse.sweep(space)
    dse.sweep(space)
    return 1


def seeded_double_pallas_engine(c, g, gc_res, gc_pre, v0, params, dt,
                                n_act, n_res, n_pre, backend="auto"):
    """An engine whose dispatch group launches TWO kernels — FC105."""
    from repro.kernels import ops
    evt, v_end = ops.row_cycle_fused(c, g, gc_res, gc_pre, v0, params, dt,
                                     n_act, n_res, n_pre, backend=backend)
    evt2, _ = ops.row_cycle_fused(c, g, gc_res, gc_pre, v0, params, dt,
                                  n_act, n_res, n_pre, backend=backend)
    return evt + 0 * evt2, v_end


SEEDED_CONFIGS = {
    "extra-dispatch": (("seeded-extra-dispatch",
                        _run_seeded_double_dispatch),),
}
