"""FC3xx — lock-discipline analysis of the serving/runtime thread fabric.

The serving engine (`serving/dse_service.py`) shares memo / window /
stats state between client threads and the dispatcher thread; the
runtime fault machinery (`runtime/fault.py`) shares liveness maps.  The
AST heuristics in repro-lint cannot see thread interactions, so this
checker *learns* each class's concurrency shape and verifies the
discipline:

1. **Lock inventory** — attributes assigned in `__init__` from
   `threading.Lock()` / `RLock()` / `Condition()`.  Classes with no
   locks are skipped (nothing to be disciplined about).
2. **Shared mutable attributes** — attributes assigned in `__init__`
   that are either initialized to a mutable container (list/dict/set
   literal, `OrderedDict()`, any CapWord class instantiation) or
   re-assigned in a non-`__init__` method.  Plain config scalars
   (`self.window_ms = float(...)`) are immutable after construction and
   exempt.
3. **Lock-context propagation** — each method body is walked with the
   set of held `self.<lock>` locks (`with self._cv:` scoping); private
   methods called only from inside the class inherit the *intersection*
   of their call sites' held sets (fixpoint), so a helper that is only
   ever invoked under `self._dispatch_lock` is analyzed as holding it.
   A method referenced without a call (e.g. `Thread(target=self._run)`)
   is a fresh thread entry and starts with nothing held.

Rules:

- **FC301** — read/write of a shared mutable attribute with no lock
  held.  This is the torn-counter / lost-update class of bug.
- **FC302** — lock-order inversion: the file set acquires lock B while
  holding A *and* A while holding B (ABBA deadlock).
- **FC303** — blocking work while holding a `threading.Condition`:
  a JAX dispatch (`row_cycle_events`, `plan_sweep`, ...) or blocking
  wait (`.result()`, `.join()`) inside a `with self._cv:` block stalls
  every producer/consumer sharing the condition for the duration of a
  fused dispatch.
- **FC304** — split-lock protection: an attribute accessed under lock A
  at some sites and lock B at others, with no common lock — mutual
  exclusion that excludes nothing.

Known limitation (by design, documented in docs/lint.md): aliasing a
shared attribute into a local (`st = self._stats; st.x += 1`) hides the
mutation from the checker — the serving code avoids the idiom so every
shared access is visible as `self.<attr>`.

Stdlib-only: this module must run in the jax-free CI lint job.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from .common import Finding, flow_context, iter_py_files

# Default scan set: the threaded serving engine and the fault runtime.
DEFAULT_PATHS = (
    "src/repro/serving/dse_service.py",
    "src/repro/runtime/fault.py",
)

LOCK_CONSTRUCTORS = ("Lock", "RLock", "Condition", "Semaphore",
                     "BoundedSemaphore")
CONDITION_CONSTRUCTORS = ("Condition",)

MUTABLE_CONSTRUCTORS = ("list", "dict", "set", "bytearray", "deque",
                        "OrderedDict", "defaultdict", "Counter")

# mutating container methods: calling one on a shared attr is a write
MUTATOR_METHODS = frozenset({
    "append", "extend", "insert", "pop", "popitem", "clear", "update",
    "setdefault", "move_to_end", "add", "remove", "discard", "sort",
    "reverse", "appendleft", "popleft",
})

# calls that block or issue a fused JAX dispatch — forbidden while
# holding a Condition (FC303)
BLOCKING_CALLS = frozenset({
    "row_cycle_events", "row_cycle_fused", "row_cycle_fused_sharded",
    "simulate_row_cycle_many", "simulate_row_cycle_lowered",
    "simulate_row_cycle_sharded", "sweep", "plan_sweep", "finalize_sweep",
    "block_until_ready", "result", "join",
})


def _is_self_attr(node) -> str | None:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _call_name(node: ast.Call) -> str | None:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return None


def _is_mutable_init(value: ast.expr) -> bool:
    """Does this `__init__` initializer produce a mutable object?"""
    if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
        return True
    if isinstance(value, ast.Call):
        name = _call_name(value)
        if name in MUTABLE_CONSTRUCTORS:
            return True
        # CapWord call = class instantiation (e.g. ServiceStats()) —
        # instances are presumed mutable; lock constructors are handled
        # separately and excluded by the caller.
        if name and name[0].isupper() and name not in LOCK_CONSTRUCTORS:
            return True
    return False


@dataclass
class Access:
    attr: str
    held: frozenset
    lineno: int
    col: int
    kind: str          # "read" | "write"
    method: str


@dataclass
class ClassModel:
    """Everything learned about one lock-bearing class."""
    name: str
    locks: dict = field(default_factory=dict)       # attr -> ctor name
    shared: set = field(default_factory=set)        # shared mutable attrs
    accesses: list = field(default_factory=list)    # [Access]
    nestings: list = field(default_factory=list)    # [(outer, inner, node)]
    blocking_under_cv: list = field(default_factory=list)  # [(node, name, lock)]


class _MethodWalker:
    """Walk one method body tracking the held-lock set."""

    def __init__(self, model: ClassModel, method: str, entry_held,
                 call_sites):
        self.model = model
        self.method = method
        self.call_sites = call_sites    # name -> [frozenset held]
        self.refs = set()               # methods referenced without call
        self.held0 = frozenset(entry_held)

    def walk(self, body):
        for stmt in body:
            self._stmt(stmt, self.held0)

    # -- statements --------------------------------------------------------
    def _stmt(self, node, held):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = []
            for item in node.items:
                attr = _is_self_attr(item.context_expr)
                if attr in self.model.locks:
                    acquired.append(attr)
                else:
                    self._expr(item.context_expr, held, store=False)
                if item.optional_vars is not None:
                    self._expr(item.optional_vars, held, store=True)
            inner = held
            for lock in acquired:
                for outer in inner:
                    self.model.nestings.append((outer, lock, node))
                inner = inner | {lock}
            for sub in node.body:
                self._stmt(sub, inner)
        elif isinstance(node, (ast.Assign,)):
            self._expr(node.value, held, store=False)
            for t in node.targets:
                self._expr(t, held, store=True)
        elif isinstance(node, ast.AugAssign):
            self._expr(node.value, held, store=False)
            self._expr(node.target, held, store=True)
        elif isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self._expr(node.value, held, store=False)
            self._expr(node.target, held, store=True)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: body runs later, with unknown locks — analyze
            # with nothing held and treat as a reference-only entry
            nested = _MethodWalker(self.model, self.method, frozenset(),
                                   self.call_sites)
            nested.walk(node.body)
            self.refs |= nested.refs
        else:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self._expr(child, held, store=False)
                elif isinstance(child, ast.stmt):
                    self._stmt(child, held)
                elif isinstance(child, (ast.withitem, ast.ExceptHandler,
                                        ast.arguments, ast.keyword)):
                    for sub in ast.iter_child_nodes(child):
                        if isinstance(sub, ast.expr):
                            self._expr(sub, held, store=False)
                        elif isinstance(sub, ast.stmt):
                            self._stmt(sub, held)

    # -- expressions -------------------------------------------------------
    def _expr(self, node, held, store):
        if node is None:
            return
        if isinstance(node, ast.Call):
            name = _call_name(node)
            # self.method(...) call site (not a bare reference, so the
            # func attribute must NOT land in self.refs below)
            callee = _is_self_attr(node.func)
            if callee is not None:
                self.call_sites.setdefault(callee, []).append(held)
            # blocking / dispatch call while holding a Condition (FC303)
            cond_held = [lk for lk in held
                         if self.model.locks.get(lk)
                         in CONDITION_CONSTRUCTORS]
            if name in BLOCKING_CALLS and cond_held:
                target = _is_self_attr(node.func)
                if target not in self.model.locks:
                    self.model.blocking_under_cv.append(
                        (node, name, sorted(cond_held)[0]))
            # mutator method on a shared attr: self._queue.append(x)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in MUTATOR_METHODS):
                owner = _is_self_attr(node.func.value)
                if owner in self.model.shared:
                    self._record(node.func.value, owner, held, "write")
            if callee is None:
                self._expr(node.func, held, store=False)
            for a in node.args:
                self._expr(a, held, store=False)
            for kw in node.keywords:
                self._expr(kw.value, held, store=False)
            return
        if isinstance(node, ast.Attribute):
            attr = _is_self_attr(node)
            if attr is not None:
                if attr in self.model.shared:
                    self._record(node, attr, held,
                                 "write" if store else "read")
                elif attr not in self.model.locks and not store:
                    # possible bare method reference (thread target)
                    self.refs.add(attr)
                self._expr(node.value, held, store=False)
                return
            # store through an attribute/subscript chain writes the base
            self._expr(node.value, held, store=store)
            return
        if isinstance(node, ast.Subscript):
            self._expr(node.value, held, store=store)
            self._expr(node.slice, held, store=False)
            return
        if isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                self._expr(elt, held, store=store)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._expr(child, held, store=False)
            elif isinstance(child, ast.comprehension):
                self._expr(child.iter, held, store=False)
                for cond in child.ifs:
                    self._expr(cond, held, store=False)

    def _record(self, node, attr, held, kind):
        for a in self.model.accesses:
            # one access per site: a mutator call records the write first,
            # then the generic attribute visit would re-record a read
            if (a.attr == attr and a.lineno == node.lineno
                    and a.col == node.col_offset and a.method == self.method):
                return
        self.model.accesses.append(Access(
            attr=attr, held=frozenset(held), lineno=node.lineno,
            col=node.col_offset, kind=kind, method=self.method))


def _build_model(cls: ast.ClassDef) -> ClassModel | None:
    model = ClassModel(name=cls.name)
    methods = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    init = methods.get("__init__")
    if init is None:
        return None
    init_attrs: dict[str, ast.expr] = {}
    for node in ast.walk(init):
        target, value = None, None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            target, value = node.target, node.value
        if target is not None:
            attr = _is_self_attr(target)
            if attr is not None:
                init_attrs[attr] = value
    for attr, value in init_attrs.items():
        if (isinstance(value, ast.Call)
                and _call_name(value) in LOCK_CONSTRUCTORS):
            model.locks[attr] = _call_name(value)
    if not model.locks:
        return None

    reassigned = set()
    for name, fn in methods.items():
        if name == "__init__":
            continue
        for node in ast.walk(fn):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                for sub in ast.walk(t):
                    attr = _is_self_attr(sub)
                    if attr is not None:
                        reassigned.add(attr)
    for attr, value in init_attrs.items():
        if attr in model.locks:
            continue
        if _is_mutable_init(value) or attr in reassigned:
            model.shared.add(attr)

    # -- fixpoint lock-context propagation ---------------------------------
    held: dict[str, frozenset] = {}
    all_locks = frozenset(model.locks)
    for name in methods:
        # public methods (and dunders) are external entry points
        held[name] = (all_locks if name.startswith("_")
                      and not name.startswith("__") else frozenset())
    for _ in range(len(methods) + 2):
        call_sites: dict[str, list] = {}
        refs: set[str] = set()
        for name, fn in methods.items():
            if name == "__init__":
                continue
            walker = _MethodWalker(ClassModel(name=model.name,
                                              locks=model.locks,
                                              shared=model.shared),
                                   name, held[name], call_sites)
            walker.walk(fn.body)
            refs |= walker.refs
        new_held = dict(held)
        for name in methods:
            if name == "__init__":
                continue
            if not name.startswith("_") or name.startswith("__"):
                new_held[name] = frozenset()
                continue
            sites = call_sites.get(name, [])
            entry = frozenset() if name in refs else None
            if sites:
                common = frozenset.intersection(*map(frozenset, sites))
                entry = common if entry is None else entry & common
            if entry is None:
                entry = frozenset()   # never called, never referenced
            new_held[name] = entry
        if new_held == held:
            break
        held = new_held

    # -- final walk collecting accesses/nestings/blocking ------------------
    call_sites = {}
    for name, fn in methods.items():
        if name == "__init__":
            continue
        walker = _MethodWalker(model, name, held[name], call_sites)
        walker.walk(fn.body)
    return model


class LockChecker:
    """Run the FC3xx analysis over a set of files."""

    def __init__(self, root: Path | None = None):
        self.root = Path(root) if root else Path.cwd()

    def _relpath(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def check_paths(self, paths=None):
        """Returns (findings_with_line_text, suppressed, classes_checked)."""
        paths = paths if paths is not None else [
            self.root / p for p in DEFAULT_PATHS]
        out, suppressed, n_classes = [], 0, 0
        for f in iter_py_files(paths):
            ctx = flow_context(f, self._relpath(f), f.read_text())
            for finding in self._check_file(ctx):
                if ctx.suppressed(finding):
                    suppressed += 1
                    continue
                out.append((finding, ctx.line_text(finding.line)))
            n_classes += sum(
                1 for node in ast.walk(ctx.tree)
                if isinstance(node, ast.ClassDef))
        return out, suppressed, n_classes

    def _check_file(self, ctx):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            model = _build_model(node)
            if model is None:
                continue
            yield from self._verdicts(ctx, model)

    def _verdicts(self, ctx, model: ClassModel):
        def finding(rule, anchor, message):
            return Finding(rule=rule, where=ctx.rel,
                           line=anchor.lineno,
                           col=getattr(anchor, "col_offset", 0),
                           message=f"[{model.name}] {message}")

        by_attr: dict[str, list] = {}
        for acc in model.accesses:
            by_attr.setdefault(acc.attr, []).append(acc)

        for attr, accesses in sorted(by_attr.items()):
            bare = [a for a in accesses if not a.held]
            if bare:
                for a in bare:
                    yield Finding(
                        "FC301", ctx.rel, a.lineno, a.col,
                        f"[{model.name}] {a.kind} of shared mutable "
                        f"attribute self.{attr} in {a.method}() with no "
                        "lock held; every cross-thread access must hold "
                        "the attribute's lock")
                continue
            common = frozenset.intersection(
                *(a.held for a in accesses))
            if not common and len(accesses) > 1:
                locks_seen = sorted({lk for a in accesses for lk in a.held})
                counts: dict[str, int] = {}
                for a in accesses:
                    for lk in a.held:
                        counts[lk] = counts.get(lk, 0) + 1
                dominant = max(sorted(counts), key=lambda lk: counts[lk])
                for a in accesses:
                    if dominant not in a.held:
                        yield Finding(
                            "FC304", ctx.rel, a.lineno, a.col,
                            f"[{model.name}] self.{attr} is protected by "
                            f"{sorted(a.held)} here but by "
                            f"['{dominant}'] elsewhere (locks seen: "
                            f"{locks_seen}); split-lock protection "
                            "excludes nothing")

        pairs = {(o, i) for o, i, _ in model.nestings}
        for outer, inner, node in model.nestings:
            if (inner, outer) in pairs:
                yield finding(
                    "FC302", node,
                    f"acquires self.{inner} while holding self.{outer}, "
                    f"but the reverse nesting also exists in this class "
                    "— ABBA deadlock")

        for node, name, lock in model.blocking_under_cv:
            yield finding(
                "FC303", node,
                f"blocking call {name}() while holding the condition "
                f"variable self.{lock}; a fused dispatch or blocking "
                "wait under the CV stalls every thread sharing it — "
                "dispatch outside the lock")


def run(paths=None, root=None):
    """Module-level entry used by `tools.flowcheck.__main__`."""
    checker = LockChecker(root=root)
    return checker.check_paths(paths)
