"""flowcheck: compiled-artifact and concurrency invariants for the fused
sweep and serving fabric.

Three analyzers (see each module's docstring for the rule catalogue):

- ``dispatch`` (FC1xx) — jaxpr/HLO audit of every public fused entry
  point over a declared shape-bucket matrix,
- ``retrace``  (FC2xx) — compile-cache behavior over the key space,
- ``locks``    (FC3xx) — stdlib-only lock-discipline AST analysis of the
  threaded serving/runtime classes.

CLI: ``python -m tools.flowcheck`` (see ``--help``); conventions —
pragmas ``# flowcheck: disable=FCxxx``, committed fingerprint baseline,
exit codes 0 (clean) / 1 (findings) / 2 (usage or internal error) —
mirror ``tools/repro_lint`` (workflow: docs/lint.md).
"""

from .common import Finding, apply_baseline, flow_context  # noqa: F401
