"""Rule engine: file walking, pragmas, baseline, and reporting.

Stdlib-only on purpose — the CI lint job installs nothing but ruff, and
this module must import (and run) there.

Suppression workflow (see docs/lint.md):

- same-line pragma, for findings that are INTENTIONAL and justified:
      x = legacy_loop()   # repro-lint: disable=RL002  (deprecated view)
- file-level pragma (any line), for files a rule cannot apply to:
      # repro-lint: disable-file=RL001
- committed baseline (`tools/repro_lint/baseline.json`), ONLY for
  grandfathered findings awaiting a real fix — never for intentional
  keeps.  Fingerprints hash (rule, path, stripped source line), so
  baselined findings survive line drift but die with the offending code.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path

PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)=([A-Z0-9, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # scan-root-relative posix path
    line: int          # 1-indexed
    col: int           # 0-indexed
    message: str

    def fingerprint(self, line_text: str) -> str:
        key = f"{self.rule}:{self.path}:{line_text.strip()}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """Everything a rule needs about one parsed source file.

    `pragma_re` defaults to the repro-lint pragma tag; sibling analyzers
    (tools/flowcheck) reuse this context with their own tag so each
    tool's pragmas only silence its own rules.
    """

    def __init__(self, path: Path, rel: str, source: str,
                 pragma_re=PRAGMA_RE):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.line_pragmas: dict[int, set] = {}
        self.file_pragmas: set = set()
        for i, line in enumerate(self.lines, start=1):
            m = pragma_re.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
            if m.group(1) == "disable-file":
                self.file_pragmas |= rules
            else:
                self.line_pragmas.setdefault(i, set()).update(rules)

    def suppressed(self, finding: Finding) -> bool:
        if finding.rule in self.file_pragmas:
            return True
        return finding.rule in self.line_pragmas.get(finding.line, set())

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def iter_py_files(paths) -> list[Path]:
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


def load_baseline(path) -> list[str]:
    """Read the committed baseline: a list of finding fingerprints."""
    path = Path(path)
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("findings", []))


def write_baseline(path, findings_with_fp) -> None:
    payload = {
        "comment": ("grandfathered repro-lint findings (fingerprints of "
                    "rule:path:line-text); see docs/lint.md — intentional "
                    "keeps belong in pragmas, not here"),
        "findings": sorted(fp for fp, _ in findings_with_fp),
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


class LintEngine:
    def __init__(self, rules, root: Path | None = None):
        self.rules = list(rules)
        self.root = Path(root) if root else Path.cwd()

    def _relpath(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def run(self, paths, baseline_fps=()):
        """Lint `paths`; returns (reported, suppressed_count, baselined).

        `reported` is the list of live findings; findings matching a
        same-line/file pragma or a baseline fingerprint are counted but
        not reported.  Each baseline fingerprint absorbs at most as many
        findings as it occurs in the baseline list.
        """
        files = iter_py_files(paths)
        contexts = []
        for f in files:
            source = f.read_text()
            contexts.append(FileContext(f, self._relpath(f), source))

        # project-wide pre-pass (RL005's call graph wants every module)
        project = {ctx.rel: ctx for ctx in contexts}
        for rule in self.rules:
            prepare = getattr(rule, "prepare", None)
            if prepare:
                prepare(project)

        reported, suppressed, baselined = [], 0, []
        budget = {}
        for fp in baseline_fps:
            budget[fp] = budget.get(fp, 0) + 1
        for ctx in contexts:
            for rule in self.rules:
                if not rule.applies_to(ctx.rel):
                    continue
                for finding in rule.check(ctx):
                    if ctx.suppressed(finding):
                        suppressed += 1
                        continue
                    fp = finding.fingerprint(ctx.line_text(finding.line))
                    if budget.get(fp, 0) > 0:
                        budget[fp] -= 1
                        baselined.append((fp, finding))
                        continue
                    reported.append((fp, finding))
        return reported, suppressed, baselined
