"""repro-lint: AST-enforced array-native invariants for this repo.

A purpose-built static-analysis pass (stdlib-only — the CI lint job has
no jax) encoding the ROADMAP conventions that previously lived as prose:
registry-driven techs/schemes, ONE fused dispatch, never-fake-zeros NaN
semantics, reserved `mc_*` corner channels, tracer hygiene on the jitted
fused path, and B_ALIGN/even-pair batch boundaries.

    python -m tools.repro_lint src tests benchmarks examples

See docs/lint.md for every rule, the pragma + baseline workflow, and the
companion runtime layer (`src/repro/core/contracts.py`).
"""

from .engine import Finding, LintEngine, load_baseline  # noqa: F401
from .rules import ALL_RULES  # noqa: F401
