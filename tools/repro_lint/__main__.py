"""CLI: `python -m tools.repro_lint [paths...]`.

Exit codes: 0 clean, 1 live findings, 2 bad invocation / unparseable
input.  `--json` writes the machine-readable report CI uploads as an
artifact.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import LintEngine, load_baseline, write_baseline
from .rules import ALL_RULES

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST-enforced array-native invariants (docs/lint.md)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/directories to lint (default: %(default)s)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write a JSON report ('-' for stdout)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="grandfathered-findings file (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings too")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to absorb all live findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = [cls() for cls in ALL_RULES]
    if args.list_rules:
        for r in rules:
            print(f"{r.rule_id}  {r.description}")
        return 0

    baseline_fps = [] if args.no_baseline else load_baseline(args.baseline)
    engine = LintEngine(rules)
    try:
        reported, suppressed, baselined = engine.run(args.paths, baseline_fps)
    except SyntaxError as e:
        print(f"repro_lint: cannot parse {e.filename}:{e.lineno}: {e.msg}",
              file=sys.stderr)
        return 2

    if args.update_baseline:
        write_baseline(args.baseline, reported)
        print(f"repro_lint: baselined {len(reported)} finding(s) into "
              f"{args.baseline}")
        return 0

    for _, f in reported:
        print(f"{f.path}:{f.line}:{f.col}: {f.rule} {f.message}")

    if args.json:
        report = {
            "findings": [dict(f.as_dict(), fingerprint=fp)
                         for fp, f in reported],
            "baselined": [dict(f.as_dict(), fingerprint=fp)
                          for fp, f in baselined],
            "suppressed_by_pragma": suppressed,
            "rules": {r.rule_id: r.description for r in rules},
        }
        text = json.dumps(report, indent=2)
        if args.json == "-":
            print(text)
        else:
            Path(args.json).write_text(text + "\n")

    tail = (f"{len(reported)} finding(s), {len(baselined)} baselined, "
            f"{suppressed} pragma-suppressed")
    if reported:
        print(f"repro_lint: FAIL - {tail}", file=sys.stderr)
        return 1
    print(f"repro_lint: OK - {tail}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
