"""The RL rules.  Each encodes a shipped bug class or ROADMAP convention;
docs/lint.md carries the full story per rule.

Every rule is an `ast`-visitor-style checker with:
  - `rule_id` / `description`
  - `applies_to(rel)`: scan-root-relative posix path scope
  - `check(ctx)`: yield `Finding`s for one `FileContext`
  - optional `prepare(project)`: project-wide pre-pass (RL005)

The registry data rules match against (tech/scheme names, batch field
names, alignment constants) is HARDCODED here rather than imported from
`src/repro` — the CI lint job has no jax, so this package must never
import the model code.  Keep the lists in sync with
`core/calibration.py` / `core/routing.py` / `core/batch.py` /
`core/transient.py`; the unit tests cross-check them.
"""

from __future__ import annotations

import ast

from .engine import Finding

# --- mirrored repo registry data (see module docstring) -------------------
REGISTERED_TECHS = ("si", "aos", "d1b")
REGISTERED_SCHEMES = ("direct", "strap", "core_mux", "sel_strap")
REGISTERED_NAMES = frozenset(REGISTERED_TECHS + REGISTERED_SCHEMES)

# DesignBatch.ARRAY_FIELDS + the FusedOperands fields: iterating any of
# these with a Python loop in core/kernels is a per-sample loop.
BATCH_AXIS_ATTRS = frozenset({
    "tech_idx", "scheme_idx", "layers",
    "density_gb_mm2", "height_um", "cbl_ff",
    "margin_mv", "margin_disturbed_mv",
    "trc_ns", "t_sense_ns", "t_fire_ns", "margin_fire_mv",
    "e_write_fj", "e_read_fj",
    "hcb_pitch_um", "blsa_area_um2",
    "manufacturable", "feasible", "valid",
    # FusedOperands
    "c", "g", "gc_res", "gc_pre", "v0", "params",
    "sa_tau_ns", "t_overhead_ns",
})

# identifiers whose NaN means "no estimate / never crossed" — the
# never-fake-zeros fields (PR-4 fake 0.0 yield, PR-6 clamped crossings)
PROTECTED_TOKENS = ("trc", "margin", "yield", "t_sense", "t_fire",
                    "t_dev", "ppm", "fail_ppm")

MC_RESERVED_PREFIX = "mc_"
B_ALIGN = 64


def _under(rel: str, *prefixes: str) -> bool:
    return any(rel.startswith(p) for p in prefixes)


def _identifiers(node) -> set:
    """Every Name id and Attribute attr in a subtree, lowercased."""
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id.lower())
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr.lower())
    return out


def _mentions_protected(node) -> bool:
    idents = _identifiers(node)
    return any(tok in ident for ident in idents for tok in PROTECTED_TOKENS)


def _is_zero(node) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool)
            and node.value == 0)


def _call_attr(node):
    """'attr' for f(...) spelled x.attr(...) or attr(...), else None."""
    if not isinstance(node, ast.Call):
        return None
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return None


class RuleBase:
    rule_id = "RL000"
    description = ""

    def applies_to(self, rel: str) -> bool:
        raise NotImplementedError

    def check(self, ctx):
        raise NotImplementedError

    def finding(self, ctx, node, message) -> Finding:
        return Finding(self.rule_id, ctx.rel, node.lineno,
                       getattr(node, "col_offset", 0), message)


class RL001NameSpecialCase(RuleBase):
    """No string comparison against registered tech/scheme names outside
    the registries — capability flags, not `name == "d1b"` branches."""

    rule_id = "RL001"
    description = ("string comparison against a registered tech/scheme "
                   "name outside the registries")
    EXEMPT = ("src/repro/core/calibration.py", "src/repro/core/routing.py")

    def applies_to(self, rel):
        return _under(rel, "src/repro/") and rel not in self.EXEMPT

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            for op, cmp in zip(node.ops, node.comparators):
                hit = None
                if isinstance(op, (ast.Eq, ast.NotEq)):
                    for side in (node.left, cmp):
                        if (isinstance(side, ast.Constant)
                                and side.value in REGISTERED_NAMES):
                            hit = side.value
                elif isinstance(op, (ast.In, ast.NotIn)) and isinstance(
                        cmp, (ast.Tuple, ast.List, ast.Set)):
                    for elt in cmp.elts:
                        if (isinstance(elt, ast.Constant)
                                and elt.value in REGISTERED_NAMES):
                            hit = elt.value
                if hit is not None:
                    yield self.finding(
                        ctx, node,
                        f"comparison against registered name {hit!r}; "
                        "branch on a registry capability flag "
                        "(TechCal/SchemeSpec field) instead of the name")


class RL002BatchPythonLoop(RuleBase):
    """No Python for/while loop iterating a batch-axis array in core/ or
    kernels/ — per-sample work must be one fused dispatch / lax.map."""

    rule_id = "RL002"
    description = "Python loop over a batch-axis array in core/kernels"

    def applies_to(self, rel):
        return _under(rel, "src/repro/core/", "src/repro/kernels/")

    def _iter_exprs(self, tree):
        # tuple(float(x) for x in np.asarray(cfg).reshape(-1)) is the
        # repo's config-normalization idiom (PRNG entropy, layer grids,
        # corner value lists) — tiny host-side tuples, not batch loops.
        tuple_genexps = {
            id(arg)
            for node in ast.walk(tree)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name) and node.func.id == "tuple"
            for arg in node.args if isinstance(arg, ast.GeneratorExp)
        }
        for node in ast.walk(tree):
            if isinstance(node, ast.For):
                yield node, node.iter
            elif isinstance(node, ast.While):
                yield node, node.test
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                if id(node) in tuple_genexps:
                    continue
                for gen in node.generators:
                    yield node, gen.iter

    def _trigger(self, iter_expr):
        for sub in ast.walk(iter_expr):
            # iterating a DesignBatch / FusedOperands / LoweredSpace
            # batch-axis field (x.margin_mv, self.tech_idx, ops.params)
            if isinstance(sub, ast.Attribute) and sub.attr in BATCH_AXIS_ATTRS:
                return f"batch-axis field .{sub.attr}"
            # iterating a corner channel's (B,) values
            if (isinstance(sub, ast.Subscript)
                    and isinstance(sub.value, ast.Attribute)
                    and sub.value.attr == "corners"):
                return "a corners[...] channel"
            # materializing an array just to loop it
            if _call_attr(sub) in ("flatnonzero", "asarray"):
                return f"a {_call_attr(sub)}(...) materialization"
        return None

    def check(self, ctx):
        for node, iter_expr in self._iter_exprs(ctx.tree):
            why = self._trigger(iter_expr)
            if why:
                yield self.finding(
                    ctx, node,
                    f"Python loop iterates {why}; per-sample work must "
                    "stay ONE fused dispatch (vectorize or lax.map)")


class RL003FakeZeros(RuleBase):
    """Never replace NaN with 0 on tRC/margin/yield-class fields: NaN
    means 'no estimate / never crossed', 0 is a great-looking lie."""

    rule_id = "RL003"
    description = "NaN squashed to zero on a protected metric field"

    def applies_to(self, rel):
        return _under(rel, "src/repro/", "benchmarks/", "examples/")

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            attr = _call_attr(node)
            if attr == "nan_to_num" and any(
                    _mentions_protected(a) for a in node.args):
                yield self.finding(
                    ctx, node,
                    "nan_to_num on a protected metric fakes a 0.0 for "
                    "'no estimate'; keep the NaN (mask or gate instead)")
            elif attr == "where" and len(node.args) == 3:
                cond, if_true, if_false = node.args
                cond_attr = _call_attr(cond)
                if (cond_attr == "isnan" and _is_zero(if_true)
                        and _mentions_protected(cond)):
                    yield self.finding(
                        ctx, node,
                        "where(isnan(x), 0, ...) on a protected metric "
                        "fakes a 0.0; keep the NaN")
                elif (cond_attr == "isfinite" and _is_zero(if_false)
                        and _mentions_protected(cond)):
                    yield self.finding(
                        ctx, node,
                        "where(isfinite(x), ..., 0) on a protected metric "
                        "fakes a 0.0; keep the NaN")


class RL004ReservedMCChannel(RuleBase):
    """Writes to reserved `mc_*` corner channels happen ONLY in
    core/space.py (the MC lowering owns them)."""

    rule_id = "RL004"
    description = "write to a reserved mc_* corner channel outside space.py"
    OWNER = "src/repro/core/space.py"

    def applies_to(self, rel):
        return _under(rel, "src/repro/") and rel != self.OWNER

    def _is_reserved_key(self, node) -> bool:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value.startswith(MC_RESERVED_PREFIX)
        # corners[MC_LOG_W] = ... / corners[space.MC_LOG_W] = ...
        if isinstance(node, ast.Name):
            return node.id.startswith("MC_")
        if isinstance(node, ast.Attribute):
            return node.attr.startswith("MC_")
        return False

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Dict):
                for key in node.keys:
                    if key is not None and self._is_reserved_key(key):
                        yield self.finding(
                            ctx, node,
                            "dict literal creates a reserved mc_* corner "
                            "channel; only core/space.py's MC lowering "
                            "may write these")
                continue
            for t in targets:
                if isinstance(t, ast.Subscript) and self._is_reserved_key(
                        t.slice):
                    yield self.finding(
                        ctx, node,
                        "subscript write to a reserved mc_* corner "
                        "channel; only core/space.py's MC lowering may "
                        "write these")


class RL005TracerLeak(RuleBase):
    """Tracer hygiene on the jitted fused path: no float()/.item()/
    np.asarray/if-on-jnp inside functions the fused dispatch traces.

    Two-phase: (a) name-level call graph over src/repro, reachability
    from the fused-path entry points; (b) of those, functions that are
    jit/pallas roots (decorator or body) and everything THEY reach form
    the traced set, whose bodies get the tracer-hazard checks.
    """

    rule_id = "RL005"
    description = "host-side op on a traced value inside the fused path"
    ROOTS = ("simulate_row_cycle_many", "simulate_row_cycle_sharded")
    NP_ALIASES = ("np", "numpy", "onp")
    NP_BANNED = ("asarray", "array", "where", "isnan", "isfinite",
                 "sum", "mean", "min", "max", "nonzero", "flatnonzero")

    def __init__(self):
        self.traced_names = frozenset()

    def applies_to(self, rel):
        return _under(rel, "src/repro/")

    # -- project pre-pass ---------------------------------------------------
    def prepare(self, project):
        """Build a module-qualified call graph over src/repro.

        Nodes are (module, func-name).  A `Name` reference resolves to a
        def in the SAME module or one pulled in by a from-import; an
        `Attribute` reference (`mod.func`) resolves only against
        MODULE-LEVEL functions (methods are too generically named —
        matching them fuses unrelated subsystems into one blob).
        Over-approximate on purpose: a spurious edge only widens the
        checked set, a missed one silently exempts code.
        """
        mods = {rel: ctx for rel, ctx in project.items()
                if _under(rel, "src/repro/")}
        defs_by_mod = {}   # rel -> {name: [def nodes]} (incl. nested/methods)
        toplevel = {}      # name -> [rels defining it at module level]
        imports = {}       # rel -> names bound by from-imports
        for rel, ctx in mods.items():
            d = {}
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    d.setdefault(node.name, []).append(node)
            defs_by_mod[rel] = d
            for stmt in ast.walk(ctx.tree):
                if isinstance(stmt, ast.ImportFrom):
                    imports.setdefault(rel, set()).update(
                        a.asname or a.name for a in stmt.names)
            for stmt in ctx.tree.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    toplevel.setdefault(stmt.name, []).append(rel)

        edges = {}         # (rel, name) -> {(rel, name)}
        jit_marked = set()
        for rel, d in defs_by_mod.items():
            for name, fnodes in d.items():
                key = (rel, name)
                refs = set()
                jitted = False
                for fn in fnodes:
                    for dec in fn.decorator_list:
                        if _identifiers(dec) & {"jit", "pallas_call"}:
                            jitted = True
                    for sub in ast.walk(fn):
                        if isinstance(sub, ast.Name) and sub.id != name:
                            if sub.id in d:
                                refs.add((rel, sub.id))
                            elif sub.id in imports.get(rel, set()) \
                                    and sub.id in toplevel:
                                refs.update((r, sub.id)
                                            for r in toplevel[sub.id])
                        elif isinstance(sub, ast.Attribute) \
                                and sub.attr != name and sub.attr in toplevel:
                            refs.update((r, sub.attr)
                                        for r in toplevel[sub.attr])
                        if _call_attr(sub) in ("jit", "pallas_call",
                                               "shard_map"):
                            jitted = True
                edges[key] = refs
                if jitted:
                    jit_marked.add(key)

        def closure(seeds):
            seen, stack = set(), list(seeds)
            while stack:
                cur = stack.pop()
                if cur in seen or cur not in edges:
                    continue
                seen.add(cur)
                stack.extend(edges[cur])
            return seen

        roots = [(rel, name) for rel, d in defs_by_mod.items()
                 for name in d if name in self.ROOTS]
        reachable = closure(roots)
        self.traced_names = frozenset(closure(reachable & jit_marked))

    # -- per-file checks ----------------------------------------------------
    def _hazards(self, fn):
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                if (isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "item"):
                    yield sub, ".item() forces a traced value to host"
                elif (isinstance(sub.func, ast.Attribute)
                        and isinstance(sub.func.value, ast.Name)
                        and sub.func.value.id in self.NP_ALIASES
                        and sub.func.attr in self.NP_BANNED):
                    yield sub, (f"numpy op np.{sub.func.attr} on a traced "
                                "value; use jnp")
                elif (isinstance(sub.func, ast.Name) and sub.func.id == "float"
                        and sub.args
                        and not isinstance(sub.args[0], ast.Constant)):
                    yield sub, "float() concretizes a traced value"
            elif isinstance(sub, (ast.If, ast.While)) and not isinstance(
                    sub, ast.IfExp):
                test_ids = {s.id for s in ast.walk(sub.test)
                            if isinstance(s, ast.Name)}
                if "jnp" in test_ids:
                    kind = "if" if isinstance(sub, ast.If) else "while"
                    yield sub, (f"Python `{kind}` on a jnp expression "
                                "inside the traced fused path; use "
                                "jnp.where / lax.cond")

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and (ctx.rel, node.name) in self.traced_names:
                for sub, why in self._hazards(node):
                    yield self.finding(
                        ctx, sub,
                        f"{why} (inside {node.name!r}, reachable from the "
                        "jitted fused row-cycle dispatch)")


class RL006BatchAlignment(RuleBase):
    """Batch-dimension literals must be positive B_ALIGN (64) multiples —
    which also keeps every replica-mode [replica, main] boundary even."""

    rule_id = "RL006"
    description = "batch-dimension literal breaks B_ALIGN/even-pair rules"
    KEYWORDS = ("b_chunk", "b_blk")
    NAME_TOKENS = ("B_CHUNK", "B_BLK", "B_ALIGN")

    def applies_to(self, rel):
        return _under(rel, "src/repro/", "benchmarks/", "examples/")

    def _bad(self, value) -> bool:
        return not (isinstance(value, int) and not isinstance(value, bool)
                    and value > 0 and value % B_ALIGN == 0)

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in self.KEYWORDS and isinstance(
                            kw.value, ast.Constant) and self._bad(
                            kw.value.value):
                        yield self.finding(
                            ctx, node,
                            f"{kw.arg}={kw.value.value!r} is not a "
                            f"positive multiple of B_ALIGN ({B_ALIGN}); "
                            "unaligned chunks break compiled-shape "
                            "sharing and can split a replica pair")
                if (isinstance(node.func, ast.Name)
                        and node.func.id == "validate_b_chunk"
                        and node.args
                        and isinstance(node.args[0], ast.Constant)
                        and self._bad(node.args[0].value)):
                    yield self.finding(
                        ctx, node,
                        f"validate_b_chunk({node.args[0].value!r}) will "
                        f"always raise; pass a positive B_ALIGN "
                        f"({B_ALIGN}) multiple")
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if (isinstance(t, ast.Name)
                            and any(tok in t.id for tok in self.NAME_TOKENS)
                            and isinstance(node.value, ast.Constant)
                            and self._bad(node.value.value)):
                        yield self.finding(
                            ctx, node,
                            f"{t.id} = {node.value.value!r} is not a "
                            f"positive multiple of B_ALIGN ({B_ALIGN})")


ALL_RULES = (RL001NameSpecialCase, RL002BatchPythonLoop, RL003FakeZeros,
             RL004ReservedMCChannel, RL005TracerLeak, RL006BatchAlignment)
