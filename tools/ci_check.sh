#!/usr/bin/env bash
# Pre-merge gate: lint-free compile of every tree + the fast test tier.
#
#   tools/ci_check.sh            # what CI runs on every PR
#   tools/ci_check.sh --slow     # additionally run the slow tier (nightly)
#
# The fast tier (`pytest -x -q`, which deselects @slow via pytest.ini)
# must stay green AND inside its wall-clock budget (FAST_TIER_BUDGET_S,
# default 180 s — raised from 90 when the sharded-sweep driver tests
# joined the tier and again for the correlated-MC tests; the default
# matches what CI uses, so local runs and shared runners share one
# number).  The gate fails on either.  The tier-1 test count is printed
# so CI logs show coverage growth across PRs.  See tests/README.md.
#
# Set JUNIT_DIR to additionally write junit XML per tier
# (junit-fast.xml / junit-slow.xml) — the nightly job uploads these as
# triage artifacts.
set -euo pipefail
cd "$(dirname "$0")/.." || exit 1

FAST_TIER_BUDGET_S="${FAST_TIER_BUDGET_S:-180}"
junit_fast=()
junit_slow=()
if [[ -n "${JUNIT_DIR:-}" ]]; then
    mkdir -p "$JUNIT_DIR"
    junit_fast=(--junitxml "$JUNIT_DIR/junit-fast.xml")
    junit_slow=(--junitxml "$JUNIT_DIR/junit-slow.xml")
fi

echo "== compile check =="
python -m compileall -q src tests benchmarks tools examples

echo "== repro-lint (AST-enforced repo invariants, docs/lint.md) =="
python -m tools.repro_lint src tests benchmarks examples

echo "== flowcheck (dispatch/retrace/lock audits, docs/lint.md) =="
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
    python -m tools.flowcheck --json flowcheck_report.json

echo "== fast test tier (budget ${FAST_TIER_BUDGET_S}s) =="
pytest_log="$(mktemp)"
trap 'rm -f "$pytest_log"' EXIT
t0="$(date +%s)"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    ${junit_fast[@]+"${junit_fast[@]}"} \
    | tee "$pytest_log"
t1="$(date +%s)"
elapsed="$((t1 - t0))"

passed="$(grep -Eo '[0-9]+ passed' "$pytest_log" | tail -n 1 \
    | grep -Eo '[0-9]+' || echo 0)"
echo "tier-1: ${passed} tests passed in ${elapsed}s"
if [[ "$passed" -eq 0 ]]; then
    echo "ci_check: FAIL - no passing tests reported" >&2
    exit 1
fi
if [[ "$elapsed" -gt "$FAST_TIER_BUDGET_S" ]]; then
    echo "ci_check: FAIL - fast tier took ${elapsed}s" \
        "(budget ${FAST_TIER_BUDGET_S}s); move heavy tests to @slow" >&2
    exit 1
fi

echo "== examples smoke (DesignSpace -> sweep -> DesignBatch -> MC yield) =="
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python examples/dram_codesign.py --smoke --mc 16 --replica > /dev/null

echo "== sharded sweep smoke (8 forced host devices, bit-equivalence) =="
# our forced count goes LAST so it wins over any pre-existing XLA_FLAGS;
# --expect-devices makes the smoke fail loudly if the forcing is lost
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
    XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.shard --smoke --expect-devices 8

echo "== serving smoke (2 concurrent clients, 1 shared dispatch, memo) =="
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --smoke

# the two-process jax.distributed smoke spawns real child processes, so
# it is opt-in locally (CI runs it as its own job: multiprocess-smoke)
if [[ "${MULTIPROC_SMOKE:-0}" == "1" ]]; then
    echo "== multi-process smoke (2-process jax.distributed cluster) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.launch.multiproc --smoke
fi

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow test tier =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q -m slow \
        ${junit_slow[@]+"${junit_slow[@]}"}
fi

echo "ci_check: OK"
