#!/usr/bin/env bash
# Pre-merge gate: lint-free compile of every tree + the fast test tier.
#
#   tools/ci_check.sh            # what CI runs on every PR
#   tools/ci_check.sh --slow     # additionally run the slow tier (manual)
#
# The fast tier (`pytest -x -q`, which deselects @slow via pytest.ini)
# must stay green and finish in well under a minute; see tests/README.md.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compile check =="
python -m compileall -q src tests benchmarks tools examples

echo "== fast test tier =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== examples smoke (DesignSpace -> sweep -> DesignBatch API) =="
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python examples/dram_codesign.py --smoke > /dev/null

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow test tier =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m slow
fi

echo "ci_check: OK"
