"""Design-space exploration — the "co-optimization" of the paper's title.

Sweeps (technology x routing scheme x layer count) fully vectorized, scores
every design point on density / margin / latency / energy / bonding
feasibility, and extracts the feasible Pareto front.  This is what turns
the calibrated physics models into the paper's conclusion: the selector+
strap topology is the only corner that is simultaneously manufacturable
(pitch), functional (margin), and fast/efficient.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from . import calibration as cal
from .calibration import TECHS, TechCal
from .density import bit_density_gb_mm2, stack_height_um
from .energy import read_energy_fj, write_energy_fj
from .netlist import effective_cbl_ff
from .routing import SCHEMES, bonding_geometry
from .sense import sense_margin_mv
from .transient import simulate_row_cycle, simulate_row_cycle_many


@dataclass(frozen=True)
class DesignPoint:
    tech: str
    scheme: str
    layers: int
    density_gb_mm2: float
    height_um: float
    cbl_ff: float
    margin_mv: float
    margin_disturbed_mv: float
    trc_ns: float
    e_write_fj: float
    e_read_fj: float
    hcb_pitch_um: float
    blsa_area_um2: float
    feasible: bool


def evaluate_grid(tech: TechCal, scheme: str, layers: np.ndarray,
                  with_transient: bool = True,
                  trc: np.ndarray | None = None) -> list[DesignPoint]:
    """Evaluate a vector of layer counts for one (tech, scheme).

    `trc` may carry precomputed row-cycle times (e.g. from the batched
    fused sweep in `full_sweep`); otherwise the transient engine runs here.
    """
    arr = jnp.asarray(layers)
    dens = np.asarray(bit_density_gb_mm2(tech, arr))
    height = np.asarray(stack_height_um(tech, arr))
    cbl = np.asarray(effective_cbl_ff(tech, scheme, arr))
    margin = np.asarray(sense_margin_mv(tech, scheme, arr))
    margin_d = np.asarray(sense_margin_mv(tech, scheme, arr, with_disturb=True))
    e_wr = np.asarray(write_energy_fj(tech, scheme, arr))
    e_rd = np.asarray(read_energy_fj(tech, scheme, arr))
    geom = bonding_geometry(tech, scheme)
    pitch = float(geom.hcb_pitch_um)
    blsa = float(geom.blsa_area_um2)
    manufacturable = bool(geom.manufacturable) or tech.name == "d1b"
    if trc is not None:
        trc = np.asarray(trc)
    elif with_transient:
        trc = np.asarray(simulate_row_cycle(tech, scheme, arr).trc_ns)
    else:
        trc = np.full(len(layers), np.nan)

    pts = []
    for i, layer in enumerate(np.asarray(layers)):
        feas = (manufacturable
                and margin[i] >= cal.MIN_FUNCTIONAL_MARGIN_MV - 1e-9
                and margin_d[i] >= cal.MIN_DISTURBED_MARGIN_MV - 1e-9)
        pts.append(DesignPoint(
            tech=tech.name, scheme=scheme, layers=int(layer),
            density_gb_mm2=float(dens[i]), height_um=float(height[i]),
            cbl_ff=float(cbl[i]), margin_mv=float(margin[i]),
            margin_disturbed_mv=float(margin_d[i]), trc_ns=float(trc[i]),
            e_write_fj=float(e_wr[i]), e_read_fj=float(e_rd[i]),
            hcb_pitch_um=pitch, blsa_area_um2=blsa, feasible=bool(feas)))
    return pts


def sweep_combos(layer_grid: np.ndarray) -> list[tuple[TechCal, str, np.ndarray]]:
    """The (tech, scheme, layer-grid) combos of the full design space."""
    combos: list[tuple[TechCal, str, np.ndarray]] = []
    for tname, tech in TECHS.items():
        if tname == "d1b":
            combos.append((tech, "direct", np.array([1])))
            continue
        for scheme in SCHEMES:
            combos.append((tech, scheme, layer_grid))
    return combos


def full_sweep(layer_grid: np.ndarray | None = None,
               with_transient: bool = True) -> list[DesignPoint]:
    """Sweep the whole (tech x scheme x layers) design space.

    The transient row-cycle times for ALL combos are produced by one
    batched, chunked pass through the fused engine
    (`simulate_row_cycle_many`) — not by per-combo transient calls.
    """
    if layer_grid is None:
        layer_grid = np.array([32, 48, 64, 87, 100, 120, 137, 160, 200])
    combos = sweep_combos(layer_grid)
    if with_transient:
        trcs = [np.asarray(r.trc_ns)
                for r in simulate_row_cycle_many(combos)]
    else:
        trcs = [None] * len(combos)
    out: list[DesignPoint] = []
    for (tech, scheme, grid), trc in zip(combos, trcs):
        out.extend(evaluate_grid(tech, scheme, grid,
                                 with_transient=with_transient, trc=trc))
    return out


def pareto_front(points: list[DesignPoint],
                 require_feasible: bool = True) -> list[DesignPoint]:
    """Non-dominated set maximizing density & margin, minimizing tRC & E."""
    cand = [p for p in points if (p.feasible or not require_feasible)]

    def dominates(a: DesignPoint, b: DesignPoint) -> bool:
        ge = (a.density_gb_mm2 >= b.density_gb_mm2
              and a.margin_disturbed_mv >= b.margin_disturbed_mv
              and a.trc_ns <= b.trc_ns and a.e_read_fj <= b.e_read_fj)
        gt = (a.density_gb_mm2 > b.density_gb_mm2
              or a.margin_disturbed_mv > b.margin_disturbed_mv
              or a.trc_ns < b.trc_ns or a.e_read_fj < b.e_read_fj)
        return ge and gt

    return [p for p in cand
            if not any(dominates(q, p) for q in cand if q is not p)]


def best_design(points: list[DesignPoint],
                density_target: float = cal.DENSITY_TARGET_GB_MM2):
    """The paper's selection rule: hit the density target with a functional,
    manufacturable design; break ties by tRC then read energy."""
    ok = [p for p in points if p.feasible
          and p.density_gb_mm2 >= density_target - 1e-9]
    if not ok:
        return None
    return min(ok, key=lambda p: (p.trc_ns, p.e_read_fj, p.height_um))
