"""Design-space exploration — the "co-optimization" of the paper's title.

Array-native flow (the public API):

    space = DesignSpace.paper_grid()        # declarative (core.space)
    batch = sweep(space)                    # ONE vectorized evaluation
    front = pareto_front(batch)             # masked array dominance
    best  = best_design(batch)              # paper's selection rule

`sweep` lowers the whole (tech x scheme x layers [x corners]) space to a
flat operand batch and pipes every metric — density, margin, energy,
bonding geometry, and the fused row-cycle tRC — through array ops end to
end: no per-combo Python loop anywhere, and the resulting `DesignBatch`
is a jit/vmap/sharding-compatible pytree (see core.batch).

This is what turns the calibrated physics models into the paper's
conclusion: the selector+strap topology is the only corner that is
simultaneously manufacturable (pitch), functional (margin), and
fast/efficient.

Legacy surface: `full_sweep` / `evaluate_grid` still return the old
`list[DesignPoint]` (deprecated; thin views over the batch), and
`pareto_front` / `best_design` accept either a `DesignBatch` or a list.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from . import calibration as cal
from . import contracts
from .batch import DesignBatch, DesignPoint
from .calibration import TECHS, TechCal
from .density import (bit_density_gb_mm2, bit_density_lowered,
                      stack_height_lowered, stack_height_um)
from .energy import (read_energy_fj, read_energy_lowered, write_energy_fj,
                     write_energy_lowered)
from .netlist import build_ladder_lowered, effective_cbl_ff
from .parasitics import bl_parasitics_lowered
from .routing import SCHEMES, bonding_geometry, bonding_geometry_lowered
from .sense import sense_margin_lowered, sense_margin_mv
from .space import MC_AXES, MC_LOG_W, DesignSpace, SpaceView
from . import transient
from .transient import simulate_row_cycle, simulate_row_cycle_many

__all__ = [
    "DesignBatch", "DesignPoint", "DesignSpace",
    "SweepPlan", "plan_sweep", "finalize_sweep",
    "score_columns", "score_from_events", "assemble_batch",
    "sweep", "pareto_mask", "pareto_front", "best_design", "as_batch",
    "full_sweep", "evaluate_grid", "sweep_combos",
]

# Corner axes `sweep` knows how to route into the physics models (the
# reserved mc_* channels of a with_mc space ride the same mechanism).
SUPPORTED_CORNER_AXES = ("rh_toggles", "trc_cycles")


# ---------------------------------------------------------------------------
# The vectorized sweep
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepPlan:
    """A lowered, dispatch-ready sweep: everything `sweep` does before the
    fused engine runs.

    The plan/finalize split is the serving seam: `plan_sweep` lowers a
    space to its operand batch, `finalize_sweep` turns a transient result
    back into the scored `DesignBatch` — and BOTH halves are the exact
    code `sweep` itself runs, so a caller that dispatches the operands
    elsewhere (e.g. `serving.dse_service` packing many clients' plans
    into one shared slab) gets results bit-identical to a direct
    `dse.sweep` by construction.
    """
    space: DesignSpace
    sp: object                         # LoweredSpace
    par: object                        # BLParasitics over the lowered space
    operands: transient.FusedOperands | None   # None when transient is off

    def __len__(self) -> int:
        return len(self.sp)

    @property
    def with_transient(self) -> bool:
        return self.operands is not None


def plan_sweep(space: DesignSpace | None = None,
               with_transient: bool = True) -> SweepPlan:
    """Lower a `DesignSpace` to a dispatch-ready `SweepPlan`.

    Validates corner axes, assembles the parasitic decomposition, and
    (when the transient is on) lowers the whole space to ONE
    `FusedOperands` batch — the heavy per-request work a warm serving
    engine wants to do once per space, off the dispatch path.
    """
    if space is None:
        space = DesignSpace.paper_grid()
    sp = space.lower()
    unknown = [k for k in sp.corners
               if k not in SUPPORTED_CORNER_AXES and k not in MC_AXES
               and k != MC_LOG_W]
    if unknown:
        raise ValueError(f"unsupported corner axes {unknown}; sweep "
                         f"understands {SUPPORTED_CORNER_AXES}")
    par = bl_parasitics_lowered(sp)
    operands = None
    if with_transient:
        ladder_c, ladder_g = build_ladder_lowered(sp, par)
        operands = transient.lower_design_operands(
            sp, ladder_c=ladder_c, ladder_g=ladder_g)
    return SweepPlan(space=space, sp=sp, par=par, operands=operands)


def score_columns(view, cbl_ff, trc=None, t_sense=None, t_fire=None,
                  dv_sense=None) -> dict:
    """Pure-jnp per-row scoring of a design-space view -> column dict.

    `view` is a `SpaceView` (or any traceable LoweredSpace-protocol
    object); `cbl_ff` the per-point total BL capacitance from the plan's
    parasitic decomposition.  The transient columns (`trc`, `t_sense`,
    `t_fire`, `dv_sense`) are either all given (post-rollup, design-point
    length) or all None (`with_transient=False`: NaN-filled).

    Every output is an elementwise (B,) array — no cross-row ops — so
    the function is batch-size independent and runs identically whether
    jitted whole-batch (the sequential sweep) or inside a per-device
    `shard_map` body (the sharded sweep).  Keys match `DesignBatch`
    field names; `assemble_batch` zips them with the host-side identity
    columns.
    """
    cbl = jnp.asarray(cbl_ff, jnp.float32)
    dens = bit_density_lowered(view)
    height = stack_height_lowered(view)
    margin = sense_margin_lowered(view, cbl_ff=cbl)
    margin_d = sense_margin_lowered(view, with_disturb=True, cbl_ff=cbl)
    e_wr = write_energy_lowered(view, cbl_ff=cbl)
    e_rd = read_energy_lowered(view, cbl_ff=cbl)
    geom = bonding_geometry_lowered(view)

    if trc is not None:
        # margin actually available at the SA fire: the simulated
        # developed signal at the enable instant minus the SA offset
        # (per-sample on MC spaces, calibrated corner otherwise) — the
        # closed-timing counterpart of the analytic charge-share margin.
        sa_offset = view.corner("mc_sa_offset_mv", None)
        if sa_offset is None:
            sa_offset = jnp.asarray(view.tech("sa_offset_mv"), jnp.float32)
        margin_fire = (dv_sense * 1e3 - sa_offset).astype(jnp.float32)
    else:
        trc = jnp.full((len(view),), jnp.nan, jnp.float32)
        t_sense = trc
        t_fire = trc
        margin_fire = trc

    valid = jnp.asarray(view.valid)
    feasible = (geom.manufacturable
                & (margin >= cal.MIN_FUNCTIONAL_MARGIN_MV - 1e-9)
                & (margin_d >= cal.MIN_DISTURBED_MARGIN_MV - 1e-9)
                & valid)
    if dv_sense is not None:
        # a design whose timing never closed (NaN tRC: a phase timed out,
        # or the WL ramp starved signal development past the ACT window)
        # is invalid as a design, not merely slow
        feasible = feasible & jnp.isfinite(trc)

    return dict(
        density_gb_mm2=dens, height_um=height, cbl_ff=cbl,
        margin_mv=margin, margin_disturbed_mv=margin_d,
        trc_ns=jnp.asarray(trc, jnp.float32),
        t_sense_ns=jnp.asarray(t_sense, jnp.float32),
        t_fire_ns=jnp.asarray(t_fire, jnp.float32),
        margin_fire_mv=margin_fire, e_write_fj=e_wr, e_read_fj=e_rd,
        hcb_pitch_um=geom.hcb_pitch_um.astype(jnp.float32),
        blsa_area_um2=geom.blsa_area_um2.astype(jnp.float32),
        manufacturable=geom.manufacturable, feasible=feasible)


def score_from_events(view, cbl_ff, sa_tau_ns, t_overhead_ns, evt) -> dict:
    """Rollup + scoring from raw fused-engine event columns -> column dict.

    `evt` is the engine's (B_ops, 4) output BEFORE replica de-interleave;
    `sa_tau_ns` / `t_overhead_ns` are the matching operand-length rollup
    vectors.  On replica spaces (`view.replica`, static) the main rows
    sit at odd indices and B_ops == 2 * len(view).

    This is THE scoring program of the sweep: the sequential path runs
    it under one `jax.jit`, the sharded path runs the same function as a
    per-device `shard_map` body (`launch.shard`) — identical per-row
    arithmetic, hence bit-identical columns.
    """
    sa_tau = jnp.asarray(sa_tau_ns, jnp.float32)
    overhead = jnp.asarray(t_overhead_ns, jnp.float32)
    if view.replica:
        evt = evt[1::2]
        sa_tau = sa_tau[1::2]
        overhead = overhead[1::2]
    t_sense, _t_restore, trc = transient._regen_and_totals(
        sa_tau, overhead, evt[:, 0], evt[:, 1], evt[:, 2], evt[:, 3])
    return score_columns(view, cbl_ff, trc=trc, t_sense=t_sense,
                         t_fire=evt[:, 0], dv_sense=evt[:, 1])


# The ONE compiled scoring program (see score_from_events): module-level
# so the sequential sweep, the serving finalize, and repeat calls all hit
# the same jit cache.
_score_columns_jit = jax.jit(score_columns)
_score_from_events_jit = jax.jit(score_from_events)


def assemble_batch(sp, cols: dict) -> DesignBatch:
    """Zip scored metric columns with a lowered space's identity columns
    into the contract-checked `DesignBatch`.

    `cols` is a `score_columns`-shaped dict (device or host arrays —
    the sharded sweep hands back gathered numpy columns); `sp` supplies
    the per-point identity (indices, layers, validity, corner values)
    and the static names/layout.
    """
    batch = DesignBatch(
        tech_idx=jnp.asarray(sp.tech_idx), scheme_idx=jnp.asarray(sp.scheme_idx),
        layers=sp.layers, valid=jnp.asarray(sp.valid),
        corners={k: jnp.asarray(v) for k, v in sp.corners.items()},
        tech_names=sp.tech_names, scheme_names=sp.scheme_names,
        n_samples=sp.samples, base_len=sp.base_len,
        **{k: jnp.asarray(v) for k, v in cols.items()})
    contracts.check_batch(batch, where="dse.sweep")
    return batch


def finalize_sweep(plan: SweepPlan,
                   res: transient.RowCycleResult | None = None) -> DesignBatch:
    """Score a planned sweep into a `DesignBatch`.

    `res` is the fused-engine result for `plan.operands` (None iff the
    plan was made with `with_transient=False`).  This is the second half
    of `sweep`: the jitted `score_from_events` program rolls the raw
    engine events up and scores every metric as flat (B,) arrays over
    the plan's lowered space — the same program the sharded driver runs
    per device — then `assemble_batch` zips in the identity columns.
    """
    if plan.with_transient != (res is not None):
        raise ValueError(
            "finalize_sweep needs the fused-engine result exactly when "
            "the plan lowered transient operands (with_transient="
            f"{plan.with_transient}, res={'set' if res is not None else 'None'})")
    view = SpaceView.from_lowered(plan.sp)
    cbl = jnp.asarray(plan.par.c_bl_total_ff, jnp.float32)
    if res is None:
        cols = _score_columns_jit(view, cbl)
    elif res.events is not None:
        cols = _score_from_events_jit(
            view, cbl, plan.operands.sa_tau_ns, plan.operands.t_overhead_ns,
            res.events)
    else:
        # result built without raw events (legacy construction): score
        # from the rolled-up columns; matches the events path up to the
        # compiler's instruction scheduling of the rollup.
        cols = _score_columns_jit(view, cbl, res.trc_ns, res.t_sense_ns,
                                  res.t_fire_ns, res.dv_sense_v)
    return assemble_batch(plan.sp, cols)


def sweep(space: DesignSpace | None = None, with_transient: bool = True,
          backend: str = "auto",
          b_chunk: int = transient.DEFAULT_B_CHUNK,
          sharding=None) -> DesignBatch:
    """Score a whole `DesignSpace` in one vectorized pass -> `DesignBatch`.

    All metrics are computed as flat (B,) arrays over the lowered space;
    the transient row-cycle times come from ONE chunked pass through the
    fused engine (`transient.simulate_row_cycle_many` on the lowered
    operand batch) — never a per-combo transient call.  Internally this
    is `plan_sweep` -> fused dispatch -> `finalize_sweep`; the split is
    public so a warm serving engine (`serving.dse_service`) can pack many
    plans into one shared dispatch and finalize each identically.

    `sharding` (a `jax.sharding.Mesh` or `NamedSharding`) distributes
    BOTH the fused dispatch and the metric scoring over a device mesh —
    each device (and each host under multi-process JAX) evaluates and
    scores its own slab of the grid via `repro.launch.shard`, so no
    per-point intermediate ever materializes host-side; results are
    bit-identical to the single-host path (which remains the
    equivalence oracle).
    """
    if sharding is not None and not with_transient:
        raise ValueError(
            "sharding= only distributes the fused transient dispatch; a "
            "with_transient=False sweep is host-side array ops with "
            "nothing to shard — pass sharding=None")
    plan = plan_sweep(space, with_transient=with_transient)
    if plan.operands is not None and sharding is not None:
        from ..launch import shard
        cols = shard.sharded_sweep_columns(plan, sharding, backend=backend,
                                           b_chunk=b_chunk)
        return assemble_batch(plan.sp, cols)
    res = None
    if plan.operands is not None:
        res = simulate_row_cycle_many(plan.operands, backend=backend,
                                      b_chunk=b_chunk)
    return finalize_sweep(plan, res)


# ---------------------------------------------------------------------------
# Pareto front / selection (vectorized dominance)
# ---------------------------------------------------------------------------

def pareto_mask(batch: DesignBatch, require_feasible: bool = True,
                block: int = 4096, extra_maximize=(),
                extra_minimize=(), sharding=None) -> jnp.ndarray:
    """Non-dominated mask maximizing density & disturbed margin, minimizing
    tRC & read energy.  Pure jnp (jit-compatible): the O(n^2) pairwise
    comparison runs as masked broadcasts over fixed-size dominator blocks,
    so peak memory is O(block * B), not O(B^2) — million-point sharded
    sweeps stay tractable (tune `block` down for very large batches).

    `extra_maximize` / `extra_minimize` append further (B,) objective
    columns — e.g. a Monte-Carlo yield column
    (`batch.mc_summary(...).corners["yield_frac"]`) as a maximized
    objective alongside the nominal metrics.

    `sharding` (Mesh / NamedSharding) distributes the dominator blocks
    over a device mesh instead of the host loop: each device tests its
    own dominator slab against the (replicated) full batch and the
    per-device dominated masks OR-reduce across the mesh
    (`launch.shard.sharded_pareto_mask`).  Dominance tests are exact
    comparisons and boolean OR is order-independent, so the sharded mask
    is bit-identical to the sequential one.

    NaN metrics (e.g. tRC with `with_transient=False`) never dominate and
    are never dominated — matching the legacy pairwise semantics.
    """
    cand = batch.valid
    if require_feasible:
        cand = cand & batch.feasible
    hi = jnp.stack([batch.density_gb_mm2, batch.margin_disturbed_mv,
                    *(jnp.asarray(x) for x in extra_maximize)], axis=1)
    lo = jnp.stack([batch.trc_ns, batch.e_read_fj,
                    *(jnp.asarray(x) for x in extra_minimize)], axis=1)
    if sharding is not None:
        from ..launch import shard
        dominated = shard.sharded_pareto_dominated(hi, lo, cand, sharding,
                                                   block=block)
        return cand & ~jnp.asarray(dominated)
    b = hi.shape[0]
    dominated = jnp.zeros((b,), bool)
    for i0 in range(0, b, block):          # dominator blocks (static count)
        hi_i, lo_i = hi[i0:i0 + block], lo[i0:i0 + block]
        cand_i = cand[i0:i0 + block]
        ge = ((hi_i[:, None, :] >= hi[None, :, :]).all(-1)
              & (lo_i[:, None, :] <= lo[None, :, :]).all(-1))
        gt = ((hi_i[:, None, :] > hi[None, :, :]).any(-1)
              | (lo_i[:, None, :] < lo[None, :, :]).any(-1))
        dominated |= (ge & gt & cand_i[:, None] & cand[None, :]).any(axis=0)
    return cand & ~dominated


def as_batch(points_or_batch) -> DesignBatch:
    """Normalize any selection input to a `DesignBatch`.

    THE compatibility adapter of the selection layer: a `DesignBatch`
    passes through untouched; a legacy `list[DesignPoint]` (or any
    iterable of point-shaped objects) is bridged via
    `DesignBatch.from_points`.  `pareto_front` / `best_design` are
    batch-native internally and use this adapter at their boundary —
    list-in/list-out back-compat lives here and nowhere else.
    """
    if isinstance(points_or_batch, DesignBatch):
        return points_or_batch
    return DesignBatch.from_points(list(points_or_batch))


def _legacy_points(points_or_batch):
    """The list half of the back-compat boundary: the materialized legacy
    list when the caller passed one (so outputs keep list form), else
    None for the batch-native path."""
    if isinstance(points_or_batch, DesignBatch):
        return None
    return list(points_or_batch)


def pareto_front(points_or_batch, require_feasible: bool = True,
                 extra_maximize=(), extra_minimize=(), sharding=None):
    """Non-dominated set.  `DesignBatch` in -> filtered `DesignBatch` out;
    legacy `list[DesignPoint]` in -> list out (order preserved), bridged
    through the `as_batch` adapter.  Extra (B,) objective columns (e.g.
    an MC yield column) and `sharding` (distribute the dominance test
    over a device mesh) pass through to `pareto_mask`."""
    points = _legacy_points(points_or_batch)
    batch = as_batch(points_or_batch if points is None else points)
    mask = np.asarray(pareto_mask(batch, require_feasible,
                                  extra_maximize=extra_maximize,
                                  extra_minimize=extra_minimize,
                                  sharding=sharding))
    if points is None:
        return batch.select(mask)
    return [p for p, m in zip(points, mask) if m]


def best_design(points_or_batch,
                density_target: float = cal.DENSITY_TARGET_GB_MM2,
                min_yield: float | None = None, yield_frac=None):
    """The paper's selection rule: hit the density target with a functional,
    manufacturable design; break ties by tRC then read energy then height.
    Accepts a `DesignBatch` or the legacy list; returns a `DesignPoint`
    (or None if nothing qualifies).

    `min_yield` adds a Monte-Carlo yield floor: candidates must have
    `yield_frac >= min_yield`, where `yield_frac` is an explicit (B,)
    column or defaults to the batch's `corners["yield_frac"]` (set by
    `DesignBatch.mc_summary`).
    """
    points = _legacy_points(points_or_batch)
    batch = as_batch(points_or_batch if points is None else points)
    cand = (np.asarray(batch.valid) & np.asarray(batch.feasible)
            & (np.asarray(batch.density_gb_mm2) >= density_target - 1e-9))
    if min_yield is not None:
        if yield_frac is None:
            yield_frac = batch.corners.get("yield_frac")
        if yield_frac is None:
            raise ValueError(
                "min_yield needs a yield column: pass yield_frac= or use "
                "a batch with corners['yield_frac'] (DesignBatch.mc_summary)")
        cand &= np.asarray(yield_frac) >= min_yield - 1e-9
    idx = np.flatnonzero(cand)
    if idx.size == 0:
        return None
    trc = np.asarray(batch.trc_ns, np.float64)[idx]
    trc = np.where(np.isnan(trc), np.inf, trc)
    e_rd = np.asarray(batch.e_read_fj, np.float64)[idx]
    height = np.asarray(batch.height_um, np.float64)[idx]
    order = np.lexsort((height, e_rd, trc))     # last key is primary
    best = int(idx[order[0]])
    return points[best] if points is not None else batch.point(best)


# ---------------------------------------------------------------------------
# Legacy list[DesignPoint] surface (deprecated)
# ---------------------------------------------------------------------------

def evaluate_grid(tech: TechCal, scheme: str, layers: np.ndarray,
                  with_transient: bool = True,
                  trc: np.ndarray | None = None) -> list[DesignPoint]:
    """Evaluate a vector of layer counts for one (tech, scheme).

    Deprecated reference path: per-(tech, scheme) scalar evaluation kept
    as the equivalence oracle for the vectorized `sweep`.  `trc` may carry
    precomputed row-cycle times; otherwise the transient engine runs here.
    """
    arr = jnp.asarray(layers)
    dens = np.asarray(bit_density_gb_mm2(tech, arr))
    height = np.asarray(stack_height_um(tech, arr))
    cbl = np.asarray(effective_cbl_ff(tech, scheme, arr))
    margin = np.asarray(sense_margin_mv(tech, scheme, arr))
    margin_d = np.asarray(sense_margin_mv(tech, scheme, arr, with_disturb=True))
    e_wr = np.asarray(write_energy_fj(tech, scheme, arr))
    e_rd = np.asarray(read_energy_fj(tech, scheme, arr))
    geom = bonding_geometry(tech, scheme)
    pitch = float(geom.hcb_pitch_um)
    blsa = float(geom.blsa_area_um2)
    manufacturable = bool(geom.manufacturable) or tech.baseline_2d
    if trc is not None:
        trc = np.asarray(trc)
    elif with_transient:
        trc = np.asarray(simulate_row_cycle(tech, scheme, arr).trc_ns)
    else:
        trc = np.full(len(layers), np.nan)

    pts = []
    for i, layer in enumerate(np.asarray(layers)):  # repro-lint: disable=RL002  (scalar equivalence oracle for tests, not the fused sweep path)
        feas = (manufacturable
                and margin[i] >= cal.MIN_FUNCTIONAL_MARGIN_MV - 1e-9
                and margin_d[i] >= cal.MIN_DISTURBED_MARGIN_MV - 1e-9)
        pts.append(DesignPoint(
            tech=tech.name, scheme=scheme, layers=int(layer),
            density_gb_mm2=float(dens[i]), height_um=float(height[i]),
            cbl_ff=float(cbl[i]), margin_mv=float(margin[i]),
            margin_disturbed_mv=float(margin_d[i]), trc_ns=float(trc[i]),
            e_write_fj=float(e_wr[i]), e_read_fj=float(e_rd[i]),
            hcb_pitch_um=pitch, blsa_area_um2=blsa, feasible=bool(feas)))
    return pts


def sweep_combos(layer_grid: np.ndarray) -> list[tuple[TechCal, str, np.ndarray]]:
    """The (tech, scheme, layer-grid) combos of the full design space.

    Deprecated: capability flags on each registered `TechCal` drive this
    now (no name-based special cases); new code should build a
    `DesignSpace` instead.  Removal timeline: docs/api.md.
    """
    warnings.warn(
        "dse.sweep_combos is deprecated and will be removed (see "
        "docs/api.md for the timeline); build a DesignSpace "
        "(DesignSpace.paper_grid / product) instead",
        DeprecationWarning, stacklevel=2)
    combos: list[tuple[TechCal, str, np.ndarray]] = []
    for tech in TECHS.values():
        schemes = tech.allowed_schemes or tuple(SCHEMES)
        grid = (np.asarray(tech.layer_grid) if tech.layer_grid is not None
                else layer_grid)
        for scheme in schemes:
            combos.append((tech, scheme, grid))
    return combos


def full_sweep(layer_grid: np.ndarray | None = None,
               with_transient: bool = True) -> list[DesignPoint]:
    """Sweep the whole (tech x scheme x layers) design space.

    Deprecated compatibility shim: equivalent to
    `sweep(DesignSpace.paper_grid(layer_grid)).to_points()`.  One batched
    fused-engine pass computes every transient, exactly like `sweep`.
    Removal timeline: docs/api.md.
    """
    warnings.warn(
        "dse.full_sweep is deprecated and will be removed (see docs/api.md "
        "for the timeline); use dse.sweep(DesignSpace.paper_grid(...)) and "
        "consume the DesignBatch columns",
        DeprecationWarning, stacklevel=2)
    grid = None if layer_grid is None else tuple(
        float(x) for x in np.asarray(layer_grid).reshape(-1))
    space = DesignSpace.paper_grid(layer_grid=grid)
    with warnings.catch_warnings():
        # the shim IS the deprecated surface; its internal to_points call
        # must not double-warn the caller
        warnings.simplefilter("ignore", DeprecationWarning)
        return sweep(space, with_transient=with_transient).to_points()
