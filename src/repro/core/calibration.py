"""Calibrated constants of the STCO engine.

The paper calibrates its TCAD/SPICE stack against external anchors (the IWO
device of ref [9], the TechInsights D1b teardown [10]).  We mirror that: the
constants below are the *calibration surface* of the engine — a small set of
element values fixed so that the engine's *derived* outputs reproduce the
paper's reported endpoints.  Everything downstream (four-scheme routing
comparison, density/margin scaling sweeps, Pareto fronts, energy/latency
tables) is computed from these by the physics modules, not hard-coded.

Paper endpoints used as calibration anchors (Figs. 3, 6, 8, 9, Table I):

  C_BL(sel+strap, w/ bonding)   6.6 fF            (Si, 137L)
  C_BL(D1b)                     20 fF
  sense margin nominal          130 mV (Si) / 189 mV (AOS) / 54 mV (D1b)
  margin w/ FBE+RH @2.6Gb/mm2   ~70 mV (Si)
  tRC nominal                   10.9 ns (Si) / 10.5 ns (AOS) / 21.3 ns (D1b)
  E_write                       6.26 / 5.38 fJ  (Si / AOS)
  E_read                        1.57 / 1.35 fJ
  bit density target            2.6 Gb/mm^2 = 137L (Si, 9.6 um) = 87L (AOS, 6.9 um)
  HCB pitch                     0.75 / 0.62 um (sel+strap), 0.26 / 0.22 um (direct, core-mux)
  BLSA area                     1.12 / 0.76 um^2 (vs 0.44 um^2 D1b)
  Cs                            4 fF (unified with D1b estimate)
"""

from __future__ import annotations

from dataclasses import dataclass, replace


# --------------------------------------------------------------------------
# Global electrical anchors
# --------------------------------------------------------------------------

CS_FF = 4.0                 # storage node capacitance, unified with D1b [10]
VDD_ARRAY = 1.1             # core array voltage (BL full swing)
VBL_PRE = VDD_ARRAY / 2.0   # bitline precharge level
VPP_3D = 1.7                # reduced WL overdrive of the 3D design (1.6-1.8 V)
VPP_D1B = 2.8               # conventional 2D WL overdrive

# Functional sensing thresholds for feasibility classification: nominal
# margin must clear 80 mV; with FBE+RH disturb the paper still calls the
# 70 mV Si point functional, so the disturbed floor is 60 mV.
MIN_FUNCTIONAL_MARGIN_MV = 80.0
MIN_DISTURBED_MARGIN_MV = 60.0

# Manufacturable wafer-to-wafer hybrid-bonding window (paper: 0.75/0.62 um is
# "well within" the window; sub-0.3 um is "prohibitively tight").
HCB_MIN_MANUFACTURABLE_PITCH_UM = 0.50

# Disturb duty assumed by the paper's mixed-mode TCAD analysis.
RH_TOGGLES_PER_64MS = 10_000
TRC_CYCLES_PER_64MS = 1.5e6
REFRESH_WINDOW_MS = 64.0

# D1b fixed reference values (not derived from geometry).
D1B_C_BL_FF = 20.0
D1B_BIT_DENSITY_GB_MM2 = 0.435
D1B_TRC_NS = 21.3
D1B_BLSA_AREA_UM2 = 0.44
D1B_E_SA_FJ = 0.9            # larger SA, higher-voltage internal nodes

# 3D design energy calibration
E_SA_FJ = 0.59               # BLSA latch energy per sense (3D design)
ENERGY_EFF = 0.975           # switching activity / adiabatic factor


# --------------------------------------------------------------------------
# Per-technology calibration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TechCal:
    """Calibrated per-technology (cell access device + integration) values."""

    name: str
    # --- geometry ---
    cell_x_nm: float            # BL-direction pitch (incl. isolation)
    cell_y_nm: float            # WL-direction pitch (line-type iso: 100 nm)
    layer_height_nm: float      # per-tier height of the stack
    array_efficiency: float     # mat area / die area (strap+SWD+SL lanes)
    layers_target: int          # layers needed for 2.6 Gb/mm^2 (derived check)
    # --- parasitics (fF) ---
    c_bl_per_layer_ff: float    # vertical local-BL capacitance per tier
    c_sel_junction_ff: float    # selector drain junction on the local BL
    c_global_strap_ff: float    # global strap metal (M1-M3 run to the bond)
    c_hcb_pad_ff: float         # hybrid Cu bond pad
    c_blsa_in_ff: float         # BLSA input (periphery side)
    c_route_extra_ff: float     # lateral IO routing (2D only; CBA kills it)
    # --- resistances (kOhm), effective large-signal values ---
    r_on_cell_kohm: float       # access transistor effective on-resistance
    r_sel_kohm: float           # IGO selector on-resistance
    r_local_bl_kohm: float      # distributed local BL wire resistance (total)
    r_global_kohm: float        # global strap + bond resistance
    r_wl_kohm: float            # WL wire+driver effective resistance
    c_wl_ff: float              # WL loading seen by the SWD
    # --- sensing calibration ---
    sa_offset_mv: float         # BLSA input-referred offset (25 mV, all)
    writeback_eff: float        # fraction of VDD restored into the cell
    # --- disturb (charge loss at target layer count, in mV on the cell) ---
    fbe_loss_mv: float          # floating-body-effect loss (AOS: none)
    rh_loss_mv: float           # row-hammer coupling loss
    # --- bonding/geometry calibration ---
    hcb_route_span_um: float    # effective routing span per direct bond
    # --- timing calibration ---
    t_overhead_ns: float        # command/decode/driver overhead per row cycle
    sa_tau_ns: float            # BLSA regenerative time constant
    r_pre_kohm: float           # precharge/equalize device resistance
    r_sa_drive_kohm: float      # SA restore drive resistance
    # --- declarative sweep capabilities (design-space registry) ---
    # These replace name-based special cases: a 2D baseline, its allowed
    # routing schemes, and its valid layer grid are *declared* here, so
    # registry-added technologies sweep correctly without editing the DSE.
    baseline_2d: bool = False             # planar reference (no CBA bonding)
    allowed_schemes: tuple | None = None  # None -> every registered scheme
    layer_grid: tuple | None = None       # None -> the sweep's layer grid
    fixed_c_bl_ff: float = 0.0            # baseline_2d: tabulated C_BL
    fixed_density_gb_mm2: float = 0.0     # baseline_2d: tabulated density
    fixed_blsa_area_um2: float = 0.0      # baseline_2d: tabulated BLSA area
    baseline_label: str = ""              # baseline_2d: report row label
    e_sa_fj: float = E_SA_FJ              # BLSA latch energy per sense
    vpp: float = VPP_3D                   # WL overdrive
    # --- Monte-Carlo variation (1-sigma spreads, DesignSpace.with_mc) ---
    # The nominal sa_offset_mv / r_on_cell_kohm above stay the corner
    # values; these sigmas only matter when a space declares MC sampling.
    sa_offset_sigma_mv: float = 0.0       # BLSA input-referred offset spread
    vth_sigma_mv: float = 0.0             # access-transistor Vth spread
    vth_overdrive_v: float = 0.6          # nominal gate overdrive (Vgs - Vth)
    # --- correlated within-die variation (DesignSpace.with_mc(corr=...)) ---
    # Variance decomposition of each standardized draw: a global die offset
    # (process shift shared by every mat of a die), a spatially correlated
    # mat/strap gradient, and the i.i.d. local remainder:
    #   z = sqrt(1-f_die-f_mat)*local + sqrt(f_die)*die + sqrt(f_mat)*grad
    # The fractions below are the f_* at corr=1 (the space's `corr` knob
    # scales them; corr=0 keeps the draws purely i.i.d.), and
    # `mc_corr_length` is the gradient's correlation length as a fraction
    # of the die span along the shared-mat axis.
    mc_die_sigma_frac: float = 0.0        # die-offset variance fraction
    mc_mat_sigma_frac: float = 0.0        # mat-gradient variance fraction
    mc_corr_length: float = 0.25          # gradient corr length (die-span)
    # --- replica-bitline timing closure (DesignSpace.with_replica) ---
    # A dummy bitline with `replica_cells` ganged cells (storage cap and
    # access conductance both scale) tracks the array; its own 90% signal
    # crossing fires the main array's SA enable, so t_sense closes per
    # corner and per MC sample instead of being the fixed own-crossing
    # time.  More cells -> earlier fire -> faster but lower-margin
    # sensing; `replica_cells=1` with `replica_store_frac=writeback_eff`
    # reproduces the fixed-timing behaviour.  The replica cells are
    # written to the full rail at manufacture, hence store_frac = 1.
    replica_cells: float = 2.0            # ganged dummy cells on the replica
    replica_store_frac: float = 1.0       # replica cell store level / VDD

    def with_(self, **kw) -> "TechCal":
        return replace(self, **kw)


# Si access transistor, epitaxial Si (Si-SiGe mold), line-type isolation.
#   cell 180 x 100 nm, 70 nm tier height.
#   C_BL(sel+strap) = 137*0.030 + 0.30 + 1.20 + 0.60 + 0.40 = 6.61 fF  (paper 6.6)
#   writeback_eff: degraded by FBE-shifted Vth at the reduced VPP=1.6-1.8 V.
SI = TechCal(
    name="si",
    cell_x_nm=180.0, cell_y_nm=100.0, layer_height_nm=70.0,
    array_efficiency=0.342, layers_target=137,
    c_bl_per_layer_ff=0.030, c_sel_junction_ff=0.30, c_global_strap_ff=1.20,
    c_hcb_pad_ff=0.60, c_blsa_in_ff=0.40, c_route_extra_ff=0.0,
    r_on_cell_kohm=381.0, r_sel_kohm=12.0, r_local_bl_kohm=8.0,
    r_global_kohm=3.0, r_wl_kohm=40.0, c_wl_ff=50.0,
    sa_offset_mv=25.0, writeback_eff=0.9047,
    fbe_loss_mv=35.0, rh_loss_mv=25.0,
    hcb_route_span_um=0.3907,
    t_overhead_ns=2.0, sa_tau_ns=1.2, r_pre_kohm=8.0, r_sa_drive_kohm=8.0,
    sa_offset_sigma_mv=5.0, vth_sigma_mv=25.0, vth_overdrive_v=0.60,
    # epi-Si mold: moderate die-level shift, strap-correlated gradient
    mc_die_sigma_frac=0.15, mc_mat_sigma_frac=0.25, mc_corr_length=0.25,
)

# AOS (W-doped In2O3, IWO-calibrated) channel, Si-deposition mold, channel-last
# + inner contact.  Tighter iso-etch pitch (115 nm), taller tier (79 nm).
#   C_BL = 87*0.030 + 0.30 + 1.20 + 0.60 + 0.40 = 5.11 fF
#   No floating body (oxide channel) -> fbe_loss = 0, better write-back.
AOS = TechCal(
    name="aos",
    cell_x_nm=115.0, cell_y_nm=100.0, layer_height_nm=79.0,
    array_efficiency=0.344, layers_target=87,
    c_bl_per_layer_ff=0.030, c_sel_junction_ff=0.30, c_global_strap_ff=1.20,
    c_hcb_pad_ff=0.60, c_blsa_in_ff=0.40, c_route_extra_ff=0.0,
    r_on_cell_kohm=420.0, r_sel_kohm=12.0, r_local_bl_kohm=6.0,
    r_global_kohm=3.0, r_wl_kohm=40.0, c_wl_ff=50.0,
    sa_offset_mv=25.0, writeback_eff=0.95,
    fbe_loss_mv=0.0, rh_loss_mv=25.0,
    hcb_route_span_um=0.4178,
    t_overhead_ns=2.0, sa_tau_ns=1.2, r_pre_kohm=8.0, r_sa_drive_kohm=8.0,
    # amorphous-oxide channels carry a wider Vth distribution than epi-Si
    sa_offset_sigma_mv=5.0, vth_sigma_mv=35.0, vth_overdrive_v=0.55,
    # deposition-temperature gradients correlate AOS mats more strongly
    mc_die_sigma_frac=0.20, mc_mat_sigma_frac=0.30, mc_corr_length=0.20,
)

# D1b 2D baseline (TechInsights-anchored): planar 4F^2-ish cell, long lateral
# BL (C_BL = 20 fF) and WL, periphery on the same die (no CBA).
#   Mature process: best write-back; but lateral routing adds C and the WL RC
#   plus IO path dominate tRC.
D1B = TechCal(
    name="d1b",
    cell_x_nm=0.0, cell_y_nm=0.0, layer_height_nm=0.0,
    array_efficiency=0.55, layers_target=1,
    c_bl_per_layer_ff=0.0, c_sel_junction_ff=0.0, c_global_strap_ff=0.0,
    c_hcb_pad_ff=0.0, c_blsa_in_ff=0.40, c_route_extra_ff=2.0,
    r_on_cell_kohm=160.0, r_sel_kohm=0.0, r_local_bl_kohm=40.0,
    r_global_kohm=0.0, r_wl_kohm=90.0, c_wl_ff=60.0,
    sa_offset_mv=25.0, writeback_eff=0.977,
    fbe_loss_mv=0.0, rh_loss_mv=12.0,
    hcb_route_span_um=0.0,
    t_overhead_ns=11.5, sa_tau_ns=1.2, r_pre_kohm=8.0, r_sa_drive_kohm=8.0,
    baseline_2d=True, allowed_schemes=("direct",), layer_grid=(1,),
    fixed_c_bl_ff=D1B_C_BL_FF, fixed_density_gb_mm2=D1B_BIT_DENSITY_GB_MM2,
    fixed_blsa_area_um2=D1B_BLSA_AREA_UM2, baseline_label="D1b 2D baseline",
    e_sa_fj=D1B_E_SA_FJ, vpp=VPP_D1B,
    # mature planar process: tighter spreads, large VPP=2.8 V overdrive
    sa_offset_sigma_mv=4.0, vth_sigma_mv=20.0, vth_overdrive_v=1.20,
    # mature planar line: weak die shift, mild long-range wafer gradient
    mc_die_sigma_frac=0.10, mc_mat_sigma_frac=0.15, mc_corr_length=0.40,
)


# --------------------------------------------------------------------------
# Technology registry
# --------------------------------------------------------------------------
# TECHS is the live registry: `register_tech` adds calibration corners
# without editing this module, and every DesignSpace builder reads it.

TECHS: dict = {}


def register_tech(tech: TechCal, overwrite: bool = False) -> TechCal:
    """Register a technology corner so DSE builders can sweep it.

    The tech's declarative capability fields (`baseline_2d`,
    `allowed_schemes`, `layer_grid`) tell the design-space builders how to
    sweep it — no name-based special cases anywhere downstream.
    """
    if not tech.name:
        raise ValueError("technology must have a non-empty name")
    if tech.name in TECHS and not overwrite:
        raise ValueError(f"technology {tech.name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    TECHS[tech.name] = tech
    return tech


def unregister_tech(name: str) -> None:
    """Remove a registered technology (primarily for test cleanup)."""
    TECHS.pop(name, None)


def get_tech(name: str) -> TechCal:
    try:
        return TECHS[name]
    except KeyError:
        raise KeyError(f"unknown technology {name!r}; registered: "
                       f"{sorted(TECHS)}") from None


for _tech in (SI, AOS, D1B):
    register_tech(_tech)
del _tech

# Strap organization (Fig. 5): 16 WLs and 8 BLs share one strap region.
WLS_PER_STRAP = 16
BLS_PER_STRAP = 8

# Number of strap-groups hanging on one global line when *no* selector
# isolates them (the plain "BL strapping" scheme (b)).
STRAPS_PER_GLOBAL = 4

DENSITY_TARGET_GB_MM2 = 2.6
