"""RC netlist assembly for the sensing path (the paper's SPICE deck, Fig. 7).

Topology (single-ended half of the open-BL pair), node order:

   0: BLSA / global sense node      (C_global + C_hcb + C_sa [+ C_unsel])
   1..K: local-BL segments          (C_local split into K lumps)
   K+1: storage node                (Cs)

 branches:
   0-1        : R_global + R_selector (scheme dependent)
   i-(i+1)    : R_local / K  (distributed local BL)
   K-(K+1)    : access transistor (time-varying: scaled by the WL ramp)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .parasitics import bl_parasitics, bl_parasitics_lowered

N_BL_SEGMENTS = 4
N_NODES = N_BL_SEGMENTS + 2


@dataclass(frozen=True)
class Ladder:
    """Batched ladder: arrays shaped (B, N) / (B, N-1)."""
    c: jnp.ndarray          # node capacitances (fF)
    g_branch: jnp.ndarray   # branch conductances (1/kOhm); last = access @ scale 1
    tech_name: str
    scheme: str

    @property
    def n_nodes(self) -> int:
        return self.c.shape[-1]


def assemble_ladder_arrays(par, r_local_bl_kohm):
    """(B, N) node caps + (B, N-1) branch conductances from a parasitic
    decomposition.

    `par` holds (B,)-shaped `BLParasitics` arrays; `r_local_bl_kohm` may be
    a scalar (one tech) or a (B,) array (the lowered DSE path) — the
    assembly is identical, so the two paths cannot drift.
    """
    b = par.c_local_ff.shape[0]
    k = N_BL_SEGMENTS

    c = jnp.zeros((b, N_NODES), jnp.float32)
    # sense node: global metal + pad + SA input + (non-isolated straps)
    c = c.at[:, 0].set(par.c_global_ff + par.c_sa_ff + par.c_unselected_ff)
    # distributed local BL
    c = c.at[:, 1:k + 1].set((par.c_local_ff / k)[:, None])
    # storage node
    c = c.at[:, k + 1].set(cal.CS_FF)

    g = jnp.zeros((b, N_NODES - 1), jnp.float32)
    r_front = par.r_path_kohm - r_local_bl_kohm       # selector+global part
    r_front = jnp.maximum(r_front, 0.05)
    g = g.at[:, 0].set(1.0 / r_front)
    r_seg = jnp.maximum(jnp.asarray(r_local_bl_kohm, jnp.float32) / k, 0.05)
    inv_seg = 1.0 / r_seg
    g = g.at[:, 1:k].set(inv_seg if inv_seg.ndim == 0 else inv_seg[:, None])
    g = g.at[:, k].set(1.0 / par.r_on_kohm)           # access transistor
    return c, g


def build_bl_ladder(tech: TechCal, scheme: str, layers) -> Ladder:
    """Assemble the batched sensing-path ladder for a technology/scheme.

    `layers` may be a scalar or a 1-D array of design points (the batch).
    """
    layers = jnp.atleast_1d(jnp.asarray(layers, jnp.float32))
    par = bl_parasitics(tech, scheme, layers)
    c, g = assemble_ladder_arrays(par, tech.r_local_bl_kohm)
    return Ladder(c=c, g_branch=g, tech_name=tech.name, scheme=scheme)


def build_ladder_lowered(view, par=None):
    """(B, N) / (B, N-1) ladder arrays over a lowered design space.

    Pass `par` to reuse an already-assembled `BLParasitics` (the DSE sweep
    computes it once for every metric).  Returns plain (c, g) arrays — the
    fused transient engine consumes them directly.
    """
    if par is None:
        par = bl_parasitics_lowered(view)
    return assemble_ladder_arrays(par, view.tech("r_local_bl_kohm"))


def replica_ladder_arrays(c: jnp.ndarray, g_branch: jnp.ndarray,
                          replica_cells) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Derive the replica-bitline ladder from a main-array ladder.

    The replica column shares the bitline's routing parasitics (all BL
    nodes and branches are identical) but ganged `replica_cells` dummy
    cells dump charge together: the storage node capacitance and the
    access-transistor conductance both scale by the cell count, so the
    replica develops signal faster than the worst-case main bitline by a
    calibratable margin.  `replica_cells` may be a scalar (one tech) or a
    (B,) array (the lowered DSE path).

    c        : (B, N)   main-ladder node capacitances
    g_branch : (B, N-1) main-ladder branch conductances
    Returns (c_replica, g_replica) with the same shapes.
    """
    cells = jnp.asarray(replica_cells, jnp.float32)
    c_rep = c.at[:, -1].mul(cells)          # ganged storage caps
    g_rep = g_branch.at[:, -1].mul(cells)   # parallel access transistors
    return c_rep, g_rep


def effective_cbl_ff(tech: TechCal, scheme: str, layers) -> jnp.ndarray:
    """Effective C_BL (all capacitance the cell must share charge with)."""
    return bl_parasitics(tech, scheme, layers).c_bl_total_ff


def effective_cbl_lowered(view) -> jnp.ndarray:
    """Array-native effective C_BL over a lowered design space."""
    return bl_parasitics_lowered(view).c_bl_total_ff
