"""Declarative design spaces and their lowering to flat operand arrays.

This is the entry half of the array-native DSE API:

    space = DesignSpace.paper_grid()              # declarative builder
    batch = dse.sweep(space)                      # one vectorized pass
    front = dse.pareto_front(batch)               # masked array dominance

A `DesignSpace` is a *declaration* — which (tech, scheme, layer) points to
evaluate, plus optional corner axes — and `lower()` turns it into the
canonical structure-of-arrays form (`LoweredSpace`) every physics module
consumes: a flat batch of per-point indices with gather helpers.  Techs
and schemes come from the live registries (`calibration.register_tech`,
`routing.register_scheme`); per-tech capability flags (`baseline_2d`,
`allowed_schemes`, `layer_grid`) replace the old name-based special cases,
so registered corners sweep correctly without touching this module.

LoweredSpace protocol (duck-typed; physics modules take any `view` with):

    view.layers          (B,) jnp.float32 layer counts
    view.valid           (B,) bool mask (False rows are padding)
    view.tech(field)     (B,) gather of a TechCal field per point
    view.scheme(field)   (B,) gather of a SchemeSpec field per point
    view.corner(name, d) (B,) corner-axis values, or the scalar default

Monte-Carlo sampling (`with_mc`) rides the same per-row channel: lowering
fans every design point out to N sampled rows (sample-major) and injects
the draws as reserved `mc_*` corner arrays (`mc_sa_offset_mv`,
`mc_delta_vth_mv`), so the physics modules pick them up through
`view.corner` with no new protocol and the whole sampled space is still
ONE flat batch through the fused row-cycle engine.

The flat batch axis is also the sharding axis: `dse.sweep(space,
sharding=mesh)` distributes the lowered operand batch over a device mesh
(`repro.launch.shard`), one slab per device, with identical results to
the single-host sweep.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace

import numpy as np
import jax.numpy as jnp

from . import calibration as cal
from . import routing

# The paper's layer-count sweep grid (Figs. 9a/9b x-axis anchors).
DEFAULT_LAYER_GRID = (32, 48, 64, 87, 100, 120, 137, 160, 200)

# Reserved per-row channels injected by Monte-Carlo lowering; user corner
# axes must not collide with these (`with_corners` rejects the prefix).
MC_AXES = ("mc_sa_offset_mv", "mc_delta_vth_mv")


def _key_entropy(key) -> tuple:
    """Normalize an MC key (int seed or JAX PRNG key) to a hashable
    entropy tuple for `np.random.default_rng` (SeedSequence entropy)."""
    if isinstance(key, (int, np.integer)):
        return (int(key),)
    try:
        import jax
        key = jax.random.key_data(key)
    except Exception:
        pass
    return tuple(int(x) for x in np.asarray(key, np.uint32).reshape(-1))


@dataclass(frozen=True)
class MCConfig:
    """Monte-Carlo sampling declaration attached by `with_mc`.

    `sa_offset_sigma_mv` / `vth_sigma_mv` of None mean "use each tech's
    calibrated sigma fields"; explicit values override every tech (the
    sigma=0 escape hatch reproduces the nominal sweep exactly).
    """
    samples: int
    entropy: tuple
    sa_offset_sigma_mv: float | None = None
    vth_sigma_mv: float | None = None


@dataclass(frozen=True)
class LoweredSpace:
    """Canonical flat form of a DesignSpace: one row per design point."""

    tech_names: tuple
    scheme_names: tuple
    tech_idx: np.ndarray        # (B,) int32 into tech_names
    scheme_idx: np.ndarray      # (B,) int32 into scheme_names
    layers_np: np.ndarray       # (B,) float32
    valid: np.ndarray           # (B,) bool
    corners: dict = field(default_factory=dict)
    samples: int = 1            # MC fan-out (B = samples * base points)

    def __len__(self) -> int:
        return int(self.tech_idx.shape[0])

    @property
    def base_len(self) -> int:
        """Design points per MC sample — the segment length of the
        sample-major layout (== len(self) when no `with_mc`)."""
        return len(self) // self.samples

    @property
    def layers(self) -> jnp.ndarray:
        return jnp.asarray(self.layers_np, jnp.float32)

    def tech(self, fieldname: str) -> np.ndarray:
        """Per-point gather of a TechCal field."""
        vals = [getattr(cal.get_tech(n), fieldname) for n in self.tech_names]
        return np.asarray(vals)[self.tech_idx]

    def scheme(self, fieldname: str) -> np.ndarray:
        """Per-point gather of a SchemeSpec field."""
        vals = [getattr(routing.scheme_spec(n), fieldname)
                for n in self.scheme_names]
        return np.asarray(vals)[self.scheme_idx]

    def corner(self, name: str, default):
        """Per-point corner-axis values, or the scalar default when the
        space declared no such axis."""
        if name in self.corners:
            return jnp.asarray(self.corners[name], jnp.float32)
        return default


def _as_layer_tuple(layers) -> tuple:
    if np.isscalar(layers):
        return (float(layers),)
    return tuple(float(x) for x in np.asarray(layers).reshape(-1))


@dataclass(frozen=True)
class DesignSpace:
    """Declarative (tech x scheme x layers [x corners]) design space.

    Build with `paper_grid()` / `product()` / `points()`, compose with
    `+`, add Monte-Carlo-style axes with `with_corners()`, then hand to
    `dse.sweep` (which calls `lower()` internally).
    """

    entries: tuple = ()          # ((tech_name, scheme_name, layers), ...)
    corner_axes: tuple = ()      # ((axis_name, values), ...)
    mc: MCConfig | None = None   # Monte-Carlo sampling (with_mc)

    # ---------------------------------------------------------- builders --
    @classmethod
    def product(cls, techs=None, schemes=None, layers=None) -> "DesignSpace":
        """Cross product honouring per-tech capability flags.

        `techs=None` sweeps every registered technology.  For each tech:
        `schemes=None` uses its `allowed_schemes` declaration (or every
        registered scheme); an explicit `schemes` is *filtered* by
        `allowed_schemes`, so a 2D baseline never sweeps bonded routing.
        A declared per-tech `layer_grid` always wins over `layers` (a
        baseline is only valid at its own layer count); `layers=None`
        falls back to the tech's `layers_target`.
        """
        tech_names = tuple(techs) if techs is not None else tuple(cal.TECHS)
        entries = []
        for tname in tech_names:
            tech = cal.get_tech(tname)
            allowed = tech.allowed_schemes
            if schemes is None:
                tech_schemes = allowed or tuple(routing.SCHEMES)
            else:
                tech_schemes = tuple(s for s in schemes
                                     if allowed is None or s in allowed)
            if tech.layer_grid is not None:
                grid = _as_layer_tuple(tech.layer_grid)
            elif layers is not None:
                grid = _as_layer_tuple(layers)
            else:
                grid = (float(tech.layers_target),)
            for sname in tech_schemes:
                routing.scheme_spec(sname)      # fail fast on unknown names
                entries.append((tname, sname, grid))
        return cls(entries=tuple(entries))

    @classmethod
    def paper_grid(cls, layer_grid=None) -> "DesignSpace":
        """The paper's full sweep: every registered tech x its allowed
        schemes x the layer grid (baselines contribute their own grid)."""
        grid = DEFAULT_LAYER_GRID if layer_grid is None else layer_grid
        return cls.product(layers=grid)

    @classmethod
    def paper_targets(cls) -> "DesignSpace":
        """One Table-1 point per registered tech: its target layer count on
        its flagship scheme (the first allowed scheme for constrained
        techs, selector+strap otherwise)."""
        pts = []
        for tech in cal.TECHS.values():
            scheme = (tech.allowed_schemes[0] if tech.allowed_schemes
                      else "sel_strap")
            pts.append((tech.name, scheme, tech.layers_target))
        return cls.points(pts)

    @classmethod
    def points(cls, pts) -> "DesignSpace":
        """Explicit design points: iterable of (tech, scheme, layers)."""
        entries = []
        for tname, sname, layers in pts:
            cal.get_tech(tname)
            routing.scheme_spec(sname)
            entries.append((tname, sname, _as_layer_tuple(layers)))
        return cls(entries=tuple(entries))

    # ------------------------------------------------------- composition --
    def __add__(self, other: "DesignSpace") -> "DesignSpace":
        if self.corner_axes != other.corner_axes:
            raise ValueError("cannot concatenate DesignSpaces with "
                             "different corner axes")
        if self.mc != other.mc:
            raise ValueError("cannot concatenate DesignSpaces with "
                             "different Monte-Carlo declarations")
        return replace(self, entries=self.entries + other.entries)

    def with_corners(self, **axes) -> "DesignSpace":
        """Attach corner axes (e.g. disturb-duty distributions for the
        Monte-Carlo ROADMAP item).  Each axis multiplies the batch: corners
        are just more rows of the same flat sweep.

        Axis semantics are defined by the consuming model — `dse.sweep`
        currently understands `rh_toggles` and `trc_cycles` (disturb duty).
        """
        new = list(self.corner_axes)
        declared = {n for n, _ in new}
        for name, values in axes.items():
            if name.startswith("mc_"):
                raise ValueError(f"corner axis {name!r}: the 'mc_' prefix "
                                 "is reserved for with_mc sampling channels")
            if name in declared:
                raise ValueError(f"corner axis {name!r} already declared")
            vals = tuple(float(v) for v in np.asarray(values).reshape(-1))
            if not vals:
                raise ValueError(f"corner axis {name!r} has no values")
            new.append((name, vals))
            declared.add(name)
        return replace(self, corner_axes=tuple(new))

    def with_mc(self, samples: int, key=0,
                sa_offset_sigma_mv: float | None = None,
                vth_sigma_mv: float | None = None) -> "DesignSpace":
        """Declare Monte-Carlo variation sampling: every design point fans
        out to `samples` rows of the SAME flat batch (sample-major), each
        with an independently drawn BLSA offset and access-transistor Vth
        perturbation.

        Draws are deterministic in `key` (an int seed or a JAX PRNG key):
        the same key lowers to bit-identical sample rows, so downstream
        yield columns are reproducible.  Sigmas default to each tech's
        calibrated `sa_offset_sigma_mv` / `vth_sigma_mv` fields; explicit
        overrides apply to every tech (`sigma=0` with `samples=1`
        reproduces the nominal sweep exactly).
        """
        samples = int(samples)
        if samples < 1:
            raise ValueError(f"with_mc needs samples >= 1, got {samples}")
        if self.mc is not None:
            raise ValueError("Monte-Carlo sampling already declared on "
                             "this space")
        return replace(self, mc=MCConfig(
            samples=samples, entropy=_key_entropy(key),
            sa_offset_sigma_mv=sa_offset_sigma_mv,
            vth_sigma_mv=vth_sigma_mv))

    # ---------------------------------------------------------- lowering --
    def __len__(self) -> int:
        base = sum(len(grid) for _, _, grid in self.entries)
        reps = 1
        for _, vals in self.corner_axes:
            reps *= len(vals)
        if self.mc is not None:
            reps *= self.mc.samples
        return base * reps

    def lower(self) -> LoweredSpace:
        """Lower to the canonical flat structure-of-arrays form.

        Row order is entry-major (techs in declaration order, schemes and
        layers nested), with the corner-combo product outermost — so the
        first base-block of a cornered space is its first corner combo.
        Monte-Carlo sampling is outermost of all: sample s of base row i
        lands at flat row `s * base + i`, which is what the DesignBatch
        segment reductions (`yield_fraction`/`quantile`) assume.
        """
        if not self.entries:
            raise ValueError(
                "design space is empty — note that product() filters "
                "explicit schemes by each tech's allowed_schemes, which can "
                "eliminate every (tech, scheme) pair")
        tech_names, scheme_names = [], []
        ti, si, ly = [], [], []
        for tname, sname, grid in self.entries:
            cal.get_tech(tname)
            routing.scheme_spec(sname)
            if tname not in tech_names:
                tech_names.append(tname)
            if sname not in scheme_names:
                scheme_names.append(sname)
            for layer in grid:
                ti.append(tech_names.index(tname))
                si.append(scheme_names.index(sname))
                ly.append(layer)
        tech_idx = np.asarray(ti, np.int32)
        scheme_idx = np.asarray(si, np.int32)
        layers = np.asarray(ly, np.float32)
        b = layers.shape[0]

        corners: dict = {}
        if self.corner_axes:
            names = [n for n, _ in self.corner_axes]
            combos = list(itertools.product(
                *[vals for _, vals in self.corner_axes]))
            reps = len(combos)
            tech_idx = np.tile(tech_idx, reps)
            scheme_idx = np.tile(scheme_idx, reps)
            layers = np.tile(layers, reps)
            for a, name in enumerate(names):
                corners[name] = np.repeat(
                    np.asarray([combo[a] for combo in combos], np.float32), b)

        samples = 1
        if self.mc is not None:
            samples = self.mc.samples
            b0 = layers.shape[0]
            rng = np.random.default_rng(self.mc.entropy)
            z = rng.standard_normal((2, samples, b0))

            def gather(fieldname):
                vals = [getattr(cal.get_tech(n), fieldname)
                        for n in tech_names]
                return np.asarray(vals, np.float64)[tech_idx]

            mu_sa = gather("sa_offset_mv")
            sig_sa = (gather("sa_offset_sigma_mv")
                      if self.mc.sa_offset_sigma_mv is None
                      else np.full(b0, float(self.mc.sa_offset_sigma_mv)))
            sig_vth = (gather("vth_sigma_mv")
                       if self.mc.vth_sigma_mv is None
                       else np.full(b0, float(self.mc.vth_sigma_mv)))
            # offset magnitudes: a sample below 0 has no physical meaning
            mc_sa = np.maximum(mu_sa[None] + sig_sa[None] * z[0], 0.0)
            mc_dvth = sig_vth[None] * z[1]

            tech_idx = np.tile(tech_idx, samples)
            scheme_idx = np.tile(scheme_idx, samples)
            layers = np.tile(layers, samples)
            corners = {k: np.tile(v, samples) for k, v in corners.items()}
            corners["mc_sa_offset_mv"] = mc_sa.reshape(-1).astype(np.float32)
            corners["mc_delta_vth_mv"] = mc_dvth.reshape(-1).astype(np.float32)

        return LoweredSpace(
            tech_names=tuple(tech_names), scheme_names=tuple(scheme_names),
            tech_idx=tech_idx, scheme_idx=scheme_idx, layers_np=layers,
            valid=np.ones(layers.shape[0], bool), corners=corners,
            samples=samples)
