"""Declarative design spaces and their lowering to flat operand arrays.

This is the entry half of the array-native DSE API:

    space = DesignSpace.paper_grid()              # declarative builder
    batch = dse.sweep(space)                      # one vectorized pass
    front = dse.pareto_front(batch)               # masked array dominance

A `DesignSpace` is a *declaration* — which (tech, scheme, layer) points to
evaluate, plus optional corner axes — and `lower()` turns it into the
canonical structure-of-arrays form (`LoweredSpace`) every physics module
consumes: a flat batch of per-point indices with gather helpers.  Techs
and schemes come from the live registries (`calibration.register_tech`,
`routing.register_scheme`); per-tech capability flags (`baseline_2d`,
`allowed_schemes`, `layer_grid`) replace the old name-based special cases,
so registered corners sweep correctly without touching this module.

LoweredSpace protocol (duck-typed; physics modules take any `view` with):

    view.layers          (B,) jnp.float32 layer counts
    view.valid           (B,) bool mask (False rows are padding)
    view.tech(field)     (B,) gather of a TechCal field per point
    view.scheme(field)   (B,) gather of a SchemeSpec field per point
    view.corner(name, d) (B,) corner-axis values, or the scalar default

Monte-Carlo sampling (`with_mc`) rides the same per-row channel: lowering
fans every design point out to N sampled rows (sample-major) and injects
the draws as reserved `mc_*` corner arrays (`mc_sa_offset_mv`,
`mc_delta_vth_mv`), so the physics modules pick them up through
`view.corner` with no new protocol and the whole sampled space is still
ONE flat batch through the fused row-cycle engine.  Correlated
within-die variation (`corr=`) composes each draw as `global_die +
mat_gradient + local` via low-rank factor draws before the reshape, and
an importance-sampling tail proposal (`tail_shift`/`tail_scale=`) adds
the per-row log-weight channel `mc_log_w` the DesignBatch reductions
consume.

The flat batch axis is also the sharding axis: `dse.sweep(space,
sharding=mesh)` distributes the lowered operand batch over a device mesh
(`repro.launch.shard`), one slab per device, with identical results to
the single-host sweep.
"""

from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass, field, replace

import numpy as np
import jax
import jax.numpy as jnp

from . import calibration as cal
from . import routing

# The paper's layer-count sweep grid (Figs. 9a/9b x-axis anchors).
DEFAULT_LAYER_GRID = (32, 48, 64, 87, 100, 120, 137, 160, 200)

# Reserved per-row channels injected by Monte-Carlo lowering; user corner
# axes must not collide with these (`with_corners` rejects the prefix).
MC_AXES = ("mc_sa_offset_mv", "mc_delta_vth_mv")

# Reserved per-row importance-sampling log-weight channel: present only
# when `with_mc` declares a shifted/scaled proposal (tail_shift/tail_scale),
# so the uniform-weight path through every DesignBatch reduction stays
# bit-identical to the plain i.i.d. estimators.
MC_LOG_W = "mc_log_w"

# Rank of the low-rank factor basis behind the correlated mat/strap
# gradient (Karhunen-Loeve-style cosine features of a squared-exponential
# kernel).  Eight factors resolve correlation lengths down to ~1/8 of the
# die span, which covers every calibrated `mc_corr_length`.
MC_GRADIENT_FACTORS = 8


def _key_entropy(key) -> tuple:
    """Normalize an MC key (int seed or JAX PRNG key) to a hashable
    entropy tuple for `np.random.default_rng` (SeedSequence entropy)."""
    if isinstance(key, (int, np.integer)):
        return (int(key),)
    with contextlib.suppress(Exception):
        import jax
        key = jax.random.key_data(key)
    return tuple(int(x) for x in np.asarray(key, np.uint32).reshape(-1))


@dataclass(frozen=True)
class MCConfig:
    """Monte-Carlo sampling declaration attached by `with_mc`.

    `sa_offset_sigma_mv` / `vth_sigma_mv` of None mean "use each tech's
    calibrated sigma fields"; explicit values override every tech (the
    sigma=0 escape hatch reproduces the nominal sweep exactly).

    `corr` scales each tech's calibrated within-die correlation fractions
    (`mc_die_sigma_frac` / `mc_mat_sigma_frac`): 0 keeps the draws purely
    i.i.d. (bit-identical to the uncorrelated lowering), 1 applies the
    calibrated decomposition in full.

    `tail_shift` / `tail_scale` declare an importance-sampling proposal on
    the *local* standardized draws — z ~ N(tail_shift, tail_scale^2)
    instead of N(0, 1), shifted toward the failure tail (larger SA offset,
    slower access Vth) — whose exact per-row density-ratio log-weights are
    lowered as the reserved `mc_log_w` channel.  Both are per-channel
    (SA offset, Vth) 2-tuples; `with_mc` broadcasts scalars.  Shift only
    the channel(s) a spec constrains: an unconstrained shifted channel
    costs pure weight variance.
    """
    samples: int
    entropy: tuple
    sa_offset_sigma_mv: float | None = None
    vth_sigma_mv: float | None = None
    corr: float = 0.0
    tail_shift: tuple = (0.0, 0.0)
    tail_scale: tuple = (1.0, 1.0)

    @property
    def is_active(self) -> bool:
        """Whether the proposal differs from the target (weights ride)."""
        return (any(s != 0.0 for s in self.tail_shift)
                or any(s != 1.0 for s in self.tail_scale))


@dataclass(frozen=True)
class LoweredSpace:
    """Canonical flat form of a DesignSpace: one row per design point."""

    tech_names: tuple
    scheme_names: tuple
    tech_idx: np.ndarray        # (B,) int32 into tech_names
    scheme_idx: np.ndarray      # (B,) int32 into scheme_names
    layers_np: np.ndarray       # (B,) float32
    valid: np.ndarray           # (B,) bool
    corners: dict = field(default_factory=dict)
    samples: int = 1            # MC fan-out (B = samples * base points)
    replica: bool = False       # replica-closed SA-enable timing: the
    #                             operand lowering adds one replica row
    #                             per design point (len(self) unchanged)

    def __len__(self) -> int:
        return int(self.tech_idx.shape[0])

    @property
    def base_len(self) -> int:
        """Design points per MC sample — the segment length of the
        sample-major layout (== len(self) when no `with_mc`)."""
        return len(self) // self.samples

    @property
    def layers(self) -> jnp.ndarray:
        return jnp.asarray(self.layers_np, jnp.float32)

    def tech(self, fieldname: str) -> np.ndarray:
        """Per-point gather of a TechCal field."""
        vals = [getattr(cal.get_tech(n), fieldname) for n in self.tech_names]
        return np.asarray(vals)[self.tech_idx]

    def scheme(self, fieldname: str) -> np.ndarray:
        """Per-point gather of a SchemeSpec field."""
        vals = [getattr(routing.scheme_spec(n), fieldname)
                for n in self.scheme_names]
        return np.asarray(vals)[self.scheme_idx]

    def corner(self, name: str, default):
        """Per-point corner-axis values, or the scalar default when the
        space declared no such axis."""
        if name in self.corners:
            return jnp.asarray(self.corners[name], jnp.float32)
        return default


def _opaque_table(vals: np.ndarray) -> jnp.ndarray:
    """Calibration table as runtime data, not a foldable constant.

    Inside jit, a registry table baked as a literal lets XLA constant-fold
    the gather (and arithmetic downstream of it) at compile time — so two
    spaces whose per-row VALUES are bit-identical but whose (static) name
    tuples differ would compile to different arithmetic and drift by an
    ulp.  The barrier makes every compiled program compute from opaque
    runtime tables, exactly like the eager/host gather path, which is
    what keeps chunked, sharded and monolithic sweeps bit-identical.
    """
    return jax.lax.optimization_barrier(jnp.asarray(vals))


@dataclass(frozen=True)
class SpaceView:
    """Device-side twin of `LoweredSpace`: the same duck-typed protocol
    (`layers` / `valid` / `tech()` / `scheme()` / `corner()`), but every
    per-point array is a jnp leaf and the calibration gathers are jnp
    ops, so a view can flow through jit / shard_map.  This is what lets
    the whole DSE metric pipeline run *inside* the sharded dispatch
    (`repro.launch.shard`), one batch slab per device, instead of
    materializing host-side (B,) arrays.

    Registered as a pytree: the index/layer/valid/corner arrays are
    leaves (sharded over the batch axis by the driver); the name tuples
    and the MC layout are static aux data, so every space with the same
    structure shares one jit cache entry.  Calibration tables are read
    from the live registries at trace time through the static name
    tuples and baked into the compiled program as constants — the same
    registry values the host path reads.
    """

    tech_names: tuple
    scheme_names: tuple
    tech_idx: jnp.ndarray       # (B,) int32 into tech_names
    scheme_idx: jnp.ndarray     # (B,) int32 into scheme_names
    layers: jnp.ndarray         # (B,) float32
    valid: jnp.ndarray          # (B,) bool
    corners: dict
    samples: int = 1
    replica: bool = False

    @classmethod
    def from_lowered(cls, sp: "LoweredSpace") -> "SpaceView":
        return cls(
            tech_names=tuple(sp.tech_names),
            scheme_names=tuple(sp.scheme_names),
            tech_idx=jnp.asarray(sp.tech_idx, jnp.int32),
            scheme_idx=jnp.asarray(sp.scheme_idx, jnp.int32),
            layers=jnp.asarray(sp.layers_np, jnp.float32),
            valid=jnp.asarray(sp.valid),
            corners={k: jnp.asarray(v, jnp.float32)
                     for k, v in sp.corners.items()},
            samples=sp.samples, replica=bool(sp.replica))

    def __len__(self) -> int:
        return int(self.tech_idx.shape[0])

    @property
    def base_len(self) -> int:
        return len(self) // self.samples

    def tech(self, fieldname: str) -> jnp.ndarray:
        """Per-point gather of a TechCal field (jnp, trace-compatible)."""
        vals = np.asarray([getattr(cal.get_tech(n), fieldname)
                           for n in self.tech_names])
        return _opaque_table(vals)[self.tech_idx]

    def scheme(self, fieldname: str) -> jnp.ndarray:
        """Per-point gather of a SchemeSpec field (jnp, trace-compatible)."""
        vals = np.asarray([getattr(routing.scheme_spec(n), fieldname)
                           for n in self.scheme_names])
        return _opaque_table(vals)[self.scheme_idx]

    def corner(self, name: str, default):
        if name in self.corners:
            return self.corners[name]
        return default

    def pad_to(self, total: int) -> "SpaceView":
        """Append inactive rows (valid=False) up to `total` — the view
        counterpart of `transient._pad_operands`, so a padded dispatch
        slab scores padding rows with benign finite inputs and drops
        them on the host slice."""
        pad = total - len(self)
        if pad < 0:
            raise ValueError(f"pad_to({total}) smaller than view ({len(self)})")
        if pad == 0:
            return self
        pad1 = lambda x, v: jnp.pad(x, (0, pad), constant_values=v)
        return replace(
            self,
            tech_idx=pad1(self.tech_idx, 0), scheme_idx=pad1(self.scheme_idx, 0),
            layers=pad1(self.layers, 1.0), valid=pad1(self.valid, False),
            corners={k: pad1(v, 0.0) for k, v in self.corners.items()})

    def slice_rows(self, lo: int, hi: int) -> "SpaceView":
        """Contiguous row slab [lo, hi) — the elastic re-slabbing unit."""
        return replace(
            self,
            tech_idx=self.tech_idx[lo:hi], scheme_idx=self.scheme_idx[lo:hi],
            layers=self.layers[lo:hi], valid=self.valid[lo:hi],
            corners={k: v[lo:hi] for k, v in self.corners.items()})


jax.tree_util.register_dataclass(
    SpaceView,
    data_fields=("tech_idx", "scheme_idx", "layers", "valid", "corners"),
    meta_fields=("tech_names", "scheme_names", "samples", "replica"))


def _gradient_basis(positions: np.ndarray, corr_length: np.ndarray,
                    n_factors: int = MC_GRADIENT_FACTORS) -> np.ndarray:
    """Low-rank basis of the correlated mat/strap gradient -> (b, K).

    Cosine features weighted by a squared-exponential spectrum and
    row-normalized to unit marginal variance: a gradient draw is
    `g[s] = basis @ w[s]` with `w ~ N(0, I_K)`, so `g` has unit variance
    per row and `corr(g_i, g_j) = basis_i . basis_j`, decaying with the
    row distance `|x_i - x_j|` on the scale of `corr_length` (both in
    die-span units).  In the long-correlation limit the k=0 (constant)
    feature dominates and the gradient degenerates into a shared offset.
    """
    x = np.asarray(positions, np.float64).reshape(-1, 1)        # (b, 1)
    ell = np.asarray(corr_length, np.float64).reshape(-1, 1)    # (b, 1)
    k = np.arange(n_factors, dtype=np.float64)[None, :]         # (1, K)
    lam = np.exp(-0.5 * (k * np.pi * np.maximum(ell, 1e-3)) ** 2)
    basis = np.sqrt(lam) * np.cos(k * np.pi * x)
    norm = np.sqrt((basis ** 2).sum(axis=1, keepdims=True))
    return basis / np.maximum(norm, 1e-30)


def _as_layer_tuple(layers) -> tuple:
    if np.isscalar(layers):
        return (float(layers),)
    return tuple(float(x) for x in np.asarray(layers).reshape(-1))


@dataclass(frozen=True)
class DesignSpace:
    """Declarative (tech x scheme x layers [x corners]) design space.

    Build with `paper_grid()` / `product()` / `points()`, compose with
    `+`, add Monte-Carlo-style axes with `with_corners()`, then hand to
    `dse.sweep` (which calls `lower()` internally).
    """

    entries: tuple = ()          # ((tech_name, scheme_name, layers), ...)
    corner_axes: tuple = ()      # ((axis_name, values), ...)
    mc: MCConfig | None = None   # Monte-Carlo sampling (with_mc)
    replica: bool = False        # replica-closed SA timing (with_replica)

    # ---------------------------------------------------------- builders --
    @classmethod
    def product(cls, techs=None, schemes=None, layers=None) -> "DesignSpace":
        """Cross product honouring per-tech capability flags.

        `techs=None` sweeps every registered technology.  For each tech:
        `schemes=None` uses its `allowed_schemes` declaration (or every
        registered scheme); an explicit `schemes` is *filtered* by
        `allowed_schemes`, so a 2D baseline never sweeps bonded routing.
        A declared per-tech `layer_grid` always wins over `layers` (a
        baseline is only valid at its own layer count); `layers=None`
        falls back to the tech's `layers_target`.
        """
        tech_names = tuple(techs) if techs is not None else tuple(cal.TECHS)
        entries = []
        for tname in tech_names:
            tech = cal.get_tech(tname)
            allowed = tech.allowed_schemes
            tech_schemes = (
                (allowed or tuple(routing.SCHEMES)) if schemes is None
                else tuple(s for s in schemes
                           if allowed is None or s in allowed))
            if tech.layer_grid is not None:
                grid = _as_layer_tuple(tech.layer_grid)
            elif layers is not None:
                grid = _as_layer_tuple(layers)
            else:
                grid = (float(tech.layers_target),)
            for sname in tech_schemes:
                routing.scheme_spec(sname)      # fail fast on unknown names
                entries.append((tname, sname, grid))
        return cls(entries=tuple(entries))

    @classmethod
    def paper_grid(cls, layer_grid=None) -> "DesignSpace":
        """The paper's full sweep: every registered tech x its allowed
        schemes x the layer grid (baselines contribute their own grid)."""
        grid = DEFAULT_LAYER_GRID if layer_grid is None else layer_grid
        return cls.product(layers=grid)

    @classmethod
    def paper_targets(cls) -> "DesignSpace":
        """One Table-1 point per registered tech: its target layer count on
        its flagship scheme (the first allowed scheme for constrained
        techs, selector+strap otherwise)."""
        pts = []
        for tech in cal.TECHS.values():
            scheme = (tech.allowed_schemes[0] if tech.allowed_schemes
                      else "sel_strap")
            pts.append((tech.name, scheme, tech.layers_target))
        return cls.points(pts)

    @classmethod
    def points(cls, pts) -> "DesignSpace":
        """Explicit design points: iterable of (tech, scheme, layers)."""
        entries = []
        for tname, sname, layers in pts:
            cal.get_tech(tname)
            routing.scheme_spec(sname)
            entries.append((tname, sname, _as_layer_tuple(layers)))
        return cls(entries=tuple(entries))

    # ------------------------------------------------------- composition --
    def __add__(self, other: "DesignSpace") -> "DesignSpace":
        if self.corner_axes != other.corner_axes:
            raise ValueError("cannot concatenate DesignSpaces with "
                             "different corner axes")
        if self.mc != other.mc:
            raise ValueError("cannot concatenate DesignSpaces with "
                             "different Monte-Carlo declarations")
        if self.replica != other.replica:
            raise ValueError("cannot concatenate DesignSpaces with "
                             "different replica-timing declarations")
        return replace(self, entries=self.entries + other.entries)

    def with_replica(self, enabled: bool = True) -> "DesignSpace":
        """Close the SA-enable timing with a replica bitline.

        Every design point gains a dummy replica column (same lowered
        parasitics, storage scaled by the tech's `replica_cells` field)
        whose own 90% crossing fires the main array's SA enable, so
        t_sense self-adjusts per corner and per MC sample instead of
        being the fixed own-crossing time.  The space's length and row
        order are unchanged — the replica rows live only inside the
        fused-engine operand batch — so `with_mc`, corner axes, sharding
        and the IS tail-yield estimators compose unchanged.
        """
        return replace(self, replica=bool(enabled))

    def with_corners(self, **axes) -> "DesignSpace":
        """Attach corner axes (e.g. disturb-duty distributions for the
        Monte-Carlo ROADMAP item).  Each axis multiplies the batch: corners
        are just more rows of the same flat sweep.

        Axis semantics are defined by the consuming model — `dse.sweep`
        currently understands `rh_toggles` and `trc_cycles` (disturb duty).
        """
        new = list(self.corner_axes)
        declared = {n for n, _ in new}
        for name, values in axes.items():
            if name.startswith("mc_"):
                raise ValueError(f"corner axis {name!r}: the 'mc_' prefix "
                                 "is reserved for with_mc sampling channels")
            if name in declared:
                raise ValueError(f"corner axis {name!r} already declared")
            vals = tuple(float(v) for v in np.asarray(values).reshape(-1))
            if not vals:
                raise ValueError(f"corner axis {name!r} has no values")
            new.append((name, vals))
            declared.add(name)
        return replace(self, corner_axes=tuple(new))

    def with_mc(self, samples: int, key=0,
                sa_offset_sigma_mv: float | None = None,
                vth_sigma_mv: float | None = None,
                corr: float = 0.0,
                tail_shift=0.0,
                tail_scale=1.0) -> "DesignSpace":
        """Declare Monte-Carlo variation sampling: every design point fans
        out to `samples` rows of the SAME flat batch (sample-major), each
        with a drawn BLSA offset and access-transistor Vth perturbation.

        Draws are deterministic in `key` (an int seed or a JAX PRNG key):
        the same key lowers to bit-identical sample rows, so downstream
        yield columns are reproducible.  Sigmas default to each tech's
        calibrated `sa_offset_sigma_mv` / `vth_sigma_mv` fields; explicit
        overrides apply to every tech (`sigma=0` with `samples=1`
        reproduces the nominal sweep exactly).

        `corr` in [0, 1] turns on correlated *within-die* variation: each
        standardized draw is composed as `global_die + mat_gradient +
        local` with the per-tech variance fractions (`mc_die_sigma_frac`,
        `mc_mat_sigma_frac`, scaled by `corr`) and a low-rank correlated
        gradient along the shared-mat axis (`mc_corr_length`).  `corr=0`
        (the default) reproduces the i.i.d. draws bit-for-bit.

        `tail_shift` / `tail_scale` declare an importance-sampling
        proposal for deep-tail (ppm) yield estimation: the local
        standardized draws come from N(tail_shift, tail_scale^2) — shifted
        toward the failure tail — and the exact per-row log-weights ride
        the batch as the reserved `mc_log_w` channel, which every
        DesignBatch reduction (`yield_fraction`/`quantile`/`mc_summary`/
        `yield_ppm`) consumes automatically.  Each accepts a scalar
        (applied to both channels) or a per-channel (SA offset, Vth)
        pair; shift only the channel(s) the target spec constrains — e.g.
        `tail_shift=(4.5, 0.0)` for a margin-only ppm floor — because an
        unconstrained shifted channel only adds weight variance.
        """
        samples = int(samples)
        if samples < 1:
            raise ValueError(f"with_mc needs samples >= 1, got {samples}")
        if self.mc is not None:
            raise ValueError("Monte-Carlo sampling already declared on "
                             "this space")
        corr = float(corr)
        if not 0.0 <= corr <= 1.0:
            raise ValueError(f"with_mc needs 0 <= corr <= 1, got {corr}")

        def per_channel(name, value):
            pair = (tuple(float(v) for v in value)
                    if np.ndim(value) else (float(value),) * 2)
            if len(pair) != 2:
                raise ValueError(f"with_mc {name} must be a scalar or a "
                                 f"(sa, vth) pair, got {value!r}")
            return pair

        shift = per_channel("tail_shift", tail_shift)
        scale = per_channel("tail_scale", tail_scale)
        if any(s <= 0.0 for s in scale):
            raise ValueError(f"with_mc needs tail_scale > 0, got {scale}")
        return replace(self, mc=MCConfig(
            samples=samples, entropy=_key_entropy(key),
            sa_offset_sigma_mv=sa_offset_sigma_mv,
            vth_sigma_mv=vth_sigma_mv, corr=corr,
            tail_shift=shift, tail_scale=scale))

    # ---------------------------------------------------------- lowering --
    def __len__(self) -> int:
        base = sum(len(grid) for _, _, grid in self.entries)
        reps = 1
        for _, vals in self.corner_axes:
            reps *= len(vals)
        if self.mc is not None:
            reps *= self.mc.samples
        return base * reps

    def lower(self) -> LoweredSpace:
        """Lower to the canonical flat structure-of-arrays form.

        Row order is entry-major (techs in declaration order, schemes and
        layers nested), with the corner-combo product outermost — so the
        first base-block of a cornered space is its first corner combo.
        Monte-Carlo sampling is outermost of all: sample s of base row i
        lands at flat row `s * base + i`, which is what the DesignBatch
        segment reductions (`yield_fraction`/`quantile`) assume.
        """
        if not self.entries:
            raise ValueError(
                "design space is empty — note that product() filters "
                "explicit schemes by each tech's allowed_schemes, which can "
                "eliminate every (tech, scheme) pair")
        tech_names, scheme_names = [], []
        ti, si, ly = [], [], []
        for tname, sname, grid in self.entries:
            cal.get_tech(tname)
            routing.scheme_spec(sname)
            if tname not in tech_names:
                tech_names.append(tname)
            if sname not in scheme_names:
                scheme_names.append(sname)
            for layer in grid:
                ti.append(tech_names.index(tname))
                si.append(scheme_names.index(sname))
                ly.append(layer)
        tech_idx = np.asarray(ti, np.int32)
        scheme_idx = np.asarray(si, np.int32)
        layers = np.asarray(ly, np.float32)
        b = layers.shape[0]

        corners: dict = {}
        if self.corner_axes:
            names = [n for n, _ in self.corner_axes]
            combos = list(itertools.product(
                *[vals for _, vals in self.corner_axes]))
            reps = len(combos)
            tech_idx = np.tile(tech_idx, reps)
            scheme_idx = np.tile(scheme_idx, reps)
            layers = np.tile(layers, reps)
            for a, name in enumerate(names):
                corners[name] = np.repeat(
                    np.asarray([combo[a] for combo in combos], np.float32), b)

        samples = 1
        if self.mc is not None:
            mc = self.mc
            samples = mc.samples
            b0 = layers.shape[0]
            rng = np.random.default_rng(mc.entropy)

            def gather(fieldname):
                vals = [getattr(cal.get_tech(n), fieldname)
                        for n in tech_names]
                return np.asarray(vals, np.float64)[tech_idx]

            # The local i.i.d. component comes FIRST and in one draw:
            # with corr=0 and no tail proposal it is the entire draw and
            # consumes the rng stream exactly like the original
            # uncorrelated lowering — bit-for-bit the same samples.
            z = rng.standard_normal((2, samples, b0))
            log_w = None
            if mc.is_active:
                # Shifted/scaled proposal on the local standardized draws;
                # the reserved mc_log_w channel carries the exact per-row
                # density ratio  log N(z|0,1) - log N(z|shift, scale^2),
                # summed over the SA-offset and Vth channels (per-channel
                # shift/scale, so an unshifted channel contributes no
                # weight variance).  Only the local component is
                # reweighted; the correlated die/gradient components below
                # stay target-distributed, so per-design estimators over
                # the sample axis remain exact.
                shift = np.asarray(mc.tail_shift,
                                   np.float64).reshape(2, 1, 1)
                scale = np.asarray(mc.tail_scale,
                                   np.float64).reshape(2, 1, 1)
                z = shift + scale * z
                log_w = (-0.5 * z ** 2
                         + 0.5 * ((z - shift) / scale) ** 2
                         + np.log(scale)).sum(axis=0)
            if mc.corr > 0.0:
                # Correlated within-die decomposition: a die-level offset
                # shared by every base row of a sample, plus a low-rank
                # mat/strap gradient along the base-row axis (the lowering
                # order is the mat order along the die span).
                f_die = mc.corr * gather("mc_die_sigma_frac")
                f_mat = mc.corr * gather("mc_mat_sigma_frac")
                over = f_die + f_mat > 1.0 + 1e-9
                if over.any():
                    bad = sorted({tech_names[t] for t in tech_idx[over]})
                    raise ValueError(
                        f"correlated-MC variance fractions of {bad} exceed "
                        "1 (mc_die_sigma_frac + mc_mat_sigma_frac scaled "
                        f"by corr={mc.corr} must stay <= 1)")
                z_die = rng.standard_normal((2, samples, 1))
                w_fac = rng.standard_normal(
                    (2, samples, MC_GRADIENT_FACTORS))
                pos = np.arange(b0, dtype=np.float64) / max(b0 - 1, 1)
                basis = _gradient_basis(pos, gather("mc_corr_length"))
                grad = np.einsum("csk,bk->csb", w_fac, basis)
                # clamp the local remainder: the guard above grants a
                # 1e-9 tolerance, so a sum at 1.0+eps must not sqrt a
                # negative number into NaN draws
                f_loc = np.maximum(1.0 - f_die - f_mat, 0.0)
                z = (np.sqrt(f_loc)[None, None] * z
                     + np.sqrt(f_die)[None, None] * z_die
                     + np.sqrt(f_mat)[None, None] * grad)

            mu_sa = gather("sa_offset_mv")
            sig_sa = (gather("sa_offset_sigma_mv")
                      if mc.sa_offset_sigma_mv is None
                      else np.full(b0, float(mc.sa_offset_sigma_mv)))
            sig_vth = (gather("vth_sigma_mv")
                       if mc.vth_sigma_mv is None
                       else np.full(b0, float(mc.vth_sigma_mv)))
            # offset magnitudes: a sample below 0 has no physical meaning
            mc_sa = np.maximum(mu_sa[None] + sig_sa[None] * z[0], 0.0)
            mc_dvth = sig_vth[None] * z[1]

            tech_idx = np.tile(tech_idx, samples)
            scheme_idx = np.tile(scheme_idx, samples)
            layers = np.tile(layers, samples)
            corners = {k: np.tile(v, samples) for k, v in corners.items()}
            corners["mc_sa_offset_mv"] = mc_sa.reshape(-1).astype(np.float32)
            corners["mc_delta_vth_mv"] = mc_dvth.reshape(-1).astype(np.float32)
            if log_w is not None:
                corners[MC_LOG_W] = log_w.reshape(-1).astype(np.float32)

        return LoweredSpace(
            tech_names=tuple(tech_names), scheme_names=tuple(scheme_names),
            tech_idx=tech_idx, scheme_idx=scheme_idx, layers_np=layers,
            valid=np.ones(layers.shape[0], bool), corners=corners,
            samples=samples, replica=self.replica)
