"""BL routing schemes and CBA bonding geometry (Figs. 2-5).

Key structural identities (derived, not tabulated):

  pitch(direct)     = sqrt(cell_x * hcb_route_span)   # one bond per BL column
  pitch(core_mux)   = pitch(direct)                    # mux sits at the core,
                                                       # bond count unchanged
  pitch(strap-like) = pitch(direct) * sqrt(BLS_PER_STRAP)
                                                       # 8 BLs share one bond
  BLSA area         = 2 * pitch^2                      # open-BL, two bond rows
                                                       # (ref + signal) per SA

Schemes are *declarative*: a `SchemeSpec` carries the structural
coefficients the parasitic/disturb/bonding models consume, and
`register_scheme` adds new routing topologies without editing any physics
module.  `SCHEMES` is the live registry (an insertion-ordered dict, so
iteration order is stable for the DSE sweep).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal


@dataclass(frozen=True)
class SchemeSpec:
    """Structural description of a BL routing scheme.

    Every coefficient is consumed arithmetically by the parasitic and
    bonding models — adding a scheme never requires a new branch in the
    physics code.
    """

    name: str
    label: str
    # --- electrical structure (parasitic assembly, Fig. 2) ---
    sel_junction: bool          # selector/mux junction terminates the local BL
    straps_per_global: int      # local BLs electrically tied to one global line
    global_strap_metal: bool    # full-length global strap metal run
    c_global_fixed_ff: float    # extra fixed metal (e.g. core-mux short run)
    r_sel_in_path: bool         # selector/mux on-resistance in series
    r_global_in_path: bool      # global strap + bond resistance in series
    # --- disturb / bonding structure ---
    isolates_unselected: bool   # inactive BLs float at a refresh potential
    bond_shared: bool           # one HCB bond per strap group (not per BL)


# Live scheme registry + compatibility views (kept in sync by
# `register_scheme`; legacy code indexes the views by name).
SCHEMES: dict = {}
SCHEME_LABELS: dict = {}
SCHEME_ISOLATES_UNSELECTED: dict = {}


def register_scheme(spec: SchemeSpec, overwrite: bool = False) -> SchemeSpec:
    """Register a BL routing scheme so sweeps and models can use it."""
    if not spec.name:
        raise ValueError("scheme must have a non-empty name")
    if spec.straps_per_global < 1:
        raise ValueError("straps_per_global must be >= 1")
    if spec.name in SCHEMES and not overwrite:
        raise ValueError(f"scheme {spec.name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    SCHEMES[spec.name] = spec
    SCHEME_LABELS[spec.name] = spec.label
    SCHEME_ISOLATES_UNSELECTED[spec.name] = spec.isolates_unselected
    return spec


def unregister_scheme(name: str) -> None:
    """Remove a registered scheme (primarily for test cleanup)."""
    SCHEMES.pop(name, None)
    SCHEME_LABELS.pop(name, None)
    SCHEME_ISOLATES_UNSELECTED.pop(name, None)


def scheme_spec(name: str) -> SchemeSpec:
    try:
        return SCHEMES[name]
    except KeyError:
        raise ValueError(f"unknown routing scheme: {name}") from None


register_scheme(SchemeSpec(
    name="direct", label="(a) Direct BLSA connection",
    sel_junction=False, straps_per_global=1, global_strap_metal=False,
    c_global_fixed_ff=0.0, r_sel_in_path=False, r_global_in_path=False,
    isolates_unselected=False, bond_shared=False))
register_scheme(SchemeSpec(
    name="strap", label="(b) BL strapping",
    sel_junction=False, straps_per_global=cal.STRAPS_PER_GLOBAL,
    global_strap_metal=True, c_global_fixed_ff=0.0,
    r_sel_in_path=False, r_global_in_path=True,
    isolates_unselected=False, bond_shared=True))
register_scheme(SchemeSpec(
    name="core_mux", label="(c) Core MUX",
    sel_junction=True, straps_per_global=1, global_strap_metal=False,
    c_global_fixed_ff=0.4, r_sel_in_path=True, r_global_in_path=False,
    isolates_unselected=False, bond_shared=False))
register_scheme(SchemeSpec(
    name="sel_strap", label="(d) BL Selector + Strap (this work)",
    sel_junction=True, straps_per_global=1, global_strap_metal=True,
    c_global_fixed_ff=0.0, r_sel_in_path=True, r_global_in_path=True,
    isolates_unselected=True, bond_shared=True))


@dataclass(frozen=True)
class BondingGeometry:
    hcb_pitch_um: jnp.ndarray
    blsa_area_um2: jnp.ndarray
    manufacturable: jnp.ndarray      # pitch within the W2W HCB window
    bonds_per_mm2_m: jnp.ndarray     # bond density (millions / mm^2)


def _assemble_geometry(cell_x_nm, hcb_route_span_um, bond_shared,
                       baseline_2d) -> BondingGeometry:
    """Coefficient-driven bonding geometry (scalar or per-point arrays).

    One bond per BL column gives pitch = sqrt(cell_x * route_span);
    strap-type schemes share that bond across the strap's BL group.  The
    2D baseline has no bonding at all (pitch 0, `manufacturable` left to
    the caller's semantics).  Shared by the scalar API and the lowered
    DSE path so the two cannot drift.
    """
    direct = jnp.sqrt(jnp.asarray(cell_x_nm, jnp.float32) * 1e-3
                      * hcb_route_span_um)
    share = jnp.where(bond_shared, jnp.sqrt(float(cal.BLS_PER_STRAP)), 1.0)
    pitch = jnp.where(baseline_2d, 0.0, direct * share).astype(jnp.float32)
    blsa_area = 2.0 * pitch * pitch
    ok = pitch >= cal.HCB_MIN_MANUFACTURABLE_PITCH_UM
    dens = jnp.where(pitch > 0,
                     1.0 / jnp.maximum(pitch * pitch, 1e-9) * 1e-6, 0.0)
    return BondingGeometry(pitch, blsa_area, ok, dens)


def hcb_pitch_um(tech: TechCal, scheme: str) -> jnp.ndarray:
    """Required hybrid-bond pitch for the scheme on this technology."""
    return bonding_geometry(tech, scheme).hcb_pitch_um


def bonding_geometry(tech: TechCal, scheme: str) -> BondingGeometry:
    return _assemble_geometry(tech.cell_x_nm, tech.hcb_route_span_um,
                              scheme_spec(scheme).bond_shared,
                              tech.baseline_2d)


def bonding_geometry_lowered(view) -> BondingGeometry:
    """Array-native bonding geometry over a lowered design space.

    `view` follows the LoweredSpace protocol (`core.space`): `.layers`,
    `.tech(field)`, `.scheme(field)` gathers, one entry per design point.
    Unlike the scalar `bonding_geometry`, `manufacturable` here already
    folds in the 2D-baseline exemption (no bonding -> nothing to
    manufacture), which is the feasibility semantics the DSE uses.
    """
    baseline = view.tech("baseline_2d")
    geom = _assemble_geometry(view.tech("cell_x_nm"),
                              view.tech("hcb_route_span_um"),
                              view.scheme("bond_shared"), baseline)
    return BondingGeometry(geom.hcb_pitch_um, geom.blsa_area_um2,
                           baseline | geom.manufacturable,
                           geom.bonds_per_mm2_m)
