"""BL routing schemes and CBA bonding geometry (Figs. 2-5).

Key structural identities (derived, not tabulated):

  pitch(direct)     = sqrt(cell_x * hcb_route_span)   # one bond per BL column
  pitch(core_mux)   = pitch(direct)                    # mux sits at the core,
                                                       # bond count unchanged
  pitch(strap-like) = pitch(direct) * sqrt(BLS_PER_STRAP)
                                                       # 8 BLs share one bond
  BLSA area         = 2 * pitch^2                      # open-BL, two bond rows
                                                       # (ref + signal) per SA
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal

SCHEMES = ("direct", "strap", "core_mux", "sel_strap")

SCHEME_LABELS = {
    "direct": "(a) Direct BLSA connection",
    "strap": "(b) BL strapping",
    "core_mux": "(c) Core MUX",
    "sel_strap": "(d) BL Selector + Strap (this work)",
}

# Which schemes let the inactive BL float at a refresh potential (decoupled
# from the global line) -> FBE / off-leakage mitigation.
SCHEME_ISOLATES_UNSELECTED = {
    "direct": False, "strap": False, "core_mux": False, "sel_strap": True,
}


@dataclass(frozen=True)
class BondingGeometry:
    hcb_pitch_um: jnp.ndarray
    blsa_area_um2: jnp.ndarray
    manufacturable: jnp.ndarray      # pitch within the W2W HCB window
    bonds_per_mm2_m: jnp.ndarray     # bond density (millions / mm^2)


def hcb_pitch_um(tech: TechCal, scheme: str) -> jnp.ndarray:
    """Required hybrid-bond pitch for the scheme on this technology."""
    if tech.name == "d1b":
        return jnp.asarray(0.0)      # no bonding in the planar baseline
    direct = jnp.sqrt(tech.cell_x_nm * 1e-3 * tech.hcb_route_span_um)
    if scheme in ("direct", "core_mux"):
        return direct
    # strap-type schemes share one bond across the strap's BL group
    return direct * jnp.sqrt(float(cal.BLS_PER_STRAP))


def bonding_geometry(tech: TechCal, scheme: str) -> BondingGeometry:
    pitch = hcb_pitch_um(tech, scheme)
    blsa_area = 2.0 * pitch * pitch
    ok = pitch >= cal.HCB_MIN_MANUFACTURABLE_PITCH_UM
    dens = jnp.where(pitch > 0, 1.0 / jnp.maximum(pitch * pitch, 1e-9) * 1e-6, 0.0)
    return BondingGeometry(pitch, blsa_area, ok, dens)
