"""Paper-table generators: everything the benchmarks print comes from here.

Each function corresponds to a paper artifact:
  fig3_routing_comparison  -> Fig. 3(c): four schemes, quantitative
  fig9a_stack_height       -> Fig. 9(a): height vs density
  fig9b_margin_vs_density  -> Fig. 9(b): margin w/ FBE+RH vs density
  fig9c_spec_table         -> Fig. 9(c): this-work vs D1b spec comparison
  table1_summary           -> Table I "This Work" column quantities
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import calibration as cal
from .calibration import AOS, D1B, SI, TECHS
from .density import bit_density_gb_mm2, layers_for_density, stack_height_um
from .energy import read_energy_fj, write_energy_fj
from .netlist import effective_cbl_ff
from .routing import SCHEME_LABELS, SCHEMES, bonding_geometry
from .sense import sense_margin_mv
from .transient import simulate_row_cycle


def fig3_routing_comparison(with_transient: bool = True) -> list[dict]:
    rows = []
    for tech in (SI, AOS):
        layers = jnp.asarray([tech.layers_target])
        for scheme in SCHEMES:
            geom = bonding_geometry(tech, scheme)
            row = dict(
                tech=tech.name, scheme=scheme, label=SCHEME_LABELS[scheme],
                cbl_ff=float(effective_cbl_ff(tech, scheme, layers)[0]),
                margin_mv=float(sense_margin_mv(tech, scheme, layers)[0]),
                hcb_pitch_um=float(geom.hcb_pitch_um),
                blsa_area_um2=float(geom.blsa_area_um2),
                manufacturable=bool(geom.manufacturable),
            )
            if with_transient:
                res = simulate_row_cycle(tech, scheme, layers)
                row["trc_ns"] = float(res.trc_ns[0])
                row["t_sense_ns"] = float(res.t_sense_ns[0])
            rows.append(row)
    # D1b reference row
    layers = jnp.asarray([1])
    row = dict(tech="d1b", scheme="direct", label="D1b 2D baseline",
               cbl_ff=float(effective_cbl_ff(D1B, "direct", layers)[0]),
               margin_mv=float(sense_margin_mv(D1B, "direct", layers)[0]),
               hcb_pitch_um=0.0, blsa_area_um2=cal.D1B_BLSA_AREA_UM2,
               manufacturable=True)
    if with_transient:
        res = simulate_row_cycle(D1B, "direct", layers)
        row["trc_ns"] = float(res.trc_ns[0])
        row["t_sense_ns"] = float(res.t_sense_ns[0])
    rows.append(row)
    return rows


def fig9a_stack_height(densities=None) -> list[dict]:
    if densities is None:
        densities = np.linspace(0.5, 3.5, 13)
    rows = []
    for tech in (SI, AOS):
        layers = np.asarray(layers_for_density(tech, densities))
        heights = np.asarray(stack_height_um(tech, layers))
        for d, l, h in zip(densities, layers, heights):
            rows.append(dict(tech=tech.name, density_gb_mm2=float(d),
                             layers=int(l), height_um=float(h)))
    return rows


def fig9b_margin_vs_density(densities=None, scheme: str = "sel_strap") -> list[dict]:
    if densities is None:
        densities = np.linspace(0.5, 3.5, 13)
    rows = []
    for tech in (SI, AOS):
        layers = jnp.asarray(np.asarray(layers_for_density(tech, densities)))
        margin = np.asarray(sense_margin_mv(tech, scheme, layers))
        margin_d = np.asarray(sense_margin_mv(tech, scheme, layers,
                                              with_disturb=True))
        for d, l, m, md in zip(densities, np.asarray(layers), margin, margin_d):
            rows.append(dict(
                tech=tech.name, density_gb_mm2=float(d), layers=int(l),
                margin_mv=float(m), margin_with_fbe_rh_mv=float(md),
                functional=bool(md >= cal.MIN_DISTURBED_MARGIN_MV)))
    return rows


def fig9c_spec_table(with_transient: bool = True) -> dict:
    """This-work (Si/AOS @ 2.6 Gb/mm^2, sel_strap) vs D1b."""
    out = {}
    for tech in (SI, AOS, D1B):
        scheme = "direct" if tech.name == "d1b" else "sel_strap"
        layers = jnp.asarray([tech.layers_target])
        entry = dict(
            layers=int(tech.layers_target),
            bit_density_gb_mm2=float(bit_density_gb_mm2(tech, layers)[0]),
            stack_height_um=float(stack_height_um(tech, layers)[0]),
            cbl_ff=float(effective_cbl_ff(tech, scheme, layers)[0]),
            sense_margin_mv=float(sense_margin_mv(tech, scheme, layers)[0]),
            sense_margin_disturbed_mv=float(
                sense_margin_mv(tech, scheme, layers, with_disturb=True)[0]),
            e_write_fj=float(write_energy_fj(tech, scheme, layers)[0]),
            e_read_fj=float(read_energy_fj(tech, scheme, layers)[0]),
            vpp=cal.VPP_D1B if tech.name == "d1b" else cal.VPP_3D,
        )
        if tech.name != "d1b":
            geom = bonding_geometry(tech, scheme)
            entry["hcb_pitch_um"] = float(geom.hcb_pitch_um)
            entry["blsa_area_um2"] = float(geom.blsa_area_um2)
        else:
            entry["blsa_area_um2"] = cal.D1B_BLSA_AREA_UM2
        if with_transient:
            entry["trc_ns"] = float(
                simulate_row_cycle(tech, scheme, layers).trc_ns[0])
        out[tech.name] = entry
    # headline ratios
    if with_transient:
        out["ratios"] = dict(
            density_x=out["si"]["bit_density_gb_mm2"] / cal.D1B_BIT_DENSITY_GB_MM2,
            trc_speedup_si=out["d1b"]["trc_ns"] / out["si"]["trc_ns"],
            trc_speedup_aos=out["d1b"]["trc_ns"] / out["aos"]["trc_ns"],
            write_energy_reduction=1 - out["si"]["e_write_fj"] / out["d1b"]["e_write_fj"],
            read_energy_reduction=1 - out["si"]["e_read_fj"] / out["d1b"]["e_read_fj"],
        )
    return out


def table1_summary() -> dict:
    spec = fig9c_spec_table(with_transient=True)
    return dict(
        cell_structure="GAA, line-type isolation",
        channel=("epitaxial Si (Si-SiGe) & AOS (Si deposition)"),
        array_direction="VBL",
        wl_bl_routing="HCB CBA: BL/WL selector, strap",
        bit_density="2.6 Gb/mm^2: %dL (Si), %dL (AOS)" % (
            spec["si"]["layers"], spec["aos"]["layers"]),
        sense_margin_mv=dict(si=spec["si"]["sense_margin_mv"],
                             aos=spec["aos"]["sense_margin_mv"],
                             d1b=spec["d1b"]["sense_margin_mv"]),
        trc_ns=dict(si=spec["si"]["trc_ns"], aos=spec["aos"]["trc_ns"],
                    d1b=spec["d1b"]["trc_ns"]),
        energy_fj=dict(
            write_si=spec["si"]["e_write_fj"], write_aos=spec["aos"]["e_write_fj"],
            read_si=spec["si"]["e_read_fj"], read_aos=spec["aos"]["e_read_fj"]),
    )
