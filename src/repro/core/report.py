"""Paper-table generators: everything the benchmarks print comes from here.

Each function corresponds to a paper artifact:
  fig3_routing_comparison  -> Fig. 3(c): four schemes, quantitative
  fig9a_stack_height       -> Fig. 9(a): height vs density
  fig9b_margin_vs_density  -> Fig. 9(b): margin w/ FBE+RH vs density
  fig9c_spec_table         -> Fig. 9(c): this-work vs D1b spec comparison
  table1_summary           -> Table I "This Work" column quantities

Yield-aware variants (Monte-Carlo through the same fused sweep):
  mc_yield_table           -> Table-1/Fig-9c points as margin/tRC *yield*
                              (per-sample SA offset + Vth variation)
  fig9b_margin_yield_vs_density -> Fig. 9(b) with the functional line
                              replaced by a per-density yield fraction
  mc_tail_yield_table      -> deep-tail (ppm) spec-failure estimates of
                              the Table-1 points via importance sampling
                              under correlated within-die variation
  fig_tail_probability     -> failure probability vs margin floor (the
                              tail curve behind the ppm table)

The DSE-shaped tables (fig3 / fig9b / fig9c) are generated from ONE
vectorized `dse.sweep` over a declarative `DesignSpace` and read straight
off the resulting `DesignBatch` columns — no per-combo model calls; the
MC variants fan the same spaces out with `with_mc` and read the
`yield_fraction`/`quantile` segment reductions.
"""

from __future__ import annotations

import itertools

import numpy as np

from . import calibration as cal
from . import dse
from .calibration import TECHS
from .density import layers_for_density, stack_height_um
from .routing import SCHEME_LABELS, SCHEMES
from .space import DesignSpace


def _non_baseline_techs():
    return [t for t in TECHS.values() if not t.baseline_2d]


def fig3_routing_comparison(with_transient: bool = True) -> list[dict]:
    """Four routing schemes on every 3D tech at its target layer count,
    plus the D1b reference row — one batched sweep."""
    space = DesignSpace.points(
        [(t.name, s, t.layers_target)
         for t in _non_baseline_techs() for s in SCHEMES])
    space = space + DesignSpace.points(
        [(t.name, (t.allowed_schemes or ("direct",))[0], t.layers_target)
         for t in TECHS.values() if t.baseline_2d])
    batch = dse.sweep(space, with_transient=with_transient)

    rows = []
    for i, (tech, scheme) in enumerate(zip(batch.tech_col, batch.scheme_col)):
        cal_t = TECHS[tech]
        baseline = cal_t.baseline_2d
        row = dict(
            tech=tech, scheme=scheme,
            label=(cal_t.baseline_label or f"{tech} 2D baseline") if baseline
            else SCHEME_LABELS[scheme],
            cbl_ff=float(batch.cbl_ff[i]),
            margin_mv=float(batch.margin_mv[i]),
            hcb_pitch_um=float(batch.hcb_pitch_um[i]),
            blsa_area_um2=(cal_t.fixed_blsa_area_um2 if baseline
                           else float(batch.blsa_area_um2[i])),
            manufacturable=bool(batch.manufacturable[i]),
        )
        if with_transient:
            row["trc_ns"] = float(batch.trc_ns[i])
            row["t_sense_ns"] = float(batch.t_sense_ns[i])
        rows.append(row)
    return rows


def fig9a_stack_height(densities=None) -> list[dict]:
    if densities is None:
        densities = np.linspace(0.5, 3.5, 13)
    rows = []
    for tech in _non_baseline_techs():
        layers = np.asarray(layers_for_density(tech, densities))
        heights = np.asarray(stack_height_um(tech, layers))
        for d, l, h in zip(densities, layers, heights):
            rows.append(dict(tech=tech.name, density_gb_mm2=float(d),
                             layers=int(l), height_um=float(h)))
    return rows


def fig9b_margin_vs_density(densities=None, scheme: str = "sel_strap") -> list[dict]:
    if densities is None:
        densities = np.linspace(0.5, 3.5, 13)
    techs = _non_baseline_techs()
    space = DesignSpace(entries=())
    for tech in techs:
        layers = np.asarray(layers_for_density(tech, densities))
        space = space + DesignSpace.points(
            [(tech.name, scheme, int(l)) for l in layers])
    batch = dse.sweep(space, with_transient=False)

    rows = []
    for i, (tech, d) in enumerate(itertools.product(techs, densities)):
        md = float(batch.margin_disturbed_mv[i])
        rows.append(dict(
            tech=tech.name, density_gb_mm2=float(d),
            layers=int(batch.layers[i]),
            margin_mv=float(batch.margin_mv[i]),
            margin_with_fbe_rh_mv=md,
            functional=bool(md >= cal.MIN_DISTURBED_MARGIN_MV)))
    return rows


def fig9c_spec_table(with_transient: bool = True) -> dict:
    """This-work (Si/AOS @ 2.6 Gb/mm^2, sel_strap) vs D1b — one sweep of
    the Table-1 target points."""
    batch = dse.sweep(DesignSpace.paper_targets(),
                      with_transient=with_transient)
    out = {}
    for i, tname in enumerate(batch.tech_col):
        tech = TECHS[tname]
        entry = dict(
            layers=int(batch.layers[i]),
            bit_density_gb_mm2=float(batch.density_gb_mm2[i]),
            stack_height_um=float(batch.height_um[i]),
            cbl_ff=float(batch.cbl_ff[i]),
            sense_margin_mv=float(batch.margin_mv[i]),
            sense_margin_disturbed_mv=float(batch.margin_disturbed_mv[i]),
            e_write_fj=float(batch.e_write_fj[i]),
            e_read_fj=float(batch.e_read_fj[i]),
            vpp=tech.vpp,
        )
        if not tech.baseline_2d:
            entry["hcb_pitch_um"] = float(batch.hcb_pitch_um[i])
            entry["blsa_area_um2"] = float(batch.blsa_area_um2[i])
        else:
            entry["blsa_area_um2"] = tech.fixed_blsa_area_um2
        if with_transient:
            entry["trc_ns"] = float(batch.trc_ns[i])
        out[tname] = entry
    # headline ratios
    if with_transient:
        out["ratios"] = dict(
            density_x=out["si"]["bit_density_gb_mm2"] / cal.D1B_BIT_DENSITY_GB_MM2,
            trc_speedup_si=out["d1b"]["trc_ns"] / out["si"]["trc_ns"],
            trc_speedup_aos=out["d1b"]["trc_ns"] / out["aos"]["trc_ns"],
            write_energy_reduction=1 - out["si"]["e_write_fj"] / out["d1b"]["e_write_fj"],
            read_energy_reduction=1 - out["si"]["e_read_fj"] / out["d1b"]["e_read_fj"],
        )
    return out


def mc_yield_table(samples: int = 256, key=0,
                   margin_floor_mv: float | None = None,
                   trc_ceiling_ns: float | None = None,
                   with_transient: bool = True) -> dict:
    """Yield-aware Table-1/Fig-9c variant: the paper's target design
    points under SA-offset + Vth Monte-Carlo, one fused sweep.

    Per tech: nominal-spec yield fractions (functional margin floor, and
    the disturbed floor on the disturbed margin), tail quantiles of the
    sampled metrics, and the spec-yield against an optional tRC ceiling.
    `margin_floor_mv` defaults to the paper's functional threshold.
    """
    if margin_floor_mv is None:
        margin_floor_mv = cal.MIN_FUNCTIONAL_MARGIN_MV
    space = DesignSpace.paper_targets().with_mc(samples=samples, key=key)
    batch = dse.sweep(space, with_transient=with_transient)

    y_margin = np.asarray(batch.yield_fraction(margin_mv=margin_floor_mv))
    y_dist = np.asarray(batch.yield_fraction(
        margin_mv=cal.MIN_DISTURBED_MARGIN_MV, disturbed=True))
    y_spec = np.asarray(batch.yield_fraction(
        margin_mv=margin_floor_mv, trc_ns=trc_ceiling_ns))
    p05_margin = np.asarray(batch.quantile(0.05, "margin_mv"))
    med_margin = np.asarray(batch.quantile(0.5, "margin_mv"))
    if with_transient:
        med_trc = np.asarray(batch.quantile(0.5, "trc_ns"))
        p95_trc = np.asarray(batch.quantile(0.95, "trc_ns"))

    out = {"samples": samples,
           "margin_floor_mv": float(margin_floor_mv),
           "trc_ceiling_ns": trc_ceiling_ns}
    base = batch.base_len
    tech_col = batch.tech_col[:base]       # sample 0 carries the row labels
    layers = np.asarray(batch.layers)[:base]
    for i, tname in enumerate(tech_col):
        entry = dict(
            layers=int(layers[i]),
            yield_margin=float(y_margin[i]),
            yield_margin_disturbed=float(y_dist[i]),
            yield_spec=float(y_spec[i]),
            margin_mv_p05=float(p05_margin[i]),
            margin_mv_median=float(med_margin[i]),
        )
        if with_transient:
            entry["trc_ns_median"] = float(med_trc[i])
            entry["trc_ns_p95"] = float(p95_trc[i])
        out[tname] = entry
    return out


def mc_tail_yield_table(samples: int = 4096, key=0,
                        margin_floor_mv: float | None = None,
                        tail_shift: float = 4.0, tail_scale: float = 1.2,
                        corr: float = 1.0, min_ess: float = 8.0) -> dict:
    """Deep-tail (ppm) spec-failure table of the paper's target points.

    Importance-sampled margin-tail estimate under correlated within-die
    variation: the SA-offset channel's local draws are shifted
    `tail_shift` sigmas into the failure tail (the Vth channel stays
    target-distributed — the margin spec does not constrain it), and
    `DesignBatch.yield_ppm` turns the weighted failures into a ppm
    estimate with a confidence interval and a tail-ESS diagnostic.

    `margin_floor_mv` defaults to the paper's functional threshold.  A
    tech whose tail ESS lands below `min_ess` reports NaN (no estimate).
    """
    if margin_floor_mv is None:
        margin_floor_mv = cal.MIN_FUNCTIONAL_MARGIN_MV
    space = DesignSpace.paper_targets().with_mc(
        samples=samples, key=key, corr=corr,
        tail_shift=(tail_shift, 0.0), tail_scale=(tail_scale, 1.0))
    batch = dse.sweep(space, with_transient=False)
    ppm = batch.yield_ppm(margin_mv=margin_floor_mv, min_ess=min_ess)

    out = {"samples": samples,
           "margin_floor_mv": float(margin_floor_mv),
           "tail_shift": float(tail_shift),
           "tail_scale": float(tail_scale),
           "corr": float(corr)}
    base = batch.base_len
    for i, tname in enumerate(batch.tech_col[:base]):
        out[tname] = dict(
            layers=int(np.asarray(batch.layers)[i]),
            fail_ppm=float(np.asarray(ppm["fail_ppm"])[i]),
            fail_ppm_lo=float(np.asarray(ppm["fail_ppm_lo"])[i]),
            fail_ppm_hi=float(np.asarray(ppm["fail_ppm_hi"])[i]),
            tail_ess=float(np.asarray(ppm["ess"])[i]),
        )
    return out


def fig_tail_probability(floors_mv=None, samples: int = 4096, key=0,
                         tail_shift: float = 4.0, tail_scale: float = 1.2,
                         corr: float = 1.0,
                         min_ess: float = 8.0) -> list[dict]:
    """Tail-probability curve: margin-spec failure probability vs the
    margin floor, per Table-1 tech — ONE importance-sampled sweep reused
    for every floor (the spec threshold is a reduction argument, not a
    sweep input)."""
    if floors_mv is None:
        floors_mv = np.linspace(20.0, 120.0, 11)
    space = DesignSpace.paper_targets().with_mc(
        samples=samples, key=key, corr=corr,
        tail_shift=(tail_shift, 0.0), tail_scale=(tail_scale, 1.0))
    batch = dse.sweep(space, with_transient=False)
    base = batch.base_len
    tech_col = batch.tech_col[:base]

    rows = []
    for floor in floors_mv:
        ppm = batch.yield_ppm(margin_mv=float(floor), min_ess=min_ess)
        for i, tname in enumerate(tech_col):
            rows.append(dict(
                tech=tname, margin_floor_mv=float(floor),
                fail_ppm=float(np.asarray(ppm["fail_ppm"])[i]),
                fail_ppm_lo=float(np.asarray(ppm["fail_ppm_lo"])[i]),
                fail_ppm_hi=float(np.asarray(ppm["fail_ppm_hi"])[i]),
                tail_ess=float(np.asarray(ppm["ess"])[i])))
    return rows


def fig9b_margin_yield_vs_density(densities=None, scheme: str = "sel_strap",
                                  samples: int = 128, key=0) -> list[dict]:
    """Fig. 9(b) yield variant: per (tech, density) the fraction of MC
    samples whose disturbed margin clears the functional floor — the
    binary `functional` line of `fig9b_margin_vs_density` becomes a
    yield curve."""
    if densities is None:
        densities = np.linspace(0.5, 3.5, 13)
    techs = _non_baseline_techs()
    space = DesignSpace(entries=())
    for tech in techs:
        layers = np.asarray(layers_for_density(tech, densities))
        space = space + DesignSpace.points(
            [(tech.name, scheme, int(l)) for l in layers])
    batch = dse.sweep(space.with_mc(samples=samples, key=key),
                      with_transient=False)
    y_dist = np.asarray(batch.yield_fraction(
        margin_mv=cal.MIN_DISTURBED_MARGIN_MV, disturbed=True))
    p05 = np.asarray(batch.quantile(0.05, "margin_disturbed_mv"))
    med = np.asarray(batch.quantile(0.5, "margin_disturbed_mv"))

    rows = []
    for i, (tech, d) in enumerate(itertools.product(techs, densities)):
        rows.append(dict(
            tech=tech.name, density_gb_mm2=float(d),
            layers=int(batch.layers[i]),
            margin_with_fbe_rh_mv_median=float(med[i]),
            margin_with_fbe_rh_mv_p05=float(p05[i]),
            yield_disturbed=float(y_dist[i])))
    return rows


def replica_timing_table() -> dict:
    """Fixed t_sense vs replica-closed timing on the Table-1 target points.

    Two sweeps of the same `DesignSpace.paper_targets()` — one nominal
    (fixed own-90% SA-enable timing) and one `with_replica()` (the SA
    enable fires on the replica bitline's own crossing) — read off as
    per-tech tRC / fire-time / margin-at-fire comparisons.  The delta
    columns quantify what timing closure buys: tRC drops because the
    replica (ganged `replica_cells` dummy cells) develops signal faster
    than the worst-case main bitline, at the cost of latching slightly
    before the main array reaches 90% of its asymptotic signal.
    """
    space = DesignSpace.paper_targets()
    fixed = dse.sweep(space, with_transient=True)
    closed = dse.sweep(space.with_replica(), with_transient=True)

    out = {}
    for i, tname in enumerate(fixed.tech_col):
        tech = TECHS[tname]
        trc_f = float(fixed.trc_ns[i])
        trc_c = float(closed.trc_ns[i])
        out[tname] = dict(
            layers=int(fixed.layers[i]),
            replica_cells=float(tech.replica_cells),
            trc_fixed_ns=trc_f,
            trc_closed_ns=trc_c,
            trc_delta_ns=trc_f - trc_c,
            t_fire_fixed_ns=float(fixed.t_fire_ns[i]),
            t_fire_closed_ns=float(closed.t_fire_ns[i]),
            margin_fire_fixed_mv=float(fixed.margin_fire_mv[i]),
            margin_fire_closed_mv=float(closed.margin_fire_mv[i]),
            feasible_fixed=bool(fixed.feasible[i]),
            feasible_closed=bool(closed.feasible[i]),
        )
    return out


def table1_summary() -> dict:
    spec = fig9c_spec_table(with_transient=True)
    return dict(
        cell_structure="GAA, line-type isolation",
        channel=("epitaxial Si (Si-SiGe) & AOS (Si deposition)"),
        array_direction="VBL",
        wl_bl_routing="HCB CBA: BL/WL selector, strap",
        bit_density="2.6 Gb/mm^2: %dL (Si), %dL (AOS)" % (
            spec["si"]["layers"], spec["aos"]["layers"]),
        sense_margin_mv=dict(si=spec["si"]["sense_margin_mv"],
                             aos=spec["aos"]["sense_margin_mv"],
                             d1b=spec["d1b"]["sense_margin_mv"]),
        trc_ns=dict(si=spec["si"]["trc_ns"], aos=spec["aos"]["trc_ns"],
                    d1b=spec["d1b"]["trc_ns"]),
        energy_fj=dict(
            write_si=spec["si"]["e_write_fj"], write_aos=spec["aos"]["e_write_fj"],
            read_si=spec["si"]["e_read_fj"], read_aos=spec["aos"]["e_read_fj"]),
    )
