"""Unit conventions for the STCO engine.

Internally the engine uses a consistent scaled-SI system chosen so numbers
stay O(1) and products compose without conversion constants:

  capacitance : fF   (1e-15 F)
  resistance  : kOhm (1e3 Ohm)
  time        : ns   (1e-9 s)    -> tau[ns] = R[kOhm] * C[fF] * 1e-3
  voltage     : V
  current     : uA   (1e-6 A)    -> I = V/R : V/kOhm = mA -> use MA2UA
  energy      : fJ   (1e-15 J)   -> E = C[fF] * V^2  (exact)
  length      : nm / um as named
  density     : Gb/mm^2
"""

from __future__ import annotations

# tau[ns] = R[kOhm] * C[fF] * RC_TO_NS
RC_TO_NS = 1e-3
# I[uA] = V[V] / R[kOhm] * MA_TO_UA
MA_TO_UA = 1e3

NM2_PER_MM2 = 1e12
GBIT = 1e9


def tau_ns(r_kohm: float, c_ff: float) -> float:
    """RC time constant in ns."""
    return r_kohm * c_ff * RC_TO_NS


def cap_energy_fj(c_ff: float, v: float) -> float:
    """(1/2) C V^2 in fJ."""
    return 0.5 * c_ff * v * v
