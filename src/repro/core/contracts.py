"""Opt-in runtime contracts for the fused sweep pipeline.

Static analysis (`tools/repro_lint`) enforces what the AST can see; this
module validates the invariants it can't: operand shapes/dtypes at the
`lower_design_operands` seam, batch-layout/mask consistency at the
`dse.sweep` seam, and the same operand contract on the sharded dispatch
(`launch.shard.simulate_row_cycle_sharded`).

Checks are ZERO-COST unless `REPRO_CHECKS=1`: every entry point returns
before touching its argument when disabled (tests/test_contracts.py
proves this with a sentinel that raises on any attribute access, and
that enabling checks never retraces the fused kernel — all checks are
host-side numpy at trace-free seams).  `tests/conftest.py` auto-enables
them under pytest.
"""

from __future__ import annotations

import os

import numpy as np


class ContractError(AssertionError):
    """A fused-pipeline invariant violated at a checked seam."""


def checks_enabled() -> bool:
    """Read `REPRO_CHECKS` lazily so tests can flip it per-call."""
    return os.environ.get("REPRO_CHECKS", "0") == "1"


def _is_tracer(x) -> bool:
    import jax

    return isinstance(x, jax.core.Tracer)


def _fail(where: str, msg: str):
    raise ContractError(f"[{where}] {msg}")


def check_operands(operands, where: str = "lower_operands") -> None:
    """Validate a `FusedOperands` batch: kernel operand shapes, float32
    dtypes, finiteness, and the replica-mode even-pair row layout."""
    if not checks_enabled():
        return
    c, g, gc_res, gc_pre, v0, params = operands[:6]
    sa_tau, overhead = operands.sa_tau_ns, operands.t_overhead_ns
    if c.ndim != 2:
        _fail(where, f"c must be (B, N), got shape {c.shape}")
    b, n = c.shape
    expected = {"g": (g, (b, n - 1)), "gc_res": (gc_res, (b, n)),
                "gc_pre": (gc_pre, (b, n)), "v0": (v0, (b, n)),
                "sa_tau_ns": (sa_tau, (b,)), "t_overhead_ns": (overhead, (b,))}
    for name, (arr, shape) in expected.items():
        if tuple(arr.shape) != shape:
            _fail(where, f"{name} must have shape {shape} (from c = "
                         f"{c.shape}), got {tuple(arr.shape)}")
    if params.ndim != 2 or params.shape[0] != b or params.shape[1] not in (5, 6):
        _fail(where, f"params must be (B, 5|6) per-point kernel params, "
                     f"got {tuple(params.shape)}")
    for name, arr in [("c", c), ("g", g), ("gc_res", gc_res),
                      ("gc_pre", gc_pre), ("v0", v0), ("params", params),
                      ("sa_tau_ns", sa_tau), ("t_overhead_ns", overhead)]:
        if arr.dtype != np.float32:
            _fail(where, f"{name} must be float32, got {arr.dtype}")
    replica = bool(getattr(operands, "replica", False))
    if replica and b % 2:
        _fail(where, f"replica mode interleaves [replica, main] pairs; "
                     f"B={b} must be even")
    if any(_is_tracer(x) for x in (c, g, params, sa_tau, overhead)):
        return  # value checks are host-side only
    for name, arr in [("c", c), ("g", g), ("gc_res", gc_res),
                      ("gc_pre", gc_pre), ("v0", v0), ("params", params),
                      ("sa_tau_ns", sa_tau), ("t_overhead_ns", overhead)]:
        if not np.isfinite(np.asarray(arr)).all():
            _fail(where, f"{name} contains non-finite operand values — "
                         "infeasible points must lower to INACTIVE rows, "
                         "never NaN/inf operands")
    if replica and params.shape[1] == 6:
        from ..kernels.row_cycle import ROLE_MAIN, ROLE_REPLICA

        role = np.asarray(params[:, 5])
        if not (np.all(role[0::2] == ROLE_REPLICA)
                and np.all(role[1::2] == ROLE_MAIN)):
            _fail(where, "replica mode requires role columns interleaved "
                         f"[ROLE_REPLICA={ROLE_REPLICA}, "
                         f"ROLE_MAIN={ROLE_MAIN}] per design point")


def check_batch(batch, where: str = "dse.sweep") -> None:
    """Validate a `DesignBatch`: every array field on the one (B,) batch
    axis, boolean masks with `feasible ⊆ valid`, corner channels shaped
    (B,) with reserved `mc_*` names confined to the registered MC axes,
    and the sample-major MC layout (`len == n_samples * base_len`)."""
    if not checks_enabled():
        return
    from .batch import ARRAY_FIELDS
    from .space import MC_AXES, MC_LOG_W

    b = int(batch.tech_idx.shape[0])
    for name in ARRAY_FIELDS:
        arr = getattr(batch, name)
        if arr.ndim != 1 or int(arr.shape[0]) != b:
            _fail(where, f"batch.{name} must be ({b},) on the single "
                         f"batch axis, got shape {tuple(arr.shape)}")
    for name in ("manufacturable", "feasible", "valid"):
        if getattr(batch, name).dtype != np.bool_:
            _fail(where, f"batch.{name} must be bool, got "
                         f"{getattr(batch, name).dtype}")
    for key, arr in batch.corners.items():
        if key.startswith("mc_") and key not in MC_AXES and key != MC_LOG_W:
            _fail(where, f"corner channel {key!r} uses the reserved mc_* "
                         f"namespace; only {MC_AXES + (MC_LOG_W,)} may be "
                         "written (and only by core/space.py)")
        if tuple(arr.shape) != (b,):
            _fail(where, f"corner channel {key!r} must be ({b},), got "
                         f"{tuple(arr.shape)}")
    n_samples = int(getattr(batch, "n_samples", 1) or 1)
    base_len = int(getattr(batch, "base_len", 0) or 0)
    if n_samples > 1:
        if base_len <= 0 or n_samples * base_len != b:
            _fail(where, f"MC batch must be sample-major with len == "
                         f"n_samples * base_len; got len={b}, "
                         f"n_samples={n_samples}, base_len={base_len}")
    if _is_tracer(batch.valid) or _is_tracer(batch.feasible):
        return  # value checks are host-side only
    valid = np.asarray(batch.valid)
    feasible = np.asarray(batch.feasible)
    if not np.all(valid | ~feasible):
        _fail(where, "feasible rows must be a subset of valid rows "
                     "(padding can never be feasible)")
    layers = np.asarray(batch.layers)
    if valid.any() and not np.isfinite(layers[valid]).all():
        _fail(where, "valid rows must carry finite layer counts")
