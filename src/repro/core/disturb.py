"""Disturb mechanisms: floating-body effect (FBE) and row hammer (RH).

The paper analyzes disturbance-induced charge loss via mixed-mode TCAD
assuming 10k RH toggles and 1.5e6 tRC cycles per 64 ms refresh window.  We
use a calibrated surrogate: charge loss expressed as an equivalent cell
voltage loss that scales with the stack (coupling paths grow with layer
count) and with the assumed disturb duty.

AOS channels have no floating body (fully-depleted oxide semiconductor) ->
FBE term is zero; this is why the AOS margin ends ~2x the Si margin in
Fig. 9b even though both see RH coupling.

The BL selector additionally *floats inactive BLs at a refresh potential*,
decoupling cells from global-BL disturb; schemes without isolation see an
extra BL-disturb term (paper: "transient spikes indicate BL disturb").
"""

from __future__ import annotations

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .routing import SCHEME_ISOLATES_UNSELECTED


def disturb_loss_mv(tech: TechCal, scheme: str, layers,
                    rh_toggles: float = cal.RH_TOGGLES_PER_64MS,
                    trc_cycles: float = cal.TRC_CYCLES_PER_64MS) -> jnp.ndarray:
    """Equivalent sense-voltage loss (mV) from FBE + RH at refresh time.

    Calibrated so that at the target layer count and nominal duty the Si
    sel_strap design loses 60 mV (130 -> 70 mV, Fig. 9b) and AOS loses
    25 mV (RH only).
    """
    layers = jnp.asarray(layers, jnp.float32)
    layer_scale = layers / max(tech.layers_target, 1)
    duty_rh = rh_toggles / cal.RH_TOGGLES_PER_64MS
    duty_fbe = trc_cycles / cal.TRC_CYCLES_PER_64MS

    fbe = tech.fbe_loss_mv * layer_scale * duty_fbe
    rh = tech.rh_loss_mv * layer_scale * duty_rh
    # non-isolated schemes keep every cell coupled to global-BL swings:
    # additional BL-disturb term (half the FBE-equivalent, both techs).
    bl_disturb = jnp.where(
        SCHEME_ISOLATES_UNSELECTED.get(scheme, True) or tech.baseline_2d,
        0.0, 15.0 * layer_scale * duty_fbe)
    return fbe + rh + bl_disturb


def disturb_loss_lowered(view) -> jnp.ndarray:
    """Array-native FBE+RH loss over a lowered design space (core.space).

    Disturb-duty corner axes registered on the space
    (`DesignSpace.with_corners(rh_toggles=..., trc_cycles=...)`) flow in
    here per design point — Monte-Carlo corners are just more batch rows.
    """
    layer_scale = view.layers / jnp.maximum(
        jnp.asarray(view.tech("layers_target"), jnp.float32), 1.0)
    duty_rh = (view.corner("rh_toggles", cal.RH_TOGGLES_PER_64MS)
               / cal.RH_TOGGLES_PER_64MS)
    duty_fbe = (view.corner("trc_cycles", cal.TRC_CYCLES_PER_64MS)
                / cal.TRC_CYCLES_PER_64MS)

    fbe = view.tech("fbe_loss_mv") * layer_scale * duty_fbe
    rh = view.tech("rh_loss_mv") * layer_scale * duty_rh
    bl_disturb = jnp.where(
        view.scheme("isolates_unselected") | view.tech("baseline_2d"),
        0.0, 15.0 * layer_scale * duty_fbe)
    return (fbe + rh + bl_disturb).astype(jnp.float32)


def off_state_leakage_note(tech: TechCal) -> str:
    if tech.fbe_loss_mv == 0.0:
        return ("oxide channel: no floating body; retention limited only by "
                "~1e-19 A off-state leakage")
    return "Si floating body: FBE charge pumping under repeated cycling"
