"""Read/write energy model (Fig. 9c).

  E_write = 1/2 (Cs + C_BL) VDD^2 * eta        full-swing write of cell+BL
  E_read  = 1/2 C_BL (VDD/2)^2 * eta + E_SA    half-swing develop + latch

The 2D baseline additionally swings its lateral IO routing (c_route_extra)
— capacitance the CBA's vertical bonding eliminates; its SA is larger
(D1B_E_SA_FJ).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .netlist import effective_cbl_ff


def write_energy_fj(tech: TechCal, scheme: str, layers) -> jnp.ndarray:
    cbl = effective_cbl_ff(tech, scheme, layers) + tech.c_route_extra_ff
    v = cal.VDD_ARRAY
    return 0.5 * (cal.CS_FF + cbl) * v * v * cal.ENERGY_EFF


def read_energy_fj(tech: TechCal, scheme: str, layers) -> jnp.ndarray:
    cbl = effective_cbl_ff(tech, scheme, layers) + tech.c_route_extra_ff
    v = cal.VDD_ARRAY / 2.0
    return 0.5 * cbl * v * v * cal.ENERGY_EFF + tech.e_sa_fj


def write_energy_lowered(view, cbl_ff: jnp.ndarray | None = None) -> jnp.ndarray:
    """Array-native write energy over a lowered design space (core.space)."""
    from .netlist import effective_cbl_lowered
    if cbl_ff is None:
        cbl_ff = effective_cbl_lowered(view)
    cbl = cbl_ff + view.tech("c_route_extra_ff")
    v = cal.VDD_ARRAY
    return (0.5 * (cal.CS_FF + cbl) * v * v * cal.ENERGY_EFF).astype(jnp.float32)


def read_energy_lowered(view, cbl_ff: jnp.ndarray | None = None) -> jnp.ndarray:
    """Array-native read energy over a lowered design space (core.space)."""
    from .netlist import effective_cbl_lowered
    if cbl_ff is None:
        cbl_ff = effective_cbl_lowered(view)
    cbl = cbl_ff + view.tech("c_route_extra_ff")
    v = cal.VDD_ARRAY / 2.0
    return (0.5 * cbl * v * v * cal.ENERGY_EFF
            + view.tech("e_sa_fj")).astype(jnp.float32)


def wl_energy_fj(tech: TechCal) -> jnp.ndarray:
    """WL driver energy per activation (the 3D design's reduced VPP pays off)."""
    return 0.5 * tech.c_wl_ff * tech.vpp * tech.vpp
