"""Batched transient simulation of the full row cycle (the paper's Fig. 8).

Implicit-Euler on the sensing-path RC ladder with a behavioral BLSA, phased
exactly like a DRAM row cycle:

  ACT   : WL ramps up (access branch scale 0->1), cell shares charge with
          the BL network; the BLSA is enabled once the sense node has
          developed 90% of its asymptotic signal (+ latch regeneration).
  RESTORE: the latched BLSA drives the sense node to the full rail through
          its drive resistance, recharging the cell through the BL + access
          transistor until 95% of VDD is restored.
  PRE   : WL ramps down, equalizer clamps all BL nodes to VDD/2 until
          within 5 mV.

tRC = t_overhead + t(ACT+RESTORE) + t(PRE).

Everything is vmap-able over a batch of design points; the inner loop is
`repro.kernels.ops.rc_multistep` (Pallas on TPU, jnp oracle on CPU).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .netlist import Ladder, N_BL_SEGMENTS, build_bl_ladder
from ..kernels import ops
from .units import tau_ns

DT_NS = 0.02
T_ACT_NS = 16.0
T_RESTORE_NS = 20.0
T_PRE_NS = 10.0


@dataclass(frozen=True)
class RowCycleResult:
    t_sense_ns: jnp.ndarray       # WL start -> SA latched
    t_restore_ns: jnp.ndarray     # WL start -> cell restored (tRAS analogue)
    t_precharge_ns: jnp.ndarray   # precharge duration (tRP analogue)
    trc_ns: jnp.ndarray           # total row cycle
    dv_sense_v: jnp.ndarray       # developed signal at SA enable
    traces: dict                  # phase -> (T, B, N) waveforms


def _first_crossing_ns(trace_ok: jnp.ndarray, dt: float, t_max: float) -> jnp.ndarray:
    """Time of first True along axis 0 of (T, B); t_max if never."""
    any_ok = jnp.any(trace_ok, axis=0)
    idx = jnp.argmax(trace_ok, axis=0)
    return jnp.where(any_ok, (idx + 1) * dt, t_max)


def wl_ramp(tech: TechCal, t_ns: jnp.ndarray, rising: bool = True) -> jnp.ndarray:
    """WL voltage profile (normalized 0..1): RC-limited driver."""
    tau = tau_ns(tech.r_wl_kohm, tech.c_wl_ff)
    x = 1.0 - jnp.exp(-t_ns / jnp.maximum(tau, 1e-3))
    return x if rising else 1.0 - x


def simulate_row_cycle(tech: TechCal, scheme: str, layers,
                       store_v: float | None = None,
                       backend: str = "ref") -> RowCycleResult:
    """Simulate ACT/RESTORE/PRE on the ladder; batched over `layers`."""
    ladder = build_bl_ladder(tech, scheme, layers)
    b, n = ladder.c.shape
    k = N_BL_SEGMENTS
    vdd, vpre = cal.VDD_ARRAY, cal.VBL_PRE
    if store_v is None:
        store_v = tech.writeback_eff * vdd

    c = ladder.c.astype(jnp.float32)
    g = ladder.g_branch.astype(jnp.float32)
    zero_clamp = jnp.zeros((b, n), jnp.float32)

    # ---------------- ACT: WL up, charge share --------------------------
    n_act = int(T_ACT_NS / DT_NS)
    t_grid = (jnp.arange(n_act) + 1) * DT_NS
    ramp_up = wl_ramp(tech, t_grid).astype(jnp.float32)
    v0 = jnp.full((b, n), vpre, jnp.float32).at[:, n - 1].set(store_v)
    trace_act = ops.rc_multistep(c, g, zero_clamp, zero_clamp, v0,
                                 ramp_up, DT_NS, backend=backend)

    cbl = ladder.c[:, :n - 1].sum(-1)
    cs = ladder.c[:, n - 1]
    dv_inf = (store_v - vpre) * cs / (cs + cbl)
    crossed = trace_act[:, :, 0] - vpre >= 0.9 * dv_inf[None, :].astype(jnp.float32)
    t_dev = _first_crossing_ns(crossed, DT_NS, T_ACT_NS)

    # developed signal actually available at SA enable
    idx_dev = jnp.clip((t_dev / DT_NS).astype(jnp.int32) - 1, 0, n_act - 1)
    dv_sense = trace_act[idx_dev, jnp.arange(b), 0] - vpre

    # latch regeneration from dv to VDD/2 rail excursion
    t_regen = tech.sa_tau_ns * jnp.log(
        jnp.maximum((vdd / 2.0) / jnp.maximum(dv_sense, 1e-4), 1.001))
    t_sense = t_dev + t_regen

    # ---------------- RESTORE: SA drives the rail -----------------------
    n_res = int(T_RESTORE_NS / DT_NS)
    # state at SA enable: take the trace at t_dev (per design point)
    v_at_dev = trace_act[idx_dev, jnp.arange(b), :]
    g_clamp_res = zero_clamp.at[:, 0].set(1.0 / tech.r_sa_drive_kohm)
    v_clamp_res = jnp.full((b, n), vdd, jnp.float32)
    ramp_on = jnp.ones((n_res,), jnp.float32)
    trace_res = ops.rc_multistep(c, g, g_clamp_res, v_clamp_res, v_at_dev,
                                 ramp_on, DT_NS, backend=backend)
    restored = trace_res[:, :, n - 1] >= 0.95 * vdd
    t_res_dur = _first_crossing_ns(restored, DT_NS, T_RESTORE_NS)
    t_restore = t_sense + t_res_dur

    # ---------------- PRE: WL down, equalize ----------------------------
    n_pre = int(T_PRE_NS / DT_NS)
    t_grid_pre = (jnp.arange(n_pre) + 1) * DT_NS
    ramp_down = wl_ramp(tech, t_grid_pre, rising=False).astype(jnp.float32)
    idx_res = jnp.clip((t_res_dur / DT_NS).astype(jnp.int32) - 1, 0, n_res - 1)
    v_end_res = trace_res[idx_res, jnp.arange(b), :]
    g_clamp_pre = zero_clamp.at[:, :n - 1].set(1.0 / tech.r_pre_kohm)
    v_clamp_pre = jnp.full((b, n), vpre, jnp.float32)
    trace_pre = ops.rc_multistep(c, g, g_clamp_pre, v_clamp_pre, v_end_res,
                                 ramp_down, DT_NS, backend=backend)
    equalized = jnp.max(jnp.abs(trace_pre[:, :, :n - 1] - vpre), axis=-1) <= 5e-3
    t_pre = _first_crossing_ns(equalized, DT_NS, T_PRE_NS)

    trc = tech.t_overhead_ns + t_restore + t_pre
    return RowCycleResult(
        t_sense_ns=t_sense, t_restore_ns=t_restore, t_precharge_ns=t_pre,
        trc_ns=trc, dv_sense_v=dv_sense,
        traces={"act": trace_act, "restore": trace_res, "pre": trace_pre},
    )


def nominal_trc_ns(tech: TechCal, scheme: str = "sel_strap",
                   layers: int | None = None) -> jnp.ndarray:
    """Nominal tRC at the technology's target layer count."""
    if layers is None:
        layers = tech.layers_target
    return simulate_row_cycle(tech, scheme, jnp.asarray([layers])).trc_ns[0]
