"""Batched transient simulation of the full row cycle (the paper's Fig. 8).

Implicit-Euler on the sensing-path RC ladder with a behavioral BLSA, phased
exactly like a DRAM row cycle:

  ACT   : WL ramps up (access branch scale 0->1), cell shares charge with
          the BL network; the BLSA is enabled once the sense node has
          developed 90% of its asymptotic signal (+ latch regeneration).
  RESTORE: the latched BLSA drives the sense node to the full rail through
          its drive resistance, recharging the cell through the BL + access
          transistor until 95% of VDD is restored.
  PRE   : WL ramps down, equalizer clamps all BL nodes to VDD/2 until
          within 5 mV.

tRC = t_overhead + t(ACT+RESTORE) + t(PRE).

Two execution engines, same physics:

  fused (default)      — one `repro.kernels.ops.row_cycle_fused` call runs
          all three phases with in-kernel crossing detection and returns
          O(B) event times/voltages; no (T, B, N) trace ever exists.  This
          is what the DSE sweeps thousands of design points through, and
          `simulate_row_cycle_many` batches arbitrary (tech, scheme,
          layers) combos through ONE fused evaluation (VMEM-bounded by
          batch chunking).
  phased (traces=True) — three `rc_multistep` calls that materialize the
          per-phase waveforms for Fig. 8 plotting; also the reference the
          fused engine is regression-tested against (within one dt).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp

from . import calibration as cal
from . import contracts
from .calibration import TechCal
from .netlist import (Ladder, build_bl_ladder, build_ladder_lowered,
                      replica_ladder_arrays)
from ..kernels import ops
from ..kernels.row_cycle import ROLE_MAIN, ROLE_REPLICA
from .units import tau_ns

DT_NS = 0.02
T_ACT_NS = 16.0
T_RESTORE_NS = 20.0
T_PRE_NS = 10.0

N_ACT_STEPS = int(T_ACT_NS / DT_NS)
N_RESTORE_STEPS = int(T_RESTORE_NS / DT_NS)
N_PRE_STEPS = int(T_PRE_NS / DT_NS)

# default fused-engine chunk: bounds device memory for arbitrary DSE grids
DEFAULT_B_CHUNK = 2048


@dataclass(frozen=True)
class RowCycleResult:
    t_sense_ns: jnp.ndarray       # WL start -> SA latched
    t_restore_ns: jnp.ndarray     # WL start -> cell restored (tRAS analogue)
    t_precharge_ns: jnp.ndarray   # precharge duration (tRP analogue)
    trc_ns: jnp.ndarray           # total row cycle
    dv_sense_v: jnp.ndarray       # developed signal at SA enable
    traces: dict                  # phase -> (T, B, N) waveforms (phased only)
    t_fire_ns: jnp.ndarray | None = None  # SA-enable fire time (the ACT
    # first-crossing; replica-closed when the replica path is enabled)
    events: jnp.ndarray | None = None     # raw (B, 4) fused-engine event
    # columns BEFORE replica de-interleave — the exact engine output.
    # Carried so `dse.finalize_sweep` can re-derive every scored column
    # through the one jitted rollup+score program both the sequential
    # and sharded sweeps run (their bit-equivalence contract).


def _first_crossing_ns(trace_ok: jnp.ndarray, dt: float) -> jnp.ndarray:
    """Time of first True along axis 0 of (T, B); NaN if never crossed.

    A crossing on the very last step returns the finite T*dt — distinct
    from never-crossed (an older revision returned the phase window for
    both, silently aliasing a last-step crossing with a timeout).
    """
    any_ok = jnp.any(trace_ok, axis=0)
    idx = jnp.argmax(trace_ok, axis=0)
    return jnp.where(any_ok, (idx + 1) * dt, jnp.nan)


def wl_ramp(tech: TechCal, t_ns: jnp.ndarray, rising: bool = True) -> jnp.ndarray:
    """WL voltage profile (normalized 0..1): RC-limited driver."""
    tau = tau_ns(tech.r_wl_kohm, tech.c_wl_ff)
    x = 1.0 - jnp.exp(-t_ns / jnp.maximum(tau, 1e-3))
    return x if rising else 1.0 - x


def _regen_and_totals(tech_sa_tau, tech_overhead, t_dev, dv_sense,
                      t_res_dur, t_pre):
    """BLSA latch regeneration + phase roll-up (shared by both engines)."""
    vdd = cal.VDD_ARRAY
    t_regen = tech_sa_tau * jnp.log(
        jnp.maximum((vdd / 2.0) / jnp.maximum(dv_sense, 1e-4), 1.001))
    t_sense = t_dev + t_regen
    t_restore = t_sense + t_res_dur
    trc = tech_overhead + t_restore + t_pre
    return t_sense, t_restore, trc


class FusedOperands(NamedTuple):
    """Lowered operand arrays for one flat design-point batch.

    This is the canonical wire format between the DSE layer and the fused
    row-cycle engine: six (B, ...) kernel operands plus the two per-point
    roll-up vectors.  `dse.sweep` lowers a whole DesignSpace into ONE of
    these; `simulate_row_cycle_many` accepts it directly.
    """
    c: jnp.ndarray              # (B, N) node capacitances
    g: jnp.ndarray              # (B, N-1) branch conductances
    gc_res: jnp.ndarray         # (B, N) restore clamp conductances
    gc_pre: jnp.ndarray         # (B, N) precharge clamp conductances
    v0: jnp.ndarray             # (B, N) initial node voltages
    params: jnp.ndarray         # (B, 6) per-point kernel params
    #                             (incl. ACTIVE and ROLE columns)
    sa_tau_ns: jnp.ndarray      # (B,) BLSA regeneration time constants
    t_overhead_ns: jnp.ndarray  # (B,) command/decode overheads
    replica: bool = False       # True -> rows are interleaved
    #                             [replica, main] pairs (replica-closed
    #                             timing); B is twice the design-point count


def lower_operands(c, g, *, r_sa_drive_kohm, r_pre_kohm, store_v, tau_wl_ns,
                   active=None, role=None):
    """Lower ladder arrays + drive parameters to fused-kernel operands.

    Every parameter may be a scalar (one tech) or a (B,) array (the
    vectorized DSE path over mixed techs); `active=0` rows are padding /
    masked-out design points that the kernel starts in the DONE state.
    `role` selects the kernel's SA-enable timing mode per row (see
    `kernels.row_cycle.ROLE_*`; default standalone fixed timing).
    """
    b, n = c.shape
    vdd, vpre = cal.VDD_ARRAY, cal.VBL_PRE
    c = c.astype(jnp.float32)
    g = g.astype(jnp.float32)

    def vec(x):
        return jnp.broadcast_to(jnp.asarray(x, jnp.float32), (b,))

    zeros = jnp.zeros((b, n), jnp.float32)
    gc_res = zeros.at[:, 0].set(vec(1.0 / jnp.asarray(r_sa_drive_kohm)))
    gc_pre = zeros.at[:, : n - 1].set(
        vec(1.0 / jnp.asarray(r_pre_kohm))[:, None])
    store_v = vec(store_v)
    v0 = jnp.full((b, n), vpre, jnp.float32).at[:, n - 1].set(store_v)

    cbl = c[:, : n - 1].sum(-1)
    cs = c[:, n - 1]
    dv_inf = (store_v - vpre) * cs / (cs + cbl)
    params = jnp.stack([
        vec(tau_wl_ns),
        0.9 * dv_inf.astype(jnp.float32),
        jnp.full((b,), vdd, jnp.float32),
        jnp.full((b,), vpre, jnp.float32),
        jnp.ones((b,), jnp.float32) if active is None else vec(active),
        jnp.zeros((b,), jnp.float32) if role is None else vec(role),
    ], axis=1)
    return c, g, gc_res, gc_pre, v0, params


def _fused_operands(ladder: Ladder, tech: TechCal, store_v: float,
                    role=None):
    """Assemble the fused-engine operand arrays for one (tech, scheme)."""
    return lower_operands(
        ladder.c, ladder.g_branch,
        r_sa_drive_kohm=tech.r_sa_drive_kohm, r_pre_kohm=tech.r_pre_kohm,
        store_v=store_v, tau_wl_ns=tau_ns(tech.r_wl_kohm, tech.c_wl_ff),
        role=role)


def _interleave(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row-interleave two equally-shaped batches: [a0, b0, a1, b1, ...]."""
    return jnp.stack([a, b], axis=1).reshape((-1,) + a.shape[1:])


def lower_design_operands(view, ladder_c=None, ladder_g=None,
                          par=None) -> FusedOperands:
    """Lower a whole design space view to ONE fused-engine operand batch.

    `view` follows the LoweredSpace protocol (`core.space`); ladder arrays
    / parasitics are rebuilt unless passed in.  Masked-out points
    (`view.valid == False`) become inactive kernel rows.

    Monte-Carlo spaces need no special handling here: the per-sample Vth
    draw is already folded into the access-transistor conductance by
    `parasitics.bl_parasitics_lowered`, so the sampled rows flow through
    the same single chunked fused dispatch as nominal design points.

    When `view.replica` is set, every design point lowers to TWO adjacent
    kernel rows — [replica, main] — with the replica's ladder derived from
    the SAME parasitics (so MC Vth draws perturb both), storage scaled by
    the tech's `replica_cells`, and role columns wiring the replica's ACT
    crossing to the main row's SA enable.  All batch boundaries downstream
    (B_ALIGN padding, chunking, Pallas blocks, device slabs) are even, so
    a pair is never split.
    """
    if ladder_c is None or ladder_g is None:
        ladder_c, ladder_g = build_ladder_lowered(view, par)
    replica = bool(getattr(view, "replica", False))
    b = ladder_c.shape[0]
    active = view.valid.astype(jnp.float32)
    sa_tau = jnp.broadcast_to(
        jnp.asarray(view.tech("sa_tau_ns"), jnp.float32), (b,))
    overhead = jnp.broadcast_to(
        jnp.asarray(view.tech("t_overhead_ns"), jnp.float32), (b,))
    tau_wl = tau_ns(view.tech("r_wl_kohm"), view.tech("c_wl_ff"))
    core = lower_operands(
        ladder_c, ladder_g,
        r_sa_drive_kohm=view.tech("r_sa_drive_kohm"),
        r_pre_kohm=view.tech("r_pre_kohm"),
        store_v=view.tech("writeback_eff") * cal.VDD_ARRAY,
        tau_wl_ns=tau_wl,
        active=active,
        role=ROLE_MAIN if replica else None)
    if replica:
        rep_c, rep_g = replica_ladder_arrays(
            ladder_c, ladder_g, view.tech("replica_cells"))
        rep = lower_operands(
            rep_c, rep_g,
            r_sa_drive_kohm=view.tech("r_sa_drive_kohm"),
            r_pre_kohm=view.tech("r_pre_kohm"),
            store_v=view.tech("replica_store_frac") * cal.VDD_ARRAY,
            tau_wl_ns=tau_wl,
            active=active,
            role=ROLE_REPLICA)
        core = tuple(_interleave(r, m) for r, m in zip(rep, core))
        sa_tau = _interleave(sa_tau, sa_tau)
        overhead = _interleave(overhead, overhead)
    operands = FusedOperands(
        *core, sa_tau_ns=sa_tau, t_overhead_ns=overhead, replica=replica)
    contracts.check_operands(operands, where="transient.lower_design_operands")
    return operands


# Fused-engine batches are padded (with inactive design points) up to a
# multiple of this, so arbitrary small batches share one compiled shape —
# the while-loop engine's jit trace is the dominant one-off cost.
B_ALIGN = 64


def _pad_operands(operands, pad: int):
    """Append `pad` inactive design points (params[:, ACTIVE] = 0)."""
    if not pad:
        return list(operands)
    padf = lambda x, v: jnp.pad(x, ((0, pad), (0, 0)), constant_values=v)
    padded = [padf(x, 1.0) for x in operands[:5]]
    padded.append(padf(operands[5], 0.0))
    return padded


def validate_b_chunk(b_chunk: int) -> int:
    """Check a fused-engine chunk size; returns it as an int.

    Chunks are the caller's memory bound, so they must be honorable
    exactly: every dispatch is padded to a B_ALIGN multiple for compiled-
    shape sharing, and a `b_chunk` that is not itself a B_ALIGN multiple
    would force either an unaligned shape or a silently larger pad.
    """
    b_chunk = int(b_chunk)
    if b_chunk < B_ALIGN or b_chunk % B_ALIGN:
        raise ValueError(
            f"b_chunk={b_chunk} must be a positive multiple of B_ALIGN "
            f"({B_ALIGN}); smaller or unaligned chunks cannot be honored "
            "without exceeding the requested memory bound")
    return b_chunk


def _row_cycle_fused_chunked(operands, backend: str, b_chunk: int):
    """Feed (c, g, gc_res, gc_pre, v0, params) through the fused engine in
    fixed-size chunks so arbitrary sweep grids fit VMEM/HBM.

    Every call is padded with inactive design points to a B_ALIGN multiple
    no larger than `b_chunk` (which must itself be a B_ALIGN multiple), so
    calls share compiled shapes and never exceed the caller's memory bound.
    """
    b_chunk = validate_b_chunk(b_chunk)
    c = operands[0]
    b = c.shape[0]
    if b <= b_chunk:
        target = min(-(-b // B_ALIGN) * B_ALIGN, b_chunk)
        padded = _pad_operands(operands, target - b)
        evt, v_end = ops.row_cycle_fused(*padded, DT_NS, N_ACT_STEPS,
                                         N_RESTORE_STEPS, N_PRE_STEPS,
                                         backend=backend)
        return evt[:b], v_end[:b]
    pad = (-b) % b_chunk
    ops_padded = _pad_operands(operands, pad)
    evts, vends = [], []
    for lo in range(0, b + pad, b_chunk):
        chunk = [x[lo:lo + b_chunk] for x in ops_padded]
        evt, v_end = ops.row_cycle_fused(*chunk, DT_NS, N_ACT_STEPS,
                                         N_RESTORE_STEPS, N_PRE_STEPS,
                                         backend=backend)
        evts.append(evt)
        vends.append(v_end)
    return (jnp.concatenate(evts, axis=0)[:b],
            jnp.concatenate(vends, axis=0)[:b])


def simulate_row_cycle(tech: TechCal, scheme: str, layers,
                       store_v: float | None = None,
                       backend: str = "auto",
                       traces: bool = False,
                       b_chunk: int = DEFAULT_B_CHUNK,
                       replica: bool = False) -> RowCycleResult:
    """Simulate ACT/RESTORE/PRE on the ladder; batched over `layers`.

    Default path is the fused trace-free engine; pass ``traces=True`` to run
    the phased three-call engine and get the full (T, B, N) waveforms
    (Fig. 8 plotting).  ``replica=True`` closes the SA-enable timing with a
    replica bitline (scaled by ``tech.replica_cells``) instead of the fixed
    own-90% crossing.
    """
    if traces:
        return simulate_row_cycle_phased(tech, scheme, layers,
                                         store_v=store_v, backend=backend,
                                         replica=replica)
    ladder = build_bl_ladder(tech, scheme, layers)
    if store_v is None:
        store_v = tech.writeback_eff * cal.VDD_ARRAY
    if replica:
        main = _fused_operands(ladder, tech, store_v, role=ROLE_MAIN)
        rep_c, rep_g = replica_ladder_arrays(ladder.c, ladder.g_branch,
                                             tech.replica_cells)
        rep = lower_operands(
            rep_c, rep_g,
            r_sa_drive_kohm=tech.r_sa_drive_kohm,
            r_pre_kohm=tech.r_pre_kohm,
            store_v=tech.replica_store_frac * cal.VDD_ARRAY,
            tau_wl_ns=tau_ns(tech.r_wl_kohm, tech.c_wl_ff),
            role=ROLE_REPLICA)
        operands = tuple(_interleave(r, m) for r, m in zip(rep, main))
        evt, _ = _row_cycle_fused_chunked(operands, backend, b_chunk)
        evt = evt[1::2]
    else:
        operands = _fused_operands(ladder, tech, store_v)
        evt, _ = _row_cycle_fused_chunked(operands, backend, b_chunk)
    t_dev, dv_sense, t_res_dur, t_pre = (evt[:, 0], evt[:, 1],
                                         evt[:, 2], evt[:, 3])
    t_sense, t_restore, trc = _regen_and_totals(
        tech.sa_tau_ns, tech.t_overhead_ns, t_dev, dv_sense, t_res_dur, t_pre)
    return RowCycleResult(
        t_sense_ns=t_sense, t_restore_ns=t_restore, t_precharge_ns=t_pre,
        trc_ns=trc, dv_sense_v=dv_sense, traces={}, t_fire_ns=t_dev)


def result_from_events(operands: FusedOperands,
                       evt: jnp.ndarray) -> RowCycleResult:
    """Roll fused-engine event columns up into a `RowCycleResult`.

    Shared by the sequential path below and the sharded driver
    (`launch.shard`), so the two can never diverge in how events map to
    result fields — a precondition of their bit-equivalence contract.

    Replica-interleaved batches are de-interleaved here: the replica rows
    (even indices) only exist to time the main rows' SA enable, so the
    result covers the main rows (odd indices) and has the design-point
    length the caller handed to `lower_design_operands`.
    """
    raw = evt
    sa_tau, overhead = operands.sa_tau_ns, operands.t_overhead_ns
    if getattr(operands, "replica", False):
        evt = evt[1::2]
        sa_tau = sa_tau[1::2]
        overhead = overhead[1::2]
    t_sense, t_restore, trc = _regen_and_totals(
        sa_tau, overhead, evt[:, 0], evt[:, 1], evt[:, 2], evt[:, 3])
    return RowCycleResult(
        t_sense_ns=t_sense, t_restore_ns=t_restore,
        t_precharge_ns=evt[:, 3], trc_ns=trc,
        dv_sense_v=evt[:, 1], traces={}, t_fire_ns=evt[:, 0], events=raw)


def row_cycle_events(operands: FusedOperands, backend: str = "auto",
                     b_chunk: int = DEFAULT_B_CHUNK) -> jnp.ndarray:
    """Raw fused-engine event columns for a lowered operand batch -> (B, 4).

    The pre-rollup view of `simulate_row_cycle_lowered`: one chunked pass
    through the fused engine, no `_regen_and_totals`, no replica
    de-interleave.  This is the serving layer's packing seam — many
    requests' operand batches can be concatenated, dispatched once, and
    the event rows sliced back per request before each request's own
    `result_from_events` rollup (which is where replica pairs collapse).
    """
    evt, _ = _row_cycle_fused_chunked(operands[:6], backend, b_chunk)
    return evt


def simulate_row_cycle_lowered(operands: FusedOperands,
                               backend: str = "auto",
                               b_chunk: int = DEFAULT_B_CHUNK) -> RowCycleResult:
    """Fused row-cycle over an already-lowered flat operand batch.

    This is the array-native entry point of the engine: the DSE sweep
    lowers its whole (tech x scheme x layers [x corners]) space to ONE
    `FusedOperands` and gets ONE trace-free `RowCycleResult` back, with no
    per-combo Python loop anywhere.
    """
    evt, _ = _row_cycle_fused_chunked(operands[:6], backend, b_chunk)
    return result_from_events(operands, evt)


def simulate_row_cycle_many(entries, backend: str = "auto",
                            b_chunk: int = DEFAULT_B_CHUNK):
    """Fused row-cycle over many (tech, scheme, layers) combos at once.

    `entries` is either a sequence of (TechCal, scheme, layers-array)
    tuples, or an already-lowered `FusedOperands` batch (from
    `lower_design_operands`), which is dispatched directly.  All design
    points are flattened into ONE batch through the fused engine (chunked
    to `b_chunk`), instead of one transient call per combo — this is what
    makes `dse.sweep` a single vectorized evaluation.  Returns one
    trace-free RowCycleResult per entry (or one flat result for a lowered
    batch).
    """
    if isinstance(entries, FusedOperands):
        return simulate_row_cycle_lowered(entries, backend, b_chunk)

    per_entry = []
    cs, gs, gcrs, gcps, v0s, pars = [], [], [], [], [], []
    sa_taus, overheads = [], []
    for tech, scheme, layers in entries:
        ladder = build_bl_ladder(tech, scheme, layers)
        store_v = tech.writeback_eff * cal.VDD_ARRAY
        c, g, gc_res, gc_pre, v0, params = _fused_operands(
            ladder, tech, store_v)
        b = c.shape[0]
        per_entry.append(b)
        cs.append(c); gs.append(g); gcrs.append(gc_res); gcps.append(gc_pre)
        v0s.append(v0); pars.append(params)
        sa_taus.append(jnp.full((b,), tech.sa_tau_ns, jnp.float32))
        overheads.append(jnp.full((b,), tech.t_overhead_ns, jnp.float32))

    operands = FusedOperands(
        *(jnp.concatenate(xs, axis=0)
          for xs in (cs, gs, gcrs, gcps, v0s, pars)),
        sa_tau_ns=jnp.concatenate(sa_taus),
        t_overhead_ns=jnp.concatenate(overheads))
    flat = simulate_row_cycle_lowered(operands, backend, b_chunk)

    results, lo = [], 0
    for b in per_entry:
        sl = slice(lo, lo + b)
        results.append(RowCycleResult(
            t_sense_ns=flat.t_sense_ns[sl], t_restore_ns=flat.t_restore_ns[sl],
            t_precharge_ns=flat.t_precharge_ns[sl], trc_ns=flat.trc_ns[sl],
            dv_sense_v=flat.dv_sense_v[sl], traces={}))
        lo += b
    return results


def simulate_row_cycle_phased(tech: TechCal, scheme: str, layers,
                              store_v: float | None = None,
                              backend: str = "ref",
                              replica: bool = False) -> RowCycleResult:
    """Phased three-call engine: materializes full (T, B, N) waveforms.

    This is the Fig. 8 plotting path and the reference the fused engine is
    validated against (event times within one dt) — including the
    replica-closed timing mode, where the SA enable fires on the replica
    bitline's own first crossing instead of the main array's.
    """
    ladder = build_bl_ladder(tech, scheme, layers)
    b, n = ladder.c.shape
    vdd, vpre = cal.VDD_ARRAY, cal.VBL_PRE
    if store_v is None:
        store_v = tech.writeback_eff * vdd

    c = ladder.c.astype(jnp.float32)
    g = ladder.g_branch.astype(jnp.float32)
    zero_clamp = jnp.zeros((b, n), jnp.float32)

    # ---------------- ACT: WL up, charge share --------------------------
    n_act = N_ACT_STEPS
    t_grid = (jnp.arange(n_act) + 1) * DT_NS
    ramp_up = wl_ramp(tech, t_grid).astype(jnp.float32)
    v0 = jnp.full((b, n), vpre, jnp.float32).at[:, n - 1].set(store_v)
    trace_act = ops.rc_multistep(c, g, zero_clamp, zero_clamp, v0,
                                 ramp_up, DT_NS, backend=backend)

    if replica:
        # replica column: same ladder with the storage end scaled by the
        # replica cell count; its OWN 90% crossing fires the SA enable.
        rep_c, rep_g = replica_ladder_arrays(ladder.c, ladder.g_branch,
                                             tech.replica_cells)
        rep_c = rep_c.astype(jnp.float32)
        rep_g = rep_g.astype(jnp.float32)
        rep_store = tech.replica_store_frac * vdd
        rep_v0 = jnp.full((b, n), vpre, jnp.float32).at[:, n - 1].set(
            rep_store)
        trace_rep = ops.rc_multistep(rep_c, rep_g, zero_clamp, zero_clamp,
                                     rep_v0, ramp_up, DT_NS, backend=backend)
        rep_cbl = rep_c[:, :n - 1].sum(-1)
        rep_cs = rep_c[:, n - 1]
        rep_dv_inf = (rep_store - vpre) * rep_cs / (rep_cs + rep_cbl)
        crossed = (trace_rep[:, :, 0] - vpre
                   >= 0.9 * rep_dv_inf[None, :].astype(jnp.float32))
    else:
        cbl = ladder.c[:, :n - 1].sum(-1)
        cs = ladder.c[:, n - 1]
        dv_inf = (store_v - vpre) * cs / (cs + cbl)
        crossed = (trace_act[:, :, 0] - vpre
                   >= 0.9 * dv_inf[None, :].astype(jnp.float32))
    t_dev = _first_crossing_ns(crossed, DT_NS)

    # developed signal actually available at SA enable; a NaN (never
    # crossed) t_dev keeps the downstream phases well-defined by indexing
    # the end of the ACT window — the NaN still propagates into
    # t_sense/trc through `_regen_and_totals`.
    t_dev_idx = jnp.where(jnp.isnan(t_dev), T_ACT_NS, t_dev)
    idx_dev = jnp.clip((t_dev_idx / DT_NS).astype(jnp.int32) - 1, 0,
                       n_act - 1)
    dv_sense = trace_act[idx_dev, jnp.arange(b), 0] - vpre

    # ---------------- RESTORE: SA drives the rail -----------------------
    n_res = N_RESTORE_STEPS
    # state at SA enable: take the trace at t_dev (per design point)
    v_at_dev = trace_act[idx_dev, jnp.arange(b), :]
    g_clamp_res = zero_clamp.at[:, 0].set(1.0 / tech.r_sa_drive_kohm)
    v_clamp_res = jnp.full((b, n), vdd, jnp.float32)
    ramp_on = jnp.ones((n_res,), jnp.float32)
    trace_res = ops.rc_multistep(c, g, g_clamp_res, v_clamp_res, v_at_dev,
                                 ramp_on, DT_NS, backend=backend)
    restored = trace_res[:, :, n - 1] >= 0.95 * vdd
    t_res_dur = _first_crossing_ns(restored, DT_NS)

    # ---------------- PRE: WL down, equalize ----------------------------
    n_pre = N_PRE_STEPS
    t_grid_pre = (jnp.arange(n_pre) + 1) * DT_NS
    ramp_down = wl_ramp(tech, t_grid_pre, rising=False).astype(jnp.float32)
    t_res_idx = jnp.where(jnp.isnan(t_res_dur), T_RESTORE_NS, t_res_dur)
    idx_res = jnp.clip((t_res_idx / DT_NS).astype(jnp.int32) - 1, 0,
                       n_res - 1)
    v_end_res = trace_res[idx_res, jnp.arange(b), :]
    g_clamp_pre = zero_clamp.at[:, :n - 1].set(1.0 / tech.r_pre_kohm)
    v_clamp_pre = jnp.full((b, n), vpre, jnp.float32)
    trace_pre = ops.rc_multistep(c, g, g_clamp_pre, v_clamp_pre, v_end_res,
                                 ramp_down, DT_NS, backend=backend)
    equalized = jnp.max(jnp.abs(trace_pre[:, :, :n - 1] - vpre), axis=-1) <= 5e-3
    t_pre = _first_crossing_ns(equalized, DT_NS)

    t_sense, t_restore, trc = _regen_and_totals(
        tech.sa_tau_ns, tech.t_overhead_ns, t_dev, dv_sense, t_res_dur, t_pre)
    traces = {"act": trace_act, "restore": trace_res, "pre": trace_pre}
    if replica:
        traces["replica"] = trace_rep
    return RowCycleResult(
        t_sense_ns=t_sense, t_restore_ns=t_restore, t_precharge_ns=t_pre,
        trc_ns=trc, dv_sense_v=dv_sense, traces=traces, t_fire_ns=t_dev)


def nominal_trc_ns(tech: TechCal, scheme: str = "sel_strap",
                   layers: int | None = None) -> jnp.ndarray:
    """Nominal tRC at the technology's target layer count."""
    if layers is None:
        layers = tech.layers_target
    return simulate_row_cycle(tech, scheme, jnp.asarray([layers])).trc_ns[0]
