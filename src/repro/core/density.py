"""Bit density, stack height and scaling projections (Fig. 9a).

  density(L)  = L * array_efficiency / cell_area
  height(L)   = L * layer_height
  layers(rho) = ceil(rho * cell_area / array_efficiency)
"""

from __future__ import annotations

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .units import GBIT, NM2_PER_MM2


def cell_area_nm2(tech: TechCal) -> float:
    return tech.cell_x_nm * tech.cell_y_nm


def bit_density_gb_mm2(tech: TechCal, layers) -> jnp.ndarray:
    if tech.baseline_2d:
        return jnp.full_like(jnp.asarray(layers, jnp.float32),
                             tech.fixed_density_gb_mm2)
    layers = jnp.asarray(layers, jnp.float32)
    per_layer = tech.array_efficiency / cell_area_nm2(tech) * NM2_PER_MM2 / GBIT
    return layers * per_layer


def bit_density_lowered(view) -> jnp.ndarray:
    """Array-native bit density over a lowered design space (see core.space)."""
    baseline = view.tech("baseline_2d")
    area = view.tech("cell_x_nm") * view.tech("cell_y_nm")
    per_layer = (view.tech("array_efficiency")
                 / jnp.where(area > 0, area, 1.0) * NM2_PER_MM2 / GBIT)
    return jnp.where(baseline, view.tech("fixed_density_gb_mm2"),
                     view.layers * per_layer).astype(jnp.float32)


def stack_height_lowered(view) -> jnp.ndarray:
    """Array-native stack height over a lowered design space."""
    return (view.layers * view.tech("layer_height_nm") * 1e-3).astype(jnp.float32)


def layers_for_density(tech: TechCal, density_gb_mm2) -> jnp.ndarray:
    density = jnp.asarray(density_gb_mm2, jnp.float32)
    per_layer = tech.array_efficiency / cell_area_nm2(tech) * NM2_PER_MM2 / GBIT
    return jnp.ceil(density / per_layer).astype(jnp.int32)


def stack_height_um(tech: TechCal, layers) -> jnp.ndarray:
    layers = jnp.asarray(layers, jnp.float32)
    return layers * tech.layer_height_nm * 1e-3


def density_scaling_vs_d1b(tech: TechCal, layers) -> jnp.ndarray:
    return bit_density_gb_mm2(tech, layers) / cal.D1B_BIT_DENSITY_GB_MM2
