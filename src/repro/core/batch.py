"""Structure-of-arrays design batches — the result half of the DSE API.

A `DesignBatch` holds every scored metric of a design-space sweep as one
flat jnp array per field (plus a validity mask), registered as a JAX
pytree: it `jit`s, `tree_map`s, and shards.  The batch axis is the ONLY
axis, so distributing a million-point sweep is literally

    batch = jax.device_put(batch, NamedSharding(mesh, P("batch")))

(or `batch.device_put(sharding)`), after `pad_to()`-aligning the axis to
the device count.  `to_points()` is the thin legacy view producing the old
`list[DesignPoint]` contract.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np
import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DesignPoint:
    """Scalar view of one design point.

    Deprecated as a bulk interface: `dse.sweep` returns a `DesignBatch`,
    and list-of-points consumers should migrate to its array fields.
    `DesignBatch.to_points()` keeps this contract alive in the meantime.
    """
    tech: str
    scheme: str
    layers: int
    density_gb_mm2: float
    height_um: float
    cbl_ff: float
    margin_mv: float
    margin_disturbed_mv: float
    trc_ns: float
    e_write_fj: float
    e_read_fj: float
    hcb_pitch_um: float
    blsa_area_um2: float
    feasible: bool


# Array leaves of the pytree, in flatten order.  All shaped (B,) on the
# single shardable batch axis.
ARRAY_FIELDS = (
    "tech_idx", "scheme_idx", "layers",
    "density_gb_mm2", "height_um", "cbl_ff",
    "margin_mv", "margin_disturbed_mv",
    "trc_ns", "t_sense_ns",
    "e_write_fj", "e_read_fj",
    "hcb_pitch_um", "blsa_area_um2",
    "manufacturable", "feasible", "valid",
)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DesignBatch:
    """One design-space sweep as a structure-of-arrays pytree.

    `tech_idx`/`scheme_idx` index the static `tech_names`/`scheme_names`
    tables (pytree aux data, so they survive jit/flatten round-trips
    without becoming tracers).  `valid` masks padding rows added by
    `pad_to`; every reduction in the DSE layer respects it.
    """

    tech_idx: jnp.ndarray            # (B,) int32 into tech_names
    scheme_idx: jnp.ndarray          # (B,) int32 into scheme_names
    layers: jnp.ndarray              # (B,) float32
    density_gb_mm2: jnp.ndarray      # (B,) float32
    height_um: jnp.ndarray           # (B,) float32
    cbl_ff: jnp.ndarray              # (B,) float32
    margin_mv: jnp.ndarray           # (B,) float32
    margin_disturbed_mv: jnp.ndarray # (B,) float32
    trc_ns: jnp.ndarray              # (B,) float32 (NaN when transient off)
    t_sense_ns: jnp.ndarray          # (B,) float32 (NaN when transient off)
    e_write_fj: jnp.ndarray          # (B,) float32
    e_read_fj: jnp.ndarray           # (B,) float32
    hcb_pitch_um: jnp.ndarray        # (B,) float32
    blsa_area_um2: jnp.ndarray       # (B,) float32
    manufacturable: jnp.ndarray      # (B,) bool
    feasible: jnp.ndarray            # (B,) bool
    valid: jnp.ndarray               # (B,) bool
    corners: dict                    # axis name -> (B,) float32
    tech_names: tuple = ()           # static lookup tables (aux data)
    scheme_names: tuple = ()

    # ------------------------------------------------------------ pytree --
    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in ARRAY_FIELDS)
        children += (self.corners,)
        return children, (self.tech_names, self.scheme_names)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tech_names, scheme_names = aux
        kwargs = dict(zip(ARRAY_FIELDS, children[:-1]))
        return cls(corners=children[-1], tech_names=tech_names,
                   scheme_names=scheme_names, **kwargs)

    # ------------------------------------------------------------- shape --
    def __len__(self) -> int:
        return int(self.tech_idx.shape[0])

    @property
    def n_valid(self) -> int:
        return int(np.asarray(self.valid).sum())

    @property
    def tech_col(self) -> list:
        """Per-row tech names (host-side convenience)."""
        return [self.tech_names[i] for i in np.asarray(self.tech_idx)]

    @property
    def scheme_col(self) -> list:
        """Per-row scheme names (host-side convenience)."""
        return [self.scheme_names[i] for i in np.asarray(self.scheme_idx)]

    def select(self, where) -> "DesignBatch":
        """Rows selected by a boolean mask or index array (host-side)."""
        idx = np.asarray(where)
        if idx.dtype == bool:
            idx = np.flatnonzero(idx)
        take = lambda a: jnp.asarray(a)[idx]
        return jax.tree_util.tree_map(take, self)

    def pad_to(self, multiple: int) -> "DesignBatch":
        """Pad the batch axis up to a multiple (sharding/chunk alignment).

        Padding rows have `valid=False` and zeros elsewhere; every DSE
        reduction and `to_points()` ignores them.
        """
        b = len(self)
        pad = (-b) % multiple
        if not pad:
            return self
        def padarr(a):
            a = jnp.asarray(a)
            return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
        return jax.tree_util.tree_map(padarr, self)

    def device_put(self, sharding) -> "DesignBatch":
        """Place every leaf with the given jax.sharding / device."""
        return jax.device_put(self, sharding)

    # ------------------------------------------------------ legacy views --
    def point(self, i: int) -> DesignPoint:
        """Scalar `DesignPoint` view of row `i`."""
        col = lambda f: np.asarray(getattr(self, f))[i]
        return DesignPoint(
            tech=self.tech_names[int(col("tech_idx"))],
            scheme=self.scheme_names[int(col("scheme_idx"))],
            layers=int(col("layers")),
            density_gb_mm2=float(col("density_gb_mm2")),
            height_um=float(col("height_um")),
            cbl_ff=float(col("cbl_ff")),
            margin_mv=float(col("margin_mv")),
            margin_disturbed_mv=float(col("margin_disturbed_mv")),
            trc_ns=float(col("trc_ns")),
            e_write_fj=float(col("e_write_fj")),
            e_read_fj=float(col("e_read_fj")),
            hcb_pitch_um=float(col("hcb_pitch_um")),
            blsa_area_um2=float(col("blsa_area_um2")),
            feasible=bool(col("feasible")))

    def to_points(self) -> list:
        """Deprecated compatibility view: the old `list[DesignPoint]`
        contract of `full_sweep`.  Skips invalid (padding) rows.  New code
        should consume the array fields directly."""
        valid = np.asarray(self.valid)
        return [self.point(i) for i in np.flatnonzero(valid)]

    @classmethod
    def from_points(cls, points) -> "DesignBatch":
        """Build a batch from legacy `DesignPoint`s (or anything with the
        same attributes); the bridge for list-based callers.

        `DesignPoint` does not record manufacturability (only the combined
        `feasible` verdict), so the bridged `manufacturable` column is a
        placeholder (all True) — consume it only on batches produced by
        `dse.sweep`.  `t_sense_ns` is likewise NaN here."""
        points = list(points)
        tech_names: list = []
        scheme_names: list = []
        for p in points:
            if p.tech not in tech_names:
                tech_names.append(p.tech)
            if p.scheme not in scheme_names:
                scheme_names.append(p.scheme)
        f32 = lambda f: jnp.asarray([getattr(p, f) for p in points],
                                    jnp.float32)
        b = len(points)
        return cls(
            tech_idx=jnp.asarray([tech_names.index(p.tech) for p in points],
                                 jnp.int32),
            scheme_idx=jnp.asarray(
                [scheme_names.index(p.scheme) for p in points], jnp.int32),
            layers=f32("layers"),
            density_gb_mm2=f32("density_gb_mm2"), height_um=f32("height_um"),
            cbl_ff=f32("cbl_ff"), margin_mv=f32("margin_mv"),
            margin_disturbed_mv=f32("margin_disturbed_mv"),
            trc_ns=f32("trc_ns"),
            t_sense_ns=jnp.full((b,), jnp.nan, jnp.float32),
            e_write_fj=f32("e_write_fj"), e_read_fj=f32("e_read_fj"),
            hcb_pitch_um=f32("hcb_pitch_um"),
            blsa_area_um2=f32("blsa_area_um2"),
            manufacturable=jnp.ones((b,), bool),   # not in DesignPoint
            feasible=jnp.asarray([bool(p.feasible) for p in points], bool),
            valid=jnp.ones((b,), bool),
            corners={},
            tech_names=tuple(tech_names), scheme_names=tuple(scheme_names))
