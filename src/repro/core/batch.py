"""Structure-of-arrays design batches — the result half of the DSE API.

A `DesignBatch` holds every scored metric of a design-space sweep as one
flat jnp array per field (plus a validity mask), registered as a JAX
pytree: it `jit`s, `tree_map`s, and shards.  The batch axis is the ONLY
axis, so distributing a million-point sweep is literally

    batch = jax.device_put(batch, NamedSharding(mesh, P("batch")))

(or `batch.device_put(sharding)`), after `pad_to()`-aligning the axis to
the device count.  `to_points()` is the thin legacy view producing the old
`list[DesignPoint]` contract.

Monte-Carlo sweeps (`DesignSpace.with_mc`) keep the SAME flat layout:
sample s of base design i sits at row `s * base_len + i`, and the batch
records `n_samples` / `base_len` as static aux data.  The yield views
(`yield_fraction`, `quantile`, `mc_summary`) are masked segment
reductions over that flat axis — no second array axis ever appears, so
jit/tree_map/sharding semantics are unchanged.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace

import numpy as np
import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DesignPoint:
    """Scalar view of one design point.

    Deprecated as a bulk interface: `dse.sweep` returns a `DesignBatch`,
    and list-of-points consumers should migrate to its array fields.
    `DesignBatch.to_points()` keeps this contract alive in the meantime.
    """
    tech: str
    scheme: str
    layers: int
    density_gb_mm2: float
    height_um: float
    cbl_ff: float
    margin_mv: float
    margin_disturbed_mv: float
    trc_ns: float
    e_write_fj: float
    e_read_fj: float
    hcb_pitch_um: float
    blsa_area_um2: float
    feasible: bool


# Array leaves of the pytree, in flatten order.  All shaped (B,) on the
# single shardable batch axis.
ARRAY_FIELDS = (
    "tech_idx", "scheme_idx", "layers",
    "density_gb_mm2", "height_um", "cbl_ff",
    "margin_mv", "margin_disturbed_mv",
    "trc_ns", "t_sense_ns", "t_fire_ns", "margin_fire_mv",
    "e_write_fj", "e_read_fj",
    "hcb_pitch_um", "blsa_area_um2",
    "manufacturable", "feasible", "valid",
)

# Columns a with_mc sweep actually perturbs (per-sample SA offset enters
# the margins; the Vth draw enters the access conductance, hence timing).
MC_SAMPLED_FIELDS = ("margin_mv", "margin_disturbed_mv",
                     "trc_ns", "t_sense_ns", "t_fire_ns", "margin_fire_mv")


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DesignBatch:
    """One design-space sweep as a structure-of-arrays pytree.

    `tech_idx`/`scheme_idx` index the static `tech_names`/`scheme_names`
    tables (pytree aux data, so they survive jit/flatten round-trips
    without becoming tracers).  `valid` masks padding rows added by
    `pad_to`; every reduction in the DSE layer respects it.
    """

    tech_idx: jnp.ndarray            # (B,) int32 into tech_names
    scheme_idx: jnp.ndarray          # (B,) int32 into scheme_names
    layers: jnp.ndarray              # (B,) float32
    density_gb_mm2: jnp.ndarray      # (B,) float32
    height_um: jnp.ndarray           # (B,) float32
    cbl_ff: jnp.ndarray              # (B,) float32
    margin_mv: jnp.ndarray           # (B,) float32
    margin_disturbed_mv: jnp.ndarray # (B,) float32
    trc_ns: jnp.ndarray              # (B,) float32 (NaN when transient off)
    t_sense_ns: jnp.ndarray          # (B,) float32 (NaN when transient off)
    t_fire_ns: jnp.ndarray           # (B,) float32 SA-enable fire time
    #                                  (replica-closed when the space
    #                                  declared with_replica; NaN when the
    #                                  transient is off or timing never
    #                                  closed)
    margin_fire_mv: jnp.ndarray      # (B,) float32 sense margin at the
    #                                  actual SA fire (dv at fire - offset)
    e_write_fj: jnp.ndarray          # (B,) float32
    e_read_fj: jnp.ndarray           # (B,) float32
    hcb_pitch_um: jnp.ndarray        # (B,) float32
    blsa_area_um2: jnp.ndarray       # (B,) float32
    manufacturable: jnp.ndarray      # (B,) bool
    feasible: jnp.ndarray            # (B,) bool
    valid: jnp.ndarray               # (B,) bool
    corners: dict                    # axis name -> (B,) float32
    tech_names: tuple = ()           # static lookup tables (aux data)
    scheme_names: tuple = ()
    n_samples: int = 1               # MC sample fan-out (1 = nominal sweep)
    base_len: int = 0                # design points per sample (0 = len)

    # ------------------------------------------------------------ pytree --
    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in ARRAY_FIELDS)
        children += (self.corners,)
        return children, (self.tech_names, self.scheme_names,
                          self.n_samples, self.base_len)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tech_names, scheme_names, n_samples, base_len = aux
        kwargs = dict(zip(ARRAY_FIELDS, children[:-1]))
        return cls(corners=children[-1], tech_names=tech_names,
                   scheme_names=scheme_names, n_samples=n_samples,
                   base_len=base_len, **kwargs)

    # ------------------------------------------------------------- shape --
    def __len__(self) -> int:
        return int(self.tech_idx.shape[0])

    @property
    def n_valid(self) -> int:
        return int(np.asarray(self.valid).sum())

    @property
    def tech_col(self) -> list:
        """Per-row tech names (host-side convenience)."""
        return [self.tech_names[i] for i in np.asarray(self.tech_idx)]  # repro-lint: disable=RL002  (host-side report view, not sweep-path compute)

    @property
    def scheme_col(self) -> list:
        """Per-row scheme names (host-side convenience)."""
        return [self.scheme_names[i] for i in np.asarray(self.scheme_idx)]  # repro-lint: disable=RL002  (host-side report view, not sweep-path compute)

    def select(self, where) -> "DesignBatch":
        """Rows selected by a boolean mask or index array (host-side).

        Selecting rows of a Monte-Carlo batch destroys the sample-major
        layout the MC reductions assume, so the MC aux is cleared to a
        sentinel (`n_samples=0`): stale `yield_fraction`/`quantile`/
        `mc_summary` calls on the selection raise instead of silently
        reducing a broken layout.  Reduce first (`mc_summary`) and select
        the per-design summary instead.
        """
        idx = np.asarray(where)
        if idx.dtype == bool:
            idx = np.flatnonzero(idx)
        take = lambda a: jnp.asarray(a)[idx]
        out = jax.tree_util.tree_map(take, self)
        return replace(out, n_samples=0 if self.n_samples != 1 else 1,
                       base_len=0)

    def slice_rows(self, start: int, stop: int) -> "DesignBatch":
        """Contiguous row slice [start:stop) — the demux/streaming helper.

        Cheaper and more explicit than `select` for the serving layer's
        per-client slab slices and per-chunk streaming: no index
        materialization, plain array slicing on every leaf.  Like
        `select`, slicing a Monte-Carlo batch destroys the sample-major
        layout, so the MC aux is cleared to the `n_samples=0` sentinel
        unless the batch was a plain (n_samples == 1) sweep.
        """
        start, stop = int(start), int(stop)
        if not 0 <= start <= stop <= len(self):
            raise ValueError(
                f"slice_rows [{start}:{stop}) out of range for a "
                f"{len(self)}-row batch")
        cut = lambda a: jnp.asarray(a)[start:stop]
        out = jax.tree_util.tree_map(cut, self)
        return replace(out, n_samples=0 if self.n_samples != 1 else 1,
                       base_len=0)

    @classmethod
    def concat(cls, batches) -> "DesignBatch":
        """Merge batches row-wise into one flat batch — the micro-batch
        packing helper.

        Name tables are unioned (indices remapped per input batch), so
        batches from different sweeps compose.  All inputs must carry the
        same corner channels and be plain (n_samples == 1) batches —
        concatenating sample-major MC layouts would interleave segments
        of different bases, so MC batches must be `mc_summary`-reduced
        first.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("concat needs at least one batch")
        corner_keys = set(batches[0].corners)
        for b in batches[1:]:
            if set(b.corners) != corner_keys:
                raise ValueError(
                    "concat needs identical corner channels on every "
                    f"batch (got {sorted(corner_keys)} vs "
                    f"{sorted(b.corners)})")
        if any(b.n_samples != 1 for b in batches):
            raise ValueError(
                "concat only composes plain (n_samples == 1) batches; "
                "reduce MC batches with mc_summary first — concatenating "
                "sample-major layouts would interleave their segments")
        tech_names: list = []
        scheme_names: list = []
        for b in batches:
            for n in b.tech_names:
                if n not in tech_names:
                    tech_names.append(n)
            for n in b.scheme_names:
                if n not in scheme_names:
                    scheme_names.append(n)
        parts = []
        for b in batches:
            tmap = np.asarray([tech_names.index(n) for n in b.tech_names]
                              or [0], np.int32)
            smap = np.asarray([scheme_names.index(n) for n in b.scheme_names]
                              or [0], np.int32)
            parts.append(replace(
                b,
                tech_idx=jnp.asarray(tmap)[b.tech_idx],
                scheme_idx=jnp.asarray(smap)[b.scheme_idx]))
        # field-wise concatenation (NOT tree_map: the inputs' static aux
        # data — name tables — legitimately differ before the union)
        cat = lambda xs: jnp.concatenate([jnp.asarray(x) for x in xs])
        kwargs = {f: cat([getattr(p, f) for p in parts])
                  for f in ARRAY_FIELDS}
        corners = {k: cat([p.corners[k] for p in parts])
                   for k in batches[0].corners}
        return cls(corners=corners, tech_names=tuple(tech_names),
                   scheme_names=tuple(scheme_names),
                   n_samples=1, base_len=0, **kwargs)

    def pad_to(self, multiple: int) -> "DesignBatch":
        """Pad the batch axis up to a multiple (sharding/chunk alignment).

        Padding rows have `valid=False` and zeros elsewhere; every DSE
        reduction and `to_points()` ignores them.
        """
        b = len(self)
        pad = (-b) % multiple
        if not pad:
            return self
        def padarr(a):
            a = jnp.asarray(a)
            return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
        return jax.tree_util.tree_map(padarr, self)

    def device_put(self, sharding) -> "DesignBatch":
        """Place every leaf with the given jax.sharding / device."""
        return jax.device_put(self, sharding)

    # -------------------------------------------------- Monte-Carlo views --
    # Sample-major layout contract (dse.sweep on a with_mc space): sample s
    # of base design i is flat row `s * base_len + i`; pad_to may append
    # invalid rows at the end.  Every reduction below is a masked segment
    # reduction over the flat batch axis — `select()`ed batches lose the
    # layout and are rejected.
    #
    # Importance sampling: a space lowered with a shifted/scaled tail
    # proposal (`with_mc(..., tail_shift=, tail_scale=)`) carries per-row
    # log-weights in `corners["mc_log_w"]`; every reduction consumes them
    # automatically (self-normalized estimators).  Without the channel the
    # weights are uniform and each reduction takes the ORIGINAL unweighted
    # code path — bit-identical to the plain i.i.d. estimators.

    def _mc_base(self) -> int:
        if self.n_samples == 0:
            raise ValueError(
                "MC reductions need the sweep's sample-major layout, which "
                "select() destroys — reduce first (mc_summary) and select "
                "the per-design summary batch instead")
        base = self.base_len or len(self)
        if len(self) < self.n_samples * base:
            raise ValueError(
                "MC reductions need the sweep's sample-major layout "
                f"({self.n_samples} samples x {base} designs), but the "
                f"batch has only {len(self)} rows — was it select()ed?")
        return base

    def _mc_weights(self) -> jnp.ndarray | None:
        """Per-row importance weights from the reserved `mc_log_w`
        channel, max-stabilized and zeroed on invalid rows — or None when
        the batch carries no weights (uniform; reductions then take the
        original unweighted code path bit-for-bit)."""
        log_w = self.corners.get("mc_log_w")
        if log_w is None:
            return None
        log_w = jnp.where(self.valid, jnp.asarray(log_w, jnp.float32),
                          -jnp.inf)
        peak = jnp.max(log_w)
        peak = jnp.where(jnp.isfinite(peak), peak, 0.0)
        return jnp.exp(log_w - peak)        # exp(-inf) == 0 on invalid rows

    def _segment_frac(self, ok: jnp.ndarray, base: int,
                      weights: jnp.ndarray | None = None) -> jnp.ndarray:
        ids = jnp.arange(len(self)) % base
        # A design with ZERO valid samples (or zero total weight) has no
        # yield estimate at all: NaN, not 0.0, so never-evaluated designs
        # cannot masquerade as true yield-0 designs (pareto_mask's NaN
        # columns neither dominate nor get dominated, so they pass
        # through selection unharmed).
        if weights is None:
            hits = jax.ops.segment_sum((ok & self.valid).astype(jnp.float32),
                                       ids, num_segments=base)
            tot = jax.ops.segment_sum(self.valid.astype(jnp.float32),
                                      ids, num_segments=base)
            return jnp.where(tot > 0.0, hits / jnp.maximum(tot, 1.0),
                             jnp.nan)
        hits = jax.ops.segment_sum(weights * (ok & self.valid), ids,
                                   num_segments=base)
        tot = jax.ops.segment_sum(weights, ids, num_segments=base)
        return jnp.where(tot > 0.0,
                         hits / jnp.where(tot > 0.0, tot, 1.0), jnp.nan)

    def _spec_ok(self, margin_mv: float | None, trc_ns: float | None,
                 disturbed: bool) -> jnp.ndarray:
        """Per-row spec pass mask (folded with validity)."""
        ok = self.valid
        if margin_mv is not None:
            col = self.margin_disturbed_mv if disturbed else self.margin_mv
            ok = ok & (col >= margin_mv)
        if trc_ns is not None:
            ok = ok & (self.trc_ns <= trc_ns)
        return ok

    def yield_fraction(self, margin_mv: float | None = None,
                       trc_ns: float | None = None,
                       disturbed: bool = False) -> jnp.ndarray:
        """Per-design fraction of MC samples meeting the spec -> (base,).

        A sample passes when its sense margin is at least `margin_mv`
        (the disturbed margin when `disturbed=True`) AND its row-cycle
        time is at most `trc_ns`; criteria passed as None are skipped.
        NaN tRC (a `with_transient=False` sweep) never passes a tRC spec.
        On a nominal sweep (no `with_mc`) this is a 0/1 pass map.  A
        design whose samples are ALL invalid has no estimate and yields
        NaN (distinct from true yield 0).  On an importance-sampled batch
        this is the self-normalized weighted estimate.
        """
        base = self._mc_base()
        return self._segment_frac(self._spec_ok(margin_mv, trc_ns,
                                                disturbed),
                                  base, self._mc_weights())

    def quantile(self, q, field: str = "trc_ns") -> jnp.ndarray:
        """Per-design quantile of a metric across MC samples -> (base,)
        (or (len(q), base) for a vector `q`).  Invalid rows are ignored.
        On an importance-sampled batch the quantile is read off the
        weighted empirical CDF (invalid/NaN rows carry zero weight)."""
        base = self._mc_base()
        n = self.n_samples * base
        vals = jnp.asarray(getattr(self, field), jnp.float32)[:n]
        weights = self._mc_weights()
        if weights is None:
            vals = jnp.where(self.valid[:n], vals, jnp.nan)
            return jnp.nanquantile(vals.reshape(self.n_samples, base),
                                   jnp.asarray(q), axis=0)
        vals = vals.reshape(self.n_samples, base)
        w = weights[:n].reshape(self.n_samples, base)
        # a row is a CDF knot only when valid AND finite: invalid rows
        # carry stale values (their weight is already zero, but leaving
        # the value in the sort would anchor low-q interpolation to it)
        usable = jnp.isfinite(vals) & self.valid[:n].reshape(
            self.n_samples, base)
        w = jnp.where(usable, w, 0.0)
        sortkey = jnp.where(usable, vals, jnp.inf)
        order = jnp.argsort(sortkey, axis=0)
        v = jnp.take_along_axis(sortkey, order, axis=0)
        ww = jnp.take_along_axis(w, order, axis=0)
        tot = ww.sum(axis=0)
        # clamp the +inf sentinel rows to the column's largest usable
        # value so interpolation beyond the last weighted point saturates
        vmax = jnp.max(jnp.where(usable & (w > 0.0), vals, -jnp.inf),
                       axis=0)
        v = jnp.where(jnp.isfinite(v), v, vmax[None, :])
        midpts = (jnp.cumsum(ww, axis=0) - 0.5 * ww)
        cdf = midpts / jnp.maximum(tot, 1e-30)[None, :]
        q_arr = jnp.asarray(q, jnp.float32)
        qs = jnp.atleast_1d(q_arr)
        out = jax.vmap(lambda p, vv: jnp.interp(qs, p, vv),
                       in_axes=(1, 1), out_axes=1)(cdf, v)
        out = jnp.where(tot[None, :] > 0.0, out, jnp.nan)
        return out[0] if q_arr.ndim == 0 else out

    def ess(self) -> jnp.ndarray:
        """Per-design effective sample size (Kish) -> (base,).

        `(sum w)^2 / sum w^2` over each design's valid samples — the
        diagnostic for how much an importance-sampled estimate can be
        trusted.  Uniform weights reduce it to the valid-sample count."""
        base = self._mc_base()
        w = self._mc_weights()
        if w is None:
            w = self.valid.astype(jnp.float32)
        ids = jnp.arange(len(self)) % base
        s1 = jax.ops.segment_sum(w, ids, num_segments=base)
        s2 = jax.ops.segment_sum(w * w, ids, num_segments=base)
        return jnp.where(s2 > 0.0,
                         s1 * s1 / jnp.where(s2 > 0.0, s2, 1.0), 0.0)

    def yield_ppm(self, margin_mv: float | None = None,
                  trc_ns: float | None = None, disturbed: bool = False,
                  z_conf: float = 1.959964, min_ess: float = 8.0) -> dict:
        """Deep-tail spec-FAILURE estimate per design, in parts per
        million -> dict of (base,) arrays.

        Unlike the self-normalized bulk reductions, this is the
        *unnormalized* importance-sampling estimator — the standardized
        draws have a known (unit) normalizing constant, so
        `p = (1/N) sum_i w_i [fail_i]` with the exact density-ratio
        weights.  Weights only ever multiply failure indicators, which is
        what makes ppm tails tractable: under a proposal shifted into the
        failure region the weights ON that region are uniformly small and
        well-behaved, where a self-normalized estimate would be drowned
        by the bulk samples' huge weights.

            fail_ppm            point estimate, failures per million
            fail_ppm_lo/hi      `z_conf`-sigma normal-approximation CI
                                bounds (clipped to [0, 1e6])
            ess                 per-design *tail* effective sample size:
                                `(sum w f)^2 / sum (w f)^2`, the
                                effective number of independent failure
                                observations behind the estimate

        A design whose tail ESS is below `min_ess` — too few (effective)
        observed failures, including the zero-observed-failure case — or
        with zero valid samples reports NaN: no estimate, mirroring
        `yield_fraction`'s zero-valid-sample NaN semantics, never a fake
        0 ppm.
        """
        base = self._mc_base()
        ok = self._spec_ok(margin_mv, trc_ns, disturbed)
        fail = (self.valid & ~ok).astype(jnp.float32)
        log_w = self.corners.get("mc_log_w")
        if log_w is None:
            wf = fail
        else:
            w = jnp.exp(jnp.asarray(log_w, jnp.float32))
            wf = jnp.where(self.valid, w, 0.0) * fail
        ids = jnp.arange(len(self)) % base
        n = jax.ops.segment_sum(self.valid.astype(jnp.float32), ids,
                                num_segments=base)
        n_safe = jnp.maximum(n, 1.0)
        s1 = jax.ops.segment_sum(wf, ids, num_segments=base)
        s2 = jax.ops.segment_sum(wf * wf, ids, num_segments=base)
        p_fail = s1 / n_safe
        # unnormalized-IS variance:  Var(w f) / N
        var = jnp.maximum(s2 / n_safe - p_fail * p_fail, 0.0) / n_safe
        sd = jnp.sqrt(var)
        ess = jnp.where(s2 > 0.0,
                        s1 * s1 / jnp.where(s2 > 0.0, s2, 1.0), 0.0)
        good = (n > 0.0) & (ess >= min_ess)
        to_ppm = lambda p: jnp.clip(p, 0.0, 1.0) * 1e6
        nan = jnp.nan
        return {
            "fail_ppm": jnp.where(good, to_ppm(p_fail), nan),
            "fail_ppm_lo": jnp.where(good, to_ppm(p_fail - z_conf * sd),
                                     nan),
            "fail_ppm_hi": jnp.where(good, to_ppm(p_fail + z_conf * sd),
                                     nan),
            "ess": ess,
        }

    def mc_summary(self, margin_mv: float | None = None,
                   trc_ns: float | None = None, disturbed: bool = False,
                   q: float = 0.5,
                   min_feasible_frac: float = 0.5) -> "DesignBatch":
        """Reduce an MC batch to one row per base design.

        Sampled metrics (`margin_mv`, `margin_disturbed_mv`, `trc_ns`,
        `t_sense_ns`) collapse to their per-design `q`-quantile;
        deterministic columns take the first sample's value.  `feasible`
        becomes "at least `min_feasible_frac` of samples feasible", and
        `corners["yield_frac"]` records `yield_fraction(margin_mv,
        trc_ns, disturbed)` — ready to use as a Pareto/selection
        objective (`dse.pareto_front(..., extra_maximize=...)`,
        `dse.best_design(..., min_yield=...)`).

        On an importance-sampled batch every reduced column (yield,
        quantiles, feasible fraction) is the weighted estimate, and
        `corners["ess"]` carries the per-design effective sample size
        diagnostic.  The raw `mc_*` draw/weight channels never survive
        the reduction.
        """
        base = self._mc_base()
        yf = self.yield_fraction(margin_mv=margin_mv, trc_ns=trc_ns,
                                 disturbed=disturbed)
        take = lambda a: jnp.asarray(a)[:base]
        kwargs = {f: take(getattr(self, f)) for f in ARRAY_FIELDS}
        for f in MC_SAMPLED_FIELDS:
            kwargs[f] = self.quantile(q, f).astype(jnp.float32)
        feas_frac = self._segment_frac(self.feasible, base,
                                       self._mc_weights())
        kwargs["feasible"] = ((feas_frac >= min_feasible_frac)
                              & kwargs["valid"])
        corners = {k: take(v) for k, v in self.corners.items()
                   if not k.startswith("mc_")}
        corners["yield_frac"] = yf.astype(jnp.float32)
        corners["ess"] = self.ess().astype(jnp.float32)
        return DesignBatch(corners=corners, tech_names=self.tech_names,
                           scheme_names=self.scheme_names, **kwargs)

    # ------------------------------------------------------ legacy views --
    def point(self, i: int) -> DesignPoint:
        """Scalar `DesignPoint` view of row `i`."""
        col = lambda f: np.asarray(getattr(self, f))[i]
        return DesignPoint(
            tech=self.tech_names[int(col("tech_idx"))],
            scheme=self.scheme_names[int(col("scheme_idx"))],
            layers=int(col("layers")),
            density_gb_mm2=float(col("density_gb_mm2")),
            height_um=float(col("height_um")),
            cbl_ff=float(col("cbl_ff")),
            margin_mv=float(col("margin_mv")),
            margin_disturbed_mv=float(col("margin_disturbed_mv")),
            trc_ns=float(col("trc_ns")),
            e_write_fj=float(col("e_write_fj")),
            e_read_fj=float(col("e_read_fj")),
            hcb_pitch_um=float(col("hcb_pitch_um")),
            blsa_area_um2=float(col("blsa_area_um2")),
            feasible=bool(col("feasible")))

    def to_points(self) -> list:
        """Deprecated compatibility view: the old `list[DesignPoint]`
        contract of `full_sweep`.  Skips invalid (padding) rows.  New code
        should consume the array fields directly.  Removal timeline:
        docs/api.md."""
        warnings.warn(
            "DesignBatch.to_points is deprecated and will be removed (see "
            "docs/api.md for the timeline); consume the DesignBatch array "
            "columns directly (tech_col/scheme_col for names, point(i) "
            "for a single row)",
            DeprecationWarning, stacklevel=2)
        valid = np.asarray(self.valid)
        return [self.point(i) for i in np.flatnonzero(valid)]  # repro-lint: disable=RL002  (deprecated per-point export shim; sweep path is array-native)

    @classmethod
    def from_points(cls, points) -> "DesignBatch":
        """Build a batch from legacy `DesignPoint`s (or anything with the
        same attributes); the bridge for list-based callers.

        `DesignPoint` does not record manufacturability (only the combined
        `feasible` verdict), so the bridged `manufacturable` column is a
        placeholder (all True) — consume it only on batches produced by
        `dse.sweep`.  `t_sense_ns` is likewise NaN here."""
        points = list(points)
        tech_names: list = []
        scheme_names: list = []
        for p in points:
            if p.tech not in tech_names:
                tech_names.append(p.tech)
            if p.scheme not in scheme_names:
                scheme_names.append(p.scheme)
        f32 = lambda f: jnp.asarray([getattr(p, f) for p in points],
                                    jnp.float32)
        b = len(points)
        return cls(
            tech_idx=jnp.asarray([tech_names.index(p.tech) for p in points],
                                 jnp.int32),
            scheme_idx=jnp.asarray(
                [scheme_names.index(p.scheme) for p in points], jnp.int32),
            layers=f32("layers"),
            density_gb_mm2=f32("density_gb_mm2"), height_um=f32("height_um"),
            cbl_ff=f32("cbl_ff"), margin_mv=f32("margin_mv"),
            margin_disturbed_mv=f32("margin_disturbed_mv"),
            trc_ns=f32("trc_ns"),
            t_sense_ns=jnp.full((b,), jnp.nan, jnp.float32),
            t_fire_ns=jnp.full((b,), jnp.nan, jnp.float32),
            margin_fire_mv=jnp.full((b,), jnp.nan, jnp.float32),
            e_write_fj=f32("e_write_fj"), e_read_fj=f32("e_read_fj"),
            hcb_pitch_um=f32("hcb_pitch_um"),
            blsa_area_um2=f32("blsa_area_um2"),
            manufacturable=jnp.ones((b,), bool),   # not in DesignPoint
            feasible=jnp.asarray([bool(p.feasible) for p in points], bool),
            valid=jnp.ones((b,), bool),
            corners={},
            tech_names=tuple(tech_names), scheme_names=tuple(scheme_names))
