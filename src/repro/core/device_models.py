"""Compact access-transistor / selector models (TCAD-calibrated surrogates).

The paper extracts device characteristics from TCAD: Si and AOS (IWO,
W-doped In2O3 double-gate [9]) cell access transistors, and the IGO BEOL
selector [11] (Ion > 50 uA @ 2 V, W/L = 70/50 nm, ~60 mV/dec SS).

We model each device with a smooth EKV-style compact model that reproduces
the quoted anchor points (Ion at the quoted bias, subthreshold slope, Ioff).
These curves feed (a) effective on-resistance extraction for the transient
engine and (b) retention analysis (off-state leakage of the storage node).

All functions are pure jnp and vmap-safe over bias sweeps and over device
parameter batches (used by the DSE).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from .units import MA_TO_UA

KT_Q_MV = 26.0  # thermal voltage at 300 K, mV


@dataclass(frozen=True)
class DeviceParams:
    name: str
    vth: float            # threshold voltage (V)
    ss_mv_dec: float      # subthreshold slope (mV/dec)
    i_spec_ua: float      # specific current scaling (uA), sets Ion
    v_early: float        # output-conductance Early voltage (V)
    ioff_a: float         # off-state leakage at Vgs=0, Vds=VDD/2 (A)
    w_nm: float
    l_nm: float


# --- calibration anchors -------------------------------------------------
# IGO selector [11]: Ion > 50 uA @ Vgs=2 V (W/L = 70/50), SS ~ 60 mV/dec.
IGO_SELECTOR = DeviceParams(
    name="igo_selector", vth=0.55, ss_mv_dec=60.0, i_spec_ua=2.10,
    v_early=12.0, ioff_a=1e-15, w_nm=70.0, l_nm=50.0,
)
# Si access transistor (GAA, line-type iso, channel width 70 nm): decent
# drive, but a floating body and ~85 mV/dec (junction-limited).
SI_ACCESS = DeviceParams(
    name="si_access", vth=0.75, ss_mv_dec=85.0, i_spec_ua=1.30,
    v_early=10.0, ioff_a=3e-16, w_nm=70.0, l_nm=60.0,
)
# AOS (IWO [9]) access transistor: ultra-low leakage oxide channel, lower
# mobility -> lower drive, near-ideal SS, no floating body.
AOS_ACCESS = DeviceParams(
    name="aos_access", vth=0.60, ss_mv_dec=65.0, i_spec_ua=0.80,
    v_early=15.0, ioff_a=1e-19, w_nm=70.0, l_nm=60.0,
)

DEVICES = {d.name: d for d in (IGO_SELECTOR, SI_ACCESS, AOS_ACCESS)}


def ids_ua(dev: DeviceParams, vgs, vds):
    """Drain current (uA), smooth EKV-like interpolation.

    I = I0 * ln^2(1 + exp((Vgs-Vth)/(2nUt))) * sat(Vds) * (1 + Vds/VA)
    which gives exp subthreshold with slope SS and ~square-law/velocity-sat
    above threshold; anchored so Ion matches the quoted TCAD point.
    """
    vgs = jnp.asarray(vgs, jnp.float32)
    vds = jnp.asarray(vds, jnp.float32)
    n = dev.ss_mv_dec / (KT_Q_MV * jnp.log(10.0))
    ut = KT_Q_MV * 1e-3
    x = (vgs - dev.vth) / (2.0 * n * ut)
    # softplus without overflow
    sp = jnp.where(x > 30.0, x, jnp.log1p(jnp.exp(jnp.minimum(x, 30.0))))
    drive = sp * sp
    vdsat = jnp.maximum(2.0 * n * ut * sp, 1e-6)
    sat = jnp.tanh(vds / vdsat)
    i = dev.i_spec_ua * (dev.w_nm / dev.l_nm) * drive * sat * (1.0 + vds / dev.v_early)
    return i + dev.ioff_a * 1e6  # leakage floor in uA


def r_on_eff_kohm(dev: DeviceParams, vgs: float, vswing: float):
    """Effective large-signal on-resistance for (dis)charging through the
    device across a `vswing` excursion: R_eff = vswing / I(vgs, vswing/2)."""
    i_ua = ids_ua(dev, vgs, vswing / 2.0)
    return vswing / i_ua * MA_TO_UA  # V/uA -> kOhm


def subthreshold_swing_mv_dec(dev: DeviceParams, vds: float = 0.05):
    """Numerically extracted SS around Vgs = Vth - 0.15 V (sanity check vs
    the calibration target)."""
    v0, v1 = dev.vth - 0.20, dev.vth - 0.10
    i0 = ids_ua(dev, v0, vds)
    i1 = ids_ua(dev, v1, vds)
    return (v1 - v0) * 1e3 / (jnp.log10(i1) - jnp.log10(i0))


def retention_time_ms(dev: DeviceParams, cs_ff: float, dv_allow_v: float = 0.2):
    """Storage-node retention limited by off-state leakage:
    t_ret = Cs * dV_allow / Ioff.  Returns milliseconds."""
    return cs_ff * 1e-15 * dv_allow_v / dev.ioff_a * 1e3
