"""Sense-margin model (full SWD + BLSA compact model, Fig. 3).

  dV_nominal = (VDD/2) * Cs/(Cs + C_BL)            charge sharing
             - (1 - writeback_eff) * (VDD/2)       incomplete restore level
             - V_offset_SA                         input-referred SA offset

  dV_disturbed = dV_nominal - disturb_loss(FBE+RH) (Fig. 9b)

All terms in mV.  Batched over `layers` design points.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal
from .disturb import disturb_loss_mv
from .netlist import effective_cbl_ff


def charge_share_mv(tech: TechCal, scheme: str, layers) -> jnp.ndarray:
    cbl = effective_cbl_ff(tech, scheme, layers)
    return 1e3 * (cal.VDD_ARRAY / 2.0) * cal.CS_FF / (cal.CS_FF + cbl)


def sense_margin_mv(tech: TechCal, scheme: str, layers,
                    with_disturb: bool = False) -> jnp.ndarray:
    dv = charge_share_mv(tech, scheme, layers)
    dv = dv - (1.0 - tech.writeback_eff) * (cal.VDD_ARRAY / 2.0) * 1e3
    dv = dv - tech.sa_offset_mv
    if with_disturb:
        dv = dv - disturb_loss_mv(tech, scheme, layers)
    return dv


def sense_margin_lowered(view, with_disturb: bool = False,
                         cbl_ff: jnp.ndarray | None = None) -> jnp.ndarray:
    """Array-native sense margin over a lowered design space (core.space).

    Pass `cbl_ff` to reuse an already-assembled parasitic decomposition
    (the DSE sweep computes it once for every metric).
    """
    from .disturb import disturb_loss_lowered
    from .netlist import effective_cbl_lowered
    if cbl_ff is None:
        cbl_ff = effective_cbl_lowered(view)
    dv = 1e3 * (cal.VDD_ARRAY / 2.0) * cal.CS_FF / (cal.CS_FF + cbl_ff)
    dv = dv - (1.0 - view.tech("writeback_eff")) * (cal.VDD_ARRAY / 2.0) * 1e3
    # Monte-Carlo spaces carry per-sample SA offsets (with_mc lowering);
    # nominal spaces fall back to the calibrated per-tech corner value.
    sa_offset = view.corner("mc_sa_offset_mv", None)
    if sa_offset is None:
        sa_offset = view.tech("sa_offset_mv")
    dv = dv - sa_offset
    if with_disturb:
        dv = dv - disturb_loss_lowered(view)
    return dv.astype(jnp.float32)


def functional(tech: TechCal, scheme: str, layers,
               with_disturb: bool = True) -> jnp.ndarray:
    """Feasibility: margin above the functional sensing threshold
    (80 mV nominal; 60 mV with FBE+RH disturb, per the paper's 70 mV
    functional Si point)."""
    thresh = (cal.MIN_DISTURBED_MARGIN_MV if with_disturb
              else cal.MIN_FUNCTIONAL_MARGIN_MV)
    return sense_margin_mv(tech, scheme, layers, with_disturb) >= thresh
