"""Array parasitic assembly (the paper's TCAD extraction layer).

Produces the effective bitline capacitance / resistance decomposition per
(technology, routing scheme, layer count).  The *structure* of the
decomposition encodes the paper's central claim: with the BL selector, only
the selected strap's local BL hangs on the global line; without it, every
strap on the global line contributes its local capacitance.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp

from . import routing
from .calibration import TechCal


@dataclass(frozen=True)
class BLParasitics:
    """Effective single-ended BL network as seen by the BLSA."""
    c_local_ff: jnp.ndarray      # selected local (vertical) BL
    c_unselected_ff: jnp.ndarray # unselected local BLs coupled onto the global line
    c_global_ff: jnp.ndarray     # global strap metal + HCB pad (+ 2D lateral route)
    c_sa_ff: jnp.ndarray         # BLSA input
    r_path_kohm: jnp.ndarray     # series resistance BLSA -> cell (excl. access tr.)
    r_on_kohm: jnp.ndarray       # access transistor effective on-resistance

    @property
    def c_bl_total_ff(self) -> jnp.ndarray:
        """Effective C_BL (everything the sense node must charge except Cs)."""
        return self.c_local_ff + self.c_unselected_ff + self.c_global_ff + self.c_sa_ff


def local_bl_cap_ff(tech: TechCal, layers) -> jnp.ndarray:
    """Vertical local BL: per-tier sidewall/fringe capacitance x tier count,
    plus the selector junction it terminates in."""
    layers = jnp.asarray(layers, jnp.float32)
    return layers * tech.c_bl_per_layer_ff + tech.c_sel_junction_ff


def _assemble(layers, *, baseline_2d, fixed_c_bl_ff, c_bl_per_layer_ff,
              c_sel_junction_ff, c_global_strap_ff, c_hcb_pad_ff,
              c_blsa_in_ff, r_on_cell_kohm, r_sel_kohm, r_local_bl_kohm,
              r_global_kohm, sel_junction, straps_per_global,
              global_strap_metal, c_global_fixed_ff, r_sel_in_path,
              r_global_in_path) -> BLParasitics:
    """Coefficient-driven BL-network assembly (Fig. 2).

    Every argument may be a scalar (one tech/scheme, batched over layers)
    or a per-design-point array (the lowered DSE path) — the arithmetic is
    identical, so the scalar API and the vectorized sweep cannot drift.

    The *structure* a SchemeSpec encodes: with a BL selector, only the
    selected strap's local BL hangs on the global line; without isolation,
    every strap on the line (`straps_per_global`) contributes its local
    capacitance.  A 2D baseline bypasses the stacked decomposition and uses
    its tabulated lateral C_BL; its lateral IO routing (c_route_extra) sits
    *behind* the column select and is charged to the energy model, not to
    the sensing ladder.
    """
    layers = jnp.asarray(layers, jnp.float32)
    zero = jnp.zeros_like(layers)
    c_vert = layers * c_bl_per_layer_ff

    c_local_3d = c_vert + jnp.where(sel_junction, c_sel_junction_ff, 0.0)
    c_unsel_3d = (straps_per_global - 1) * c_vert
    c_glob_3d = (jnp.where(global_strap_metal, c_global_strap_ff, 0.0)
                 + c_global_fixed_ff + c_hcb_pad_ff)
    r_path_3d = (r_local_bl_kohm
                 + jnp.where(r_sel_in_path, r_sel_kohm, 0.0)
                 + jnp.where(r_global_in_path, r_global_kohm, 0.0))

    return BLParasitics(
        c_local_ff=jnp.where(baseline_2d, fixed_c_bl_ff - c_blsa_in_ff,
                             c_local_3d) + zero,
        c_unselected_ff=jnp.where(baseline_2d, 0.0, c_unsel_3d) + zero,
        c_global_ff=jnp.where(baseline_2d, 0.0, c_glob_3d) + zero,
        c_sa_ff=zero + c_blsa_in_ff,
        r_path_kohm=jnp.where(baseline_2d, r_local_bl_kohm,
                              r_path_3d) + zero,
        r_on_kohm=zero + r_on_cell_kohm,
    )


def bl_parasitics(tech: TechCal, scheme: str, layers) -> BLParasitics:
    """Assemble the BL network for one (tech, scheme), batched over layers.

    The scheme's structure comes from its registered `SchemeSpec`
    (`routing.register_scheme`) — no per-name branches here.
    """
    spec = routing.scheme_spec(scheme)
    return _assemble(
        layers,
        baseline_2d=tech.baseline_2d, fixed_c_bl_ff=tech.fixed_c_bl_ff,
        c_bl_per_layer_ff=tech.c_bl_per_layer_ff,
        c_sel_junction_ff=tech.c_sel_junction_ff,
        c_global_strap_ff=tech.c_global_strap_ff,
        c_hcb_pad_ff=tech.c_hcb_pad_ff, c_blsa_in_ff=tech.c_blsa_in_ff,
        r_on_cell_kohm=tech.r_on_cell_kohm, r_sel_kohm=tech.r_sel_kohm,
        r_local_bl_kohm=tech.r_local_bl_kohm,
        r_global_kohm=tech.r_global_kohm,
        sel_junction=spec.sel_junction,
        straps_per_global=spec.straps_per_global,
        global_strap_metal=spec.global_strap_metal,
        c_global_fixed_ff=spec.c_global_fixed_ff,
        r_sel_in_path=spec.r_sel_in_path,
        r_global_in_path=spec.r_global_in_path,
    )


def bl_parasitics_lowered(view) -> BLParasitics:
    """Array-native BL networks over a lowered design space.

    `view` follows the LoweredSpace protocol (`core.space`): per-point
    `.layers` plus `.tech(field)` / `.scheme(field)` gathers.  One call
    covers every (tech, scheme, layers) point of the flat batch.

    Monte-Carlo spaces (`DesignSpace.with_mc`) carry per-sample Vth
    perturbations; those fold into the access-transistor effective
    on-resistance here — r_on scales inversely with the gate overdrive,
    so a +dVth sample conducts less and slows the fused row cycle.
    """
    par = _assemble(
        view.layers,
        baseline_2d=view.tech("baseline_2d"),
        fixed_c_bl_ff=view.tech("fixed_c_bl_ff"),
        c_bl_per_layer_ff=view.tech("c_bl_per_layer_ff"),
        c_sel_junction_ff=view.tech("c_sel_junction_ff"),
        c_global_strap_ff=view.tech("c_global_strap_ff"),
        c_hcb_pad_ff=view.tech("c_hcb_pad_ff"),
        c_blsa_in_ff=view.tech("c_blsa_in_ff"),
        r_on_cell_kohm=view.tech("r_on_cell_kohm"),
        r_sel_kohm=view.tech("r_sel_kohm"),
        r_local_bl_kohm=view.tech("r_local_bl_kohm"),
        r_global_kohm=view.tech("r_global_kohm"),
        sel_junction=view.scheme("sel_junction"),
        straps_per_global=view.scheme("straps_per_global"),
        global_strap_metal=view.scheme("global_strap_metal"),
        c_global_fixed_ff=view.scheme("c_global_fixed_ff"),
        r_sel_in_path=view.scheme("r_sel_in_path"),
        r_global_in_path=view.scheme("r_global_in_path"),
    )
    dvth_mv = view.corner("mc_delta_vth_mv", None)
    if dvth_mv is not None:
        # triode-region conductance ~ overdrive: r_on' = r_on * Vov/(Vov-dVth),
        # with dVth clamped inside the overdrive so r_on stays finite/positive
        vov = jnp.asarray(view.tech("vth_overdrive_v"), jnp.float32)
        dvth_v = jnp.clip(jnp.asarray(dvth_mv, jnp.float32) * 1e-3,
                          -0.5 * vov, 0.5 * vov)
        par = replace(par, r_on_kohm=par.r_on_kohm * vov / (vov - dvth_v))
    return par


def wl_parasitics(tech: TechCal):
    """WL loading seen by the sub-wordline driver (R in kOhm, C in fF)."""
    return tech.r_wl_kohm, tech.c_wl_ff
