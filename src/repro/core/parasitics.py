"""Array parasitic assembly (the paper's TCAD extraction layer).

Produces the effective bitline capacitance / resistance decomposition per
(technology, routing scheme, layer count).  The *structure* of the
decomposition encodes the paper's central claim: with the BL selector, only
the selected strap's local BL hangs on the global line; without it, every
strap on the global line contributes its local capacitance.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from . import calibration as cal
from .calibration import TechCal


@dataclass(frozen=True)
class BLParasitics:
    """Effective single-ended BL network as seen by the BLSA."""
    c_local_ff: jnp.ndarray      # selected local (vertical) BL
    c_unselected_ff: jnp.ndarray # unselected local BLs coupled onto the global line
    c_global_ff: jnp.ndarray     # global strap metal + HCB pad (+ 2D lateral route)
    c_sa_ff: jnp.ndarray         # BLSA input
    r_path_kohm: jnp.ndarray     # series resistance BLSA -> cell (excl. access tr.)
    r_on_kohm: jnp.ndarray       # access transistor effective on-resistance

    @property
    def c_bl_total_ff(self) -> jnp.ndarray:
        """Effective C_BL (everything the sense node must charge except Cs)."""
        return self.c_local_ff + self.c_unselected_ff + self.c_global_ff + self.c_sa_ff


def local_bl_cap_ff(tech: TechCal, layers) -> jnp.ndarray:
    """Vertical local BL: per-tier sidewall/fringe capacitance x tier count,
    plus the selector junction it terminates in."""
    layers = jnp.asarray(layers, jnp.float32)
    return layers * tech.c_bl_per_layer_ff + tech.c_sel_junction_ff


def bl_parasitics(tech: TechCal, scheme: str, layers) -> BLParasitics:
    """Assemble the BL network for one of the four routing schemes (Fig. 2).

    Schemes:
      direct    : every vertical BL is bonded straight to its own BLSA.
                  No selector junction, no global strap metal.
      strap     : BLs strapped onto a global line; *all* straps on the line
                  stay electrically connected (no isolation).
      core_mux  : mux at the array core; local BL + short metal to the mux,
                  mux junction; still one bond per mux output at tight pitch.
      sel_strap : the paper's proposal; selector isolates unselected straps,
                  so the global line sees only junctions + one local BL.
    """
    layers = jnp.asarray(layers, jnp.float32)
    zero = jnp.zeros_like(layers)
    c_vert = layers * tech.c_bl_per_layer_ff

    if tech.name == "d1b":
        # Planar baseline: fixed long lateral BL, no stacking.  The lateral
        # IO routing (c_route_extra) sits *behind* the column select and is
        # swung only on data transfer -> it is charged to the energy model,
        # not to the sensing ladder.
        c_local = jnp.full_like(layers, cal.D1B_C_BL_FF - tech.c_blsa_in_ff)
        return BLParasitics(
            c_local_ff=c_local,
            c_unselected_ff=zero,
            c_global_ff=zero,
            c_sa_ff=zero + tech.c_blsa_in_ff,
            r_path_kohm=zero + tech.r_local_bl_kohm,
            r_on_kohm=zero + tech.r_on_cell_kohm,
        )

    if scheme == "direct":
        c_local = c_vert
        c_unsel = zero
        c_glob = zero + tech.c_hcb_pad_ff
        r_path = zero + tech.r_local_bl_kohm
    elif scheme == "strap":
        # no selector: every strap's local BL + its junctionless tap loads
        # the global line.
        c_local = c_vert
        c_unsel = (cal.STRAPS_PER_GLOBAL - 1) * c_vert
        c_glob = zero + tech.c_global_strap_ff + tech.c_hcb_pad_ff
        r_path = zero + tech.r_local_bl_kohm + tech.r_global_kohm
    elif scheme == "core_mux":
        c_local = c_vert + tech.c_sel_junction_ff
        c_unsel = zero
        c_glob = zero + 0.4 + tech.c_hcb_pad_ff      # short metal to core mux
        r_path = zero + tech.r_local_bl_kohm + tech.r_sel_kohm
    elif scheme == "sel_strap":
        c_local = c_vert + tech.c_sel_junction_ff
        c_unsel = zero                               # isolated by the selector
        c_glob = zero + tech.c_global_strap_ff + tech.c_hcb_pad_ff
        r_path = (zero + tech.r_local_bl_kohm + tech.r_sel_kohm
                  + tech.r_global_kohm)
    else:
        raise ValueError(f"unknown routing scheme: {scheme}")

    return BLParasitics(
        c_local_ff=c_local,
        c_unselected_ff=c_unsel,
        c_global_ff=c_glob,
        c_sa_ff=zero + tech.c_blsa_in_ff,
        r_path_kohm=r_path,
        r_on_kohm=zero + tech.r_on_cell_kohm,
    )


def wl_parasitics(tech: TechCal):
    """WL loading seen by the sub-wordline driver (R in kOhm, C in fF)."""
    return tech.r_wl_kohm, tech.c_wl_ff
