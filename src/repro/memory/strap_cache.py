"""StrapCache: the paper's Selector+Strap as a paged, gated KV cache.

Pages of `page_size` tokens are grouped into straps of `pages_per_strap`
pages.  At decode, a *selector* picks which straps participate:

  exact mode : all straps selected (bit-exact with dense attention; the
               default for correctness-critical serving)
  gated mode : top-k straps by selector score (mean-key dot query), the
               paper-analogue optimization — HBM traffic per token drops by
               the selectivity, like C_BL 20 fF -> 6.6 fF.

The compute path is `repro.kernels.ops.strap_attend` (Pallas on TPU — the
gather happens in the BlockSpec index map, so unselected straps are never
read from HBM).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ops


@dataclass
class StrapCacheConfig:
    page_size: int = 64
    pages_per_strap: int = 4
    top_straps: int = 0        # 0 = exact (all straps)

    @property
    def strap_tokens(self) -> int:
        return self.page_size * self.pages_per_strap


@dataclass
class StrapKVCache:
    """Paged KV storage for ONE layer: (B, P, page, Hkv, hd)."""
    cfg: StrapCacheConfig
    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    length: jnp.ndarray        # (B,) tokens currently stored
    # selector metadata: running mean key per strap (B, S_straps, Hkv, hd)
    strap_key_sum: jnp.ndarray

    @classmethod
    def create(cls, cfg: StrapCacheConfig, batch: int, max_tokens: int,
               n_kv: int, head_dim: int, dtype=jnp.bfloat16):
        p = -(-max_tokens // cfg.page_size)
        p = -(-p // cfg.pages_per_strap) * cfg.pages_per_strap
        straps = p // cfg.pages_per_strap
        z = jnp.zeros((batch, p, cfg.page_size, n_kv, head_dim), dtype)
        return cls(cfg=cfg, k_pages=z, v_pages=jnp.copy(z),
                   length=jnp.zeros((batch,), jnp.int32),
                   strap_key_sum=jnp.zeros((batch, straps, n_kv, head_dim),
                                           jnp.float32))

    @property
    def n_straps(self) -> int:
        return self.k_pages.shape[1] // self.cfg.pages_per_strap

    def bulk_load(self, k: jnp.ndarray, v: jnp.ndarray) -> "StrapKVCache":
        """Load a prefill's (B, S, Hkv, hd) keys/values into pages."""
        b, s, hkv, hd = k.shape
        ps = self.cfg.page_size
        p_needed = -(-s // ps)
        pad = p_needed * ps - s
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = kp.reshape(b, p_needed, ps, hkv, hd).astype(self.k_pages.dtype)
        vp = vp.reshape(b, p_needed, ps, hkv, hd).astype(self.v_pages.dtype)
        k_pages = self.k_pages.at[:, :p_needed].set(kp)
        v_pages = self.v_pages.at[:, :p_needed].set(vp)
        # strap selector metadata
        g = self.cfg.pages_per_strap
        straps_touched = -(-p_needed // g)
        ks = jnp.zeros_like(self.strap_key_sum)
        kt = jnp.pad(kp, ((0, 0), (0, straps_touched * g - p_needed),
                          (0, 0), (0, 0), (0, 0)))
        kt = kt.reshape(b, straps_touched, g * ps, hkv, hd)
        ks = ks.at[:, :straps_touched].set(
            jnp.sum(kt.astype(jnp.float32), axis=2))
        return StrapKVCache(self.cfg, k_pages, v_pages,
                            jnp.full((b,), s, jnp.int32), ks)

    def append(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> "StrapKVCache":
        """Append one token's (B, Hkv, hd) K/V."""
        b = k_new.shape[0]
        ps, g = self.cfg.page_size, self.cfg.pages_per_strap
        idx = self.length                                  # (B,)
        page_i = idx // ps
        slot_i = idx % ps
        bidx = jnp.arange(b)
        k_pages = self.k_pages.at[bidx, page_i, slot_i].set(
            k_new.astype(self.k_pages.dtype))
        v_pages = self.v_pages.at[bidx, page_i, slot_i].set(
            v_new.astype(self.v_pages.dtype))
        strap_i = idx // (ps * g)
        ks = self.strap_key_sum.at[bidx, strap_i].add(
            k_new.astype(jnp.float32))
        return StrapKVCache(self.cfg, k_pages, v_pages, idx + 1, ks)

    # -- the selector -----------------------------------------------------
    def select_straps(self, q: jnp.ndarray) -> jnp.ndarray:
        """Choose strap ids per sequence: exact mode -> all valid straps;
        gated mode -> top-k by sum-key score, always incl. the newest strap.

        q: (B, Hq, hd).  Returns (B, S_sel) int32, -1 padded.
        """
        b = q.shape[0]
        n = self.n_straps
        tokens_per_strap = self.cfg.strap_tokens
        n_valid = (self.length + tokens_per_strap - 1) // tokens_per_strap
        all_ids = jnp.arange(n)[None, :].repeat(b, 0)
        valid = all_ids < n_valid[:, None]
        if not self.cfg.top_straps:
            return jnp.where(valid, all_ids, -1).astype(jnp.int32)

        hq = q.shape[1]
        hkv = self.strap_key_sum.shape[2]
        grp = hq // hkv
        qg = q.reshape(b, hkv, grp, -1).astype(jnp.float32)
        scores = jnp.einsum("bhgd,bshd->bs", qg, self.strap_key_sum)
        newest = jnp.maximum(n_valid - 1, 0)
        scores = scores + 1e9 * jax.nn.one_hot(newest, n)   # keep newest
        scores = jnp.where(valid, scores, -jnp.inf)
        k = min(self.cfg.top_straps, n)
        _, ids = jax.lax.top_k(scores, k)
        keep = jnp.take_along_axis(valid, ids, axis=1)
        return jnp.where(keep, ids, -1).astype(jnp.int32)

    def attend(self, q: jnp.ndarray, backend: str = "auto") -> jnp.ndarray:
        """Gated decode attention: (B, Hq, hd) -> (B, Hq, hd).

        Passes `length` so zero-initialised padding slots inside a
        partially filled strap are masked out of the softmax (their raw
        logit is 0, which would otherwise compete with real tokens).
        """
        ids = self.select_straps(q)
        return ops.strap_attend(q, self.k_pages, self.v_pages, ids,
                                self.cfg.pages_per_strap, backend=backend,
                                lengths=self.length)

    def hbm_bytes_per_token(self) -> tuple[int, int]:
        """(gated, dense) bytes read per decode step — the C_BL analogue."""
        b, p, ps, hkv, hd = self.k_pages.shape
        dtype_bytes = self.k_pages.dtype.itemsize
        dense = 2 * p * ps * hkv * hd * dtype_bytes
        sel = self.cfg.top_straps or self.n_straps
        gated = 2 * sel * self.cfg.strap_tokens * hkv * hd * dtype_bytes
        return gated, dense
