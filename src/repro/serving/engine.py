"""Batched serving engine: continuous prefill + greedy/sampled decode.

Two cache back-ends:
  dense : the model's native stacked cache (M.decode_step), exact.
  strap : StrapCache-gated attention for dense-transformer families — the
          paper-technique path.  In exact mode (top_straps=0) it matches
          dense decode to numerical tolerance (tested); gated mode trades
          bounded attention error for an HBM-traffic reduction reported by
          `stats()`.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..memory.strap_cache import StrapCacheConfig, StrapKVCache
from ..models import registry as M
from ..models.common import apply_norm, embed_tokens, lm_logits
from ..models.mlp import mlp_apply
from ..models.moe import moe_apply
from ..models.attention import _project_qkv
from ..models.common import apply_rope


@dataclass
class ServeStats:
    tokens_decoded: int = 0
    hbm_bytes_gated: int = 0
    hbm_bytes_dense: int = 0

    @property
    def traffic_reduction(self) -> float:
        if not self.hbm_bytes_dense:
            return 1.0
        return self.hbm_bytes_gated / self.hbm_bytes_dense


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_tokens: int = 2048,
                 cache_backend: str = "dense",
                 strap_cfg: StrapCacheConfig | None = None):
        assert cache_backend in ("dense", "strap")  # repro-lint: disable=RL001  (KV-cache backend id, not a routing-scheme name)
        if cache_backend == "strap":  # repro-lint: disable=RL001  (KV-cache backend id, not a routing-scheme name)
            assert cfg.family in ("dense", "vlm"), \
                "strap cache applies to full-attention decoder families"
        self.cfg = cfg
        self.params = params
        self.max_tokens = max_tokens
        self.backend = cache_backend
        self.strap_cfg = strap_cfg or StrapCacheConfig()
        self.stats = ServeStats()
        self._cache = None
        self._pos = None
        self._last_logits = None

    # ------------------------------------------------------------------
    def prefill(self, tokens: jnp.ndarray):
        cfg = self.cfg
        logits, cache = M.prefill(cfg, self.params, {"tokens": tokens})
        b, s = tokens.shape
        self._pos = jnp.full((b,), s, jnp.int32)
        if self.backend == "dense":
            # grow the seq axis to max_tokens
            pad = self.max_tokens - cache["k"].shape[2]
            grow = lambda x: jnp.pad(
                x, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            self._cache = {k: (grow(v) if v.ndim == 5 and k in ("k", "v")
                               else v) for k, v in cache.items()}
        else:
            caches = []
            for layer in range(cfg.n_layers):
                sc = StrapKVCache.create(
                    self.strap_cfg, b, self.max_tokens, cfg.n_kv_heads,
                    cfg.head_dim_, cache["k"].dtype)
                caches.append(sc.bulk_load(cache["k"][layer],
                                           cache["v"][layer]))
            self._cache = caches
        self._last_logits = logits
        return logits

    # ------------------------------------------------------------------
    def _decode_strap(self, token):
        """Per-layer decode using StrapCache gated attention."""
        cfg = self.cfg
        p = self.params
        dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
        h = embed_tokens(p, token, dtype)
        pos = self._pos
        new_caches = []
        layers = p["layers"]
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda x, li=li: x[li], layers)
            a_in = apply_norm(cfg, h, lp, "ln1")
            q, k_new, v_new = _project_qkv(cfg, lp, a_in)
            if cfg.rope_theta > 0:
                q = apply_rope(q, pos[:, None], cfg.rope_theta)
                k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
            sc = self._cache[li].append(k_new[:, 0], v_new[:, 0])
            o = sc.attend(q[:, 0])                       # (B, Hq, hd)
            gated, dense = sc.hbm_bytes_per_token()
            self.stats.hbm_bytes_gated += gated
            self.stats.hbm_bytes_dense += dense
            new_caches.append(sc)
            attn = o.reshape(o.shape[0], 1, -1).astype(dtype) @ lp["wo"]
            h = h + attn
            m_in = apply_norm(cfg, h, lp, "ln2")
            if cfg.n_experts:
                mo, _ = moe_apply(cfg, lp, m_in)
            else:
                mo = mlp_apply(cfg, lp, m_in)
            h = h + mo
        self._cache = new_caches
        h = apply_norm(cfg, h, p, "final")
        return lm_logits(cfg, p, h)[:, 0]

    def step(self, token=None, greedy: bool = True, key=None):
        """Decode one token for the whole batch; returns (B, 1) ids."""
        if token is None:
            logits = self._last_logits
            token = (
                jnp.argmax(logits, axis=-1) if greedy or key is None
                else jax.random.categorical(key, logits)
            )[:, None].astype(jnp.int32)
        if self.backend == "dense":
            logits, self._cache = M.decode_step(
                self.cfg, self.params, self._cache, token, self._pos)
        else:
            logits = self._decode_strap(token)
        self._pos = self._pos + 1
        self._last_logits = logits
        self.stats.tokens_decoded += int(token.shape[0])
        return token, logits

    def generate(self, tokens: jnp.ndarray, n_new: int, greedy=True):
        self.prefill(tokens)
        out = []
        tok = None
        for _ in range(n_new):
            tok, _ = self.step(tok, greedy=greedy)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
