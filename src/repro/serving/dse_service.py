"""Co-design-as-a-service: a warm DSE engine with cross-client
micro-batching and memoization.

The offline flow (`dse.sweep` per caller) re-pays lowering, dispatch and
compile cost per script run.  This module keeps ONE long-lived engine
warm and amortizes it across every caller:

  micro-batching : concurrent clients' sweep/yield queries queue for a
        short window (`window_ms`); the window's cache misses are packed
        into a shared B_ALIGN-aligned operand slab and run as ONE fused
        dispatch (`transient.row_cycle_events` on the concatenated
        `FusedOperands`), then de-multiplexed into per-client
        `DesignBatch` results.  Each client's rows go through exactly the
        `plan_sweep` -> events -> `result_from_events` ->
        `finalize_sweep` pipeline `dse.sweep` itself runs, so the demuxed
        result is bit-identical to a direct call (tested).
  memoization    : results are kept in an LRU memo keyed on the full
        request identity — the (tech, scheme, layers) entry tuple plus
        corner-axis values, MC declaration (entropy, sigmas, proposal)
        and replica/transient flags (`request_key`).  A repeated query is
        answered without touching the engine at all; distinct corners
        can never collide because the key carries the exact corner
        values, not a lossy digest.
  streaming      : `sweep_stream` partitions an arbitrarily large space
        into entry-aligned chunks and yields each chunk's batch as it is
        served — partial results for sweeps too big to want as one
        response, with every chunk riding the same window/memo machinery.
  observability  : `stats()` reports request/window/dispatch counters,
        memo hit rate, slab occupancy and latency aggregates.

Run modes: `start()` launches the background dispatcher thread (true
concurrent micro-batching, used by `launch.serve`); without it, blocking
calls (`sweep`, `query_yield`) flush their own window inline, and
`submit` + `flush` give tests deterministic window control.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass, replace

import jax.numpy as jnp

from ..core import dse, transient
from ..core.batch import DesignBatch
from ..core.space import DesignSpace

VALID_KINDS = ("sweep", "yield")

# mc_summary keyword arguments a yield query's `spec` may carry
YIELD_SPEC_KEYS = ("margin_mv", "trc_ns", "disturbed", "q",
                   "min_feasible_frac")


def request_key(space: DesignSpace, with_transient: bool = True) -> tuple:
    """Memo key of one query: the full request identity, exactly.

    `DesignSpace` is a frozen dataclass of tuples — entries
    ((tech, scheme, layers), ...), corner axes with their *values*, the
    MC declaration (sample count, key entropy, sigmas, corr, tail
    proposal) and the replica flag — so the space itself is the
    collision-free "corner hash": two spaces differing in any corner
    value, MC key or flag produce different keys by construction.
    """
    return (space, bool(with_transient))


@dataclass(frozen=True)
class Query:
    """One client request: score `space`, optionally reduce to yield."""
    space: DesignSpace
    kind: str = "sweep"
    with_transient: bool = True
    spec: tuple = ()        # sorted (name, value) mc_summary kwargs

    @classmethod
    def make(cls, space: DesignSpace, kind: str = "sweep",
             with_transient: bool = True, spec: dict | None = None) -> "Query":
        if not isinstance(space, DesignSpace):
            raise TypeError(f"query needs a DesignSpace, got {type(space)!r}")
        if kind not in VALID_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {VALID_KINDS}")
        spec = dict(spec or {})
        bad = sorted(k for k in spec if k not in YIELD_SPEC_KEYS)
        if bad:
            raise ValueError(f"unknown spec key(s) {bad}; yield specs "
                             f"take {YIELD_SPEC_KEYS}")
        if kind == "yield":
            if space.mc is None:
                raise ValueError(
                    "a yield query needs a Monte-Carlo space — declare "
                    "sampling with space.with_mc(samples, key)")
        elif spec:
            raise ValueError("spec= only applies to yield queries")
        return cls(space=space, kind=kind,
                   with_transient=bool(with_transient),
                   spec=tuple(sorted(spec.items())))

    @property
    def key(self) -> tuple:
        return request_key(self.space, self.with_transient)


@dataclass(frozen=True)
class Response:
    """One served query: the full scored batch, plus the yield-kind
    `mc_summary` reduction when requested."""
    batch: DesignBatch
    summary: DesignBatch | None = None
    memo_hit: bool = False
    elapsed_ms: float = 0.0


@dataclass
class ServiceStats:
    """Mutable counter block behind `DSEService.stats()`."""
    requests: int = 0
    sweep_queries: int = 0
    yield_queries: int = 0
    windows: int = 0
    dispatches: int = 0
    memo_hits: int = 0
    memo_misses: int = 0
    memo_evictions: int = 0
    coalesced: int = 0
    rows_requested: int = 0
    rows_dispatched: int = 0
    chunks_streamed: int = 0
    errors: int = 0
    total_latency_ms: float = 0.0
    max_latency_ms: float = 0.0


@dataclass
class _Pending:
    query: Query
    future: Future
    t0: float


@dataclass(frozen=True)
class StreamChunk:
    """One streamed partial result: chunk `index`'s sub-space and its
    served response (`response.batch` holds the rows)."""
    index: int
    space: DesignSpace
    response: Response


def _pack_operands(parts) -> transient.FusedOperands:
    """Concatenate per-request operand batches into one shared slab.

    All parts share the ladder width (N_NODES is a module constant) and
    the replica flag (grouped by the caller); replica parts have even row
    counts, so [replica, main] pairs stay adjacent across the seam.
    """
    cat = lambda i: jnp.concatenate([jnp.asarray(p[i]) for p in parts])
    return transient.FusedOperands(
        *(cat(i) for i in range(8)), replica=parts[0].replica)


class DSEService:
    """Long-lived co-design engine: warm dispatches, micro-batched
    windows, memoized results.

    Thread-safe.  `start()`/`stop()` control the background dispatcher
    (also usable as a context manager); without it every blocking call
    serves its own window inline and `submit`/`flush` give deterministic
    window control.

    Lock discipline (checked by `tools/flowcheck --only locks`, and the
    contract documented in docs/serving.md):

    - `self._cv` (Condition) protects the request-side state: `_queue`,
      `_stats`, `_running`, `_thread`.  Nothing blocking — in particular
      no JAX dispatch — ever runs under it.
    - `self._dispatch_lock` (Lock) serializes serving and protects the
      memo (`_memo`).  The only permitted nesting is
      `_dispatch_lock -> _cv` (stats updates inside a serve); the
      reverse order never occurs, so the pair cannot deadlock.
    - shared attributes are always accessed as `self.<attr>` under the
      owning lock — never aliased into a local first — so every access
      is visible to the static checker.
    """

    def __init__(self, window_ms: float = 3.0, memo_entries: int = 64,
                 backend: str = "auto",
                 b_chunk: int = transient.DEFAULT_B_CHUNK):
        if memo_entries < 0:
            raise ValueError(f"memo_entries must be >= 0, got {memo_entries}")
        self.window_ms = float(window_ms)
        self.memo_entries = int(memo_entries)
        self.backend = backend
        self.b_chunk = transient.validate_b_chunk(b_chunk)
        self._memo: OrderedDict[tuple, DesignBatch] = OrderedDict()
        self._queue: list[_Pending] = []
        self._cv = threading.Condition()
        self._dispatch_lock = threading.Lock()
        self._stats = ServiceStats()
        self._thread: threading.Thread | None = None
        self._running = False

    # ------------------------------------------------------------ client --
    def submit(self, space: DesignSpace, kind: str = "sweep",
               with_transient: bool = True,
               spec: dict | None = None) -> Future:
        """Enqueue one query; returns a Future resolving to a `Response`.

        With the dispatcher running, the query is served at the close of
        the current micro-batch window alongside every other client's
        queued queries; otherwise it waits for `flush()` (or any blocking
        call, which flushes inline).
        """
        query = Query.make(space, kind=kind, with_transient=with_transient,
                           spec=spec)
        pending = _Pending(query=query, future=Future(),
                           t0=time.perf_counter())
        with self._cv:
            self._queue.append(pending)
            self._stats.requests += 1
            if query.kind == "yield":
                self._stats.yield_queries += 1
            else:
                self._stats.sweep_queries += 1
            self._cv.notify()
        return pending.future

    def sweep(self, space: DesignSpace, with_transient: bool = True,
              timeout: float | None = 60.0) -> DesignBatch:
        """Blocking sweep query -> `DesignBatch` (the `dse.sweep`
        equivalent, served through the shared engine)."""
        fut = self.submit(space, kind="sweep", with_transient=with_transient)
        if not self._dispatcher_running():
            self.flush()
        return fut.result(timeout=timeout).batch

    def query_yield(self, space: DesignSpace, timeout: float | None = 60.0,
                    **spec) -> Response:
        """Blocking yield query: MC sweep + `mc_summary(**spec)` reduction.

        The response's `batch` is the full sample-major MC batch and
        `summary` the one-row-per-design reduction (with
        `corners["yield_frac"]` / `corners["ess"]`).
        """
        fut = self.submit(space, kind="yield", spec=spec)
        if not self._dispatcher_running():
            self.flush()
        return fut.result(timeout=timeout)

    def sweep_stream(self, space: DesignSpace, chunk_rows: int | None = None,
                     timeout: float | None = 60.0):
        """Stream a large sweep as per-chunk partial results.

        Partitions the space into entry-aligned sub-spaces of at most
        `chunk_rows` lowered rows (default: the engine's `b_chunk`) and
        yields a `StreamChunk` per sub-space as it is served — each
        chunk's batch is exactly `dse.sweep(chunk.space)` (same memo and
        micro-batch machinery as any other client, so a re-streamed
        sweep hits the memo chunk by chunk).  Corner axes partition
        cleanly (each chunk carries the full corner product for its
        entries); Monte-Carlo spaces are rejected, because the MC draw
        stream depends on the lowered base length — a chunked MC sweep
        would silently differ from the monolithic one.
        """
        if space.mc is not None:
            raise ValueError(
                "sweep_stream cannot chunk a with_mc space: the MC draws "
                "depend on the lowered base length, so chunked results "
                "would differ from the monolithic sweep — sweep it whole, "
                "or stream the nominal space and run MC on the survivors")
        chunk_rows = int(chunk_rows if chunk_rows is not None
                         else self.b_chunk)
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        for i, sub in enumerate(_split_space(space, chunk_rows)):
            fut = self.submit(sub, kind="sweep")
            if not self._dispatcher_running():
                self.flush()
            resp = fut.result(timeout=timeout)
            with self._cv:
                self._stats.chunks_streamed += 1
            yield StreamChunk(index=i, space=sub, response=resp)

    def warm(self, space: DesignSpace | None = None) -> Response:
        """Pre-compile the fused dispatch (and seed the memo) with a
        small sweep — `DesignSpace.paper_targets()` by default — so the
        first real client never pays the jit trace."""
        space = space if space is not None else DesignSpace.paper_targets()
        fut = self.submit(space, kind="sweep")
        if not self._dispatcher_running():
            self.flush()
        return fut.result(timeout=None)

    # --------------------------------------------------------- lifecycle --
    def _dispatcher_running(self) -> bool:
        with self._cv:
            return self._running

    def start(self) -> "DSEService":
        """Launch the background dispatcher (idempotent)."""
        with self._cv:
            if self._running:
                return self
            self._running = True
            thread = threading.Thread(target=self._dispatch_loop,
                                      name="dse-service", daemon=True)
            self._thread = thread
        thread.start()
        return self

    def stop(self) -> None:
        """Stop the dispatcher after draining the queue."""
        with self._cv:
            if not self._running:
                return
            self._running = False
            thread, self._thread = self._thread, None
            self._cv.notify_all()
        if thread is not None:
            thread.join()

    def __enter__(self) -> "DSEService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and self._running:
                    self._cv.wait(timeout=0.05)
                if not self._queue and not self._running:
                    return
            # window open: wait for concurrent clients to pile on
            time.sleep(self.window_ms / 1e3)
            self.flush()

    # ---------------------------------------------------------- serving --
    def flush(self) -> int:
        """Serve everything queued right now as one micro-batch window;
        returns the number of requests served."""
        with self._cv:
            pending, self._queue = self._queue, []
        if not pending:
            return 0
        with self._dispatch_lock:
            try:
                self._serve_window(pending)
            except Exception as e:       # safety net; errors surface via
                failed = [p for p in pending if not p.future.done()]
                with self._cv:           # the futures, never kill the loop
                    self._stats.errors += len(failed)
                for p in failed:
                    p.future.set_exception(e)
        return len(pending)

    def _serve_window(self, pending: list[_Pending]) -> None:
        ready: list[tuple[_Pending, DesignBatch, bool]] = []
        misses: OrderedDict[tuple, list[_Pending]] = OrderedDict()
        hits = coalesced = rows_requested = 0
        for p in pending:
            rows_requested += len(p.query.space)
            cached = self._memo_get(p.query.key)
            if cached is not None:
                hits += 1
                ready.append((p, cached, True))
            else:
                group = misses.setdefault(p.query.key, [])
                if group:
                    # identical concurrent queries coalesce onto one plan
                    coalesced += 1
                group.append(p)
        with self._cv:
            self._stats.windows += 1
            self._stats.rows_requested += rows_requested
            self._stats.memo_hits += hits
            self._stats.memo_misses += len(misses)
            self._stats.coalesced += coalesced

        # plan every unique miss (a bad request fails only its own
        # group), then pack compatible operand batches into shared
        # slabs: ONE fused dispatch per (replica-mode) group
        plans: dict[tuple, dse.SweepPlan] = {}
        for key, group in misses.items():
            try:
                plans[key] = dse.plan_sweep(
                    group[0].query.space,
                    with_transient=group[0].query.with_transient)
            except Exception as e:
                self._fail(group, e)
        results: dict[tuple, transient.RowCycleResult | None] = {
            k: None for k in plans if plans[k].operands is None}
        needs_engine = [k for k in plans if plans[k].operands is not None]
        for _, keys in itertools.groupby(
                sorted(needs_engine,
                       key=lambda k: plans[k].operands.replica),
                key=lambda k: plans[k].operands.replica):
            keys = list(keys)
            parts = [plans[k].operands for k in keys]
            packed = _pack_operands(parts)
            evt = transient.row_cycle_events(packed, backend=self.backend,
                                             b_chunk=self.b_chunk)
            with self._cv:
                self._stats.dispatches += 1
                self._stats.rows_dispatched += int(packed.c.shape[0])
            lo = 0
            for k, part in zip(keys, parts):
                b = int(part.c.shape[0])
                results[k] = transient.result_from_events(part,
                                                          evt[lo:lo + b])
                lo += b

        for key, group in misses.items():
            if key not in plans:
                continue   # plan failed; futures already carry the error
            try:
                batch = dse.finalize_sweep(plans[key], results[key])
            except Exception as e:
                self._fail(group, e)
                continue
            self._memo_put(key, batch)
            ready.extend((p, batch, False) for p in group)

        for p, batch, was_hit in ready:
            try:
                p.future.set_result(self._respond(p, batch, was_hit))
            except Exception as e:
                with self._cv:
                    self._stats.errors += 1
                if not p.future.done():
                    p.future.set_exception(e)

    def _fail(self, group: list[_Pending], exc: Exception) -> None:
        with self._cv:
            self._stats.errors += len(group)
        for p in group:
            if not p.future.done():
                p.future.set_exception(exc)

    def _respond(self, p: _Pending, batch: DesignBatch,
                 was_hit: bool) -> Response:
        summary = None
        if p.query.kind == "yield":
            summary = batch.mc_summary(**dict(p.query.spec))
        elapsed_ms = (time.perf_counter() - p.t0) * 1e3
        with self._cv:
            self._stats.total_latency_ms += elapsed_ms
            self._stats.max_latency_ms = max(self._stats.max_latency_ms,
                                             elapsed_ms)
        return Response(batch=batch, summary=summary, memo_hit=was_hit,
                        elapsed_ms=elapsed_ms)

    # -------------------------------------------------------------- memo --
    # `_memo_get`/`_memo_put` run on the serving path, which already holds
    # `_dispatch_lock` (flush acquires it around `_serve_window`); the
    # public `memo_clear` takes it explicitly.
    def _memo_get(self, key: tuple) -> DesignBatch | None:
        batch = self._memo.get(key)
        if batch is not None:
            self._memo.move_to_end(key)
        return batch

    def _memo_put(self, key: tuple, batch: DesignBatch) -> None:
        if not self.memo_entries:
            return
        self._memo[key] = batch
        self._memo.move_to_end(key)
        evicted = 0
        while len(self._memo) > self.memo_entries:
            self._memo.popitem(last=False)
            evicted += 1
        if evicted:
            with self._cv:
                self._stats.memo_evictions += evicted

    def memo_clear(self) -> int:
        """Drop every memoized result; returns how many were dropped."""
        with self._dispatch_lock:
            n = len(self._memo)
            self._memo.clear()
        return n

    # ------------------------------------------------------------- stats --
    def stats(self) -> dict:
        """Counters + derived rates — the service's `stats()` endpoint."""
        with self._cv:
            st = replace(self._stats)
            queued = len(self._queue)
        with self._dispatch_lock:
            memo_entries = len(self._memo)
        lookups = st.memo_hits + st.memo_misses
        served = st.memo_hits + st.memo_misses + st.coalesced
        return {
            "requests": st.requests,
            "queued": queued,
            "sweep_queries": st.sweep_queries,
            "yield_queries": st.yield_queries,
            "windows": st.windows,
            "dispatches": st.dispatches,
            "memo": {
                "entries": memo_entries,
                "capacity": self.memo_entries,
                "hits": st.memo_hits,
                "misses": st.memo_misses,
                "evictions": st.memo_evictions,
                "coalesced": st.coalesced,
                "hit_rate": st.memo_hits / lookups if lookups else 0.0,
            },
            "rows": {
                "requested": st.rows_requested,
                "dispatched": st.rows_dispatched,
            },
            "chunks_streamed": st.chunks_streamed,
            "errors": st.errors,
            "latency_ms": {
                "mean": st.total_latency_ms / served if served else 0.0,
                "max": st.max_latency_ms,
            },
        }


def _split_space(space: DesignSpace, chunk_rows: int):
    """Partition a (non-MC) space into sub-spaces of <= chunk_rows lowered
    rows each, entry-aligned and in entry order.

    Corner axes replicate into every chunk (the corner product rides each
    sub-space whole), so the per-entry row cost is len(grid) * reps; a
    single entry larger than the chunk budget is split along its layer
    grid.  For corner-free spaces, concatenating the chunks' batches in
    order reproduces the monolithic sweep's row order exactly.
    """
    reps = 1
    for _, vals in space.corner_axes:
        reps *= len(vals)
    per_chunk = max(1, chunk_rows // reps)
    pieces = []
    for tname, sname, grid in space.entries:
        for i in range(0, len(grid), per_chunk):
            pieces.append((tname, sname, tuple(grid[i:i + per_chunk])))
    out, rows = [], 0
    for piece in pieces:
        cost = len(piece[2])
        if out and rows + cost > per_chunk:
            yield replace(space, entries=tuple(out))
            out, rows = [], 0
        out.append(piece)
        rows += cost
    if out:
        yield replace(space, entries=tuple(out))
