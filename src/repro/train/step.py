"""Step factories: train_step / serve_prefill / serve_decode.

These are the functions the dry-run lowers and the launchers execute.  All
are pure; sharding is attached by the caller (jax.jit in_shardings built
from repro.distributed.sharding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import registry as M
from .optimizer import OptConfig, make_optimizer


def make_train_step(cfg, oc: OptConfig | None = None,
                    microbatch: int | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    `microbatch`: number of gradient-accumulation slices of the global batch
    (sequential lax.scan), trading step latency for activation memory.
    """
    opt = make_optimizer(cfg.optimizer, oc)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch)

    def grads_of(params, batch):
        if not microbatch or microbatch <= 1:
            return jax.value_and_grad(loss_of)(params, batch)

        def slice_mb(i, x):
            mb = x.shape[0] // microbatch
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, loss_acc = carry
            mb_batch = jax.tree.map(functools.partial(slice_mb, i), batch)
            loss, g = jax.value_and_grad(loss_of)(params, mb_batch)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())),
                                       jnp.arange(microbatch))
        scale = 1.0 / microbatch
        return lsum * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        # global-norm clip at 1.0
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-6))
        grads = jax.tree.map(lambda g: g * clip, grads)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step, opt


def make_serve_prefill(cfg):
    def serve_prefill(params, batch):
        return M.prefill(cfg, params, batch)
    return serve_prefill


def make_serve_decode(cfg):
    def serve_decode(params, cache, token, pos):
        logits, new_cache = M.decode_step(cfg, params, cache, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, new_cache
    return serve_decode
