"""Fault-tolerant training loop (the end-to-end driver).

Composes: data pipeline -> jit'd train step (sharded if a mesh is given)
-> checkpoint manager -> FaultTolerantRunner (crash/NaN restart) ->
optional strapped hierarchical gradient sync for multi-pod meshes.
Runs for real on CPU (examples/train_lm.py trains a ~100M model).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from ..ckpt.manager import CheckpointManager
from ..configs.base import ArchConfig
from ..data.pipeline import DataLoader, LoaderConfig, SyntheticSource
from ..models import registry as M
from ..runtime.fault import FailureInjector, FaultTolerantRunner
from .optimizer import OptConfig
from .step import make_train_step


@dataclass
class TrainConfig:
    steps: int = 200
    batch_size: int = 8
    seq_len: int = 256
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatch: int | None = None
    opt: OptConfig = field(default_factory=OptConfig)
    seed: int = 0
    failure_schedule: dict = field(default_factory=dict)


def train(cfg: ArchConfig, tc: TrainConfig, verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(tc.seed)
    params = M.init_params(cfg, key)
    step_fn, opt = make_train_step(cfg, tc.opt, tc.microbatch)
    opt_state = opt.init(params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    source = SyntheticSource(cfg.vocab_size, tc.seed)
    loader = DataLoader(source, LoaderConfig(batch_size=tc.batch_size,
                                             seq_len=tc.seq_len,
                                             seed=tc.seed))
    ckpt = CheckpointManager(tc.ckpt_dir, keep=2)

    state = dict(params=params, opt=opt_state)
    losses = []
    t_start = time.time()

    def do_step(state, step):
        batch = loader.batch_at(step)   # deterministic: restart-safe
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = jit_step(state["params"], state["opt"],
                                              batch)
        m = {k: float(v) for k, v in metrics.items()}
        losses.append(m["loss"])
        if verbose and step % tc.log_every == 0:
            dt = time.time() - t_start
            tps = (step + 1) * tc.batch_size * tc.seq_len / max(dt, 1e-9)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} tok/s {tps:,.0f}", flush=True)
        return dict(params=params, opt=opt_state), m

    def save(step, state):
        ckpt.save(step, state, blocking=False)

    def restore():
        ckpt.wait()
        restored, step = ckpt.restore(like=state)
        if verbose:
            print(f"[fault] restored from checkpoint @ step {step}",
                  flush=True)
        return restored, step

    # initial checkpoint so a crash at step 0 can restore
    ckpt.save(0, state, blocking=True)
    runner = FaultTolerantRunner(do_step, save, restore,
                                 injector=FailureInjector(tc.failure_schedule),
                                 ckpt_every=tc.ckpt_every)
    state, log = runner.run(state, tc.steps)
    ckpt.wait()
    loader.close()
    return dict(final_loss=losses[-1] if losses else None,
                first_loss=losses[0] if losses else None,
                losses=losses, restarts=runner.restarts, log=log,
                state=state)
