"""Optimizers, built from scratch (no optax): AdamW and AdamW8bit.

AdamW8bit keeps both Adam moments in int8 with per-row fp32 scales
(block = last dim), cutting optimizer-state HBM 4x — this is what lets
arctic-480b's train state fit 16 GB/chip at 256 chips (see DESIGN.md §5).
State tensors inherit the parameter's sharding (co-located, "CBA" rule).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(oc: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos
    return oc.lr * jnp.minimum(warm, 1.0) * decay


# ---------------------------------------------------------------------------
# AdamW (fp32 moments)
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return dict(m=zeros,
                v=jax.tree.map(jnp.copy, zeros),
                count=jnp.zeros((), jnp.int32))


def adamw_update(oc: OptConfig, grads, state, params):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    lr = lr_schedule(oc, count)
    bc1 = 1 - oc.b1 ** c
    bc2 = 1 - oc.b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = oc.b1 * m + (1 - oc.b1) * g32
        v = oc.b2 * v + (1 - oc.b2) * g32 * g32
        step = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        step = step + oc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, dict(m=new_m, v=new_v, count=count)


# ---------------------------------------------------------------------------
# AdamW8bit (int8 moments, per-row scales)
# ---------------------------------------------------------------------------

def _q8(x):
    """Quantize along the last dim: returns (int8, fp32 scale[..., 1])."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dq8(q, s):
    return q.astype(jnp.float32) * s


def adamw8_init(params):
    def z8(p):
        q, s = _q8(jnp.zeros(p.shape, jnp.float32))
        return dict(q=q, s=s)
    return dict(m=jax.tree.map(z8, params),
                v=jax.tree.map(z8, params),
                count=jnp.zeros((), jnp.int32))


def adamw8_update(oc: OptConfig, grads, state, params):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    lr = lr_schedule(oc, count)
    bc1 = 1 - oc.b1 ** c
    bc2 = 1 - oc.b2 ** c

    def upd(g, mq, vq, p):
        g32 = g.astype(jnp.float32)
        m = oc.b1 * _dq8(mq["q"], mq["s"]) + (1 - oc.b1) * g32
        v = oc.b2 * _dq8(vq["q"], vq["s"]) + (1 - oc.b2) * g32 * g32
        v = jnp.maximum(v, 0.0)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        step = step + oc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        nmq, nms = _q8(m)
        nvq, nvs = _q8(v)
        return new_p, dict(q=nmq, s=nms), dict(q=nvq, s=nvs)

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])
    leaves_p = treedef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p
            in zip(leaves_g, leaves_m, leaves_v, leaves_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_p, dict(m=new_m, v=new_v, count=count)


# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def make_optimizer(name: str, oc: OptConfig | None = None) -> Optimizer:
    oc = oc or OptConfig()
    if name == "adamw":
        return Optimizer(adamw_init,
                         lambda g, s, p: adamw_update(oc, g, s, p))
    if name == "adamw8bit":
        return Optimizer(adamw8_init,
                         lambda g, s, p: adamw8_update(oc, g, s, p))
    raise ValueError(name)


def opt_state_axes(name: str, param_axes_tree):
    """Optimizer-state logical axes mirror the parameter axes (co-location)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if name == "adamw":
        return dict(m=param_axes_tree, v=param_axes_tree, count=())
    if name == "adamw8bit":
        def q8_axes(ax):
            return dict(q=ax, s=ax[:-1] + (None,))
        mapped = jax.tree.map(q8_axes, param_axes_tree, is_leaf=is_axes)
        return dict(m=mapped, v=mapped, count=())
    raise ValueError(name)


def abstract_opt_state(name: str, abstract_params):
    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    if name == "adamw":
        return dict(m=jax.tree.map(f32, abstract_params),
                    v=jax.tree.map(f32, abstract_params),
                    count=jax.ShapeDtypeStruct((), jnp.int32))
    if name == "adamw8bit":
        def q8(p):
            return dict(q=jax.ShapeDtypeStruct(p.shape, jnp.int8),
                        s=jax.ShapeDtypeStruct(p.shape[:-1] + (1,), jnp.float32))
        return dict(m=jax.tree.map(q8, abstract_params),
                    v=jax.tree.map(q8, abstract_params),
                    count=jax.ShapeDtypeStruct((), jnp.int32))
    raise ValueError(name)
