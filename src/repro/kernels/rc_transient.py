"""Pallas TPU kernel: fused multi-step RC-ladder transient (SPICE inner loop).

This is the compute hot-spot of the paper's methodology: implicit-Euler
time-stepping of batched tridiagonal RC networks (bitline ladders), swept
over thousands of design points by the DSE.

TPU adaptation (vs. a CUDA SPICE engine): instead of one-thread-per-netlist
with shared-memory staging, we tile the *design batch* across the grid and
keep the entire (B_blk, N) ladder state resident in VMEM for ALL T time
steps — the HBM traffic is one read of the netlist and one write of the
(decimated) trace, independent of T.  The Thomas recurrences are sequential
in N (N is small: 6-8 nodes) but fully vectorized across the batch lanes,
which matches the VPU's (8, 128) vector registers: batch is the lane axis.

Grid:      (ceil(B / B_BLK),)
BlockSpec: every operand blocked along batch only; `ramp` (T,) replicated.
VMEM use:  (T_trace + 6) * B_BLK * N * 4B  — a few MB for typical sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B_BLK = 128


def _rc_kernel(c_ref, g_ref, gc_ref, vc_ref, v0_ref, ramp_ref, trace_ref,
               *, n_steps: int, dt: float):
    """One batch-block: run n_steps implicit-Euler steps, write full trace."""
    c = c_ref[...]            # (B_blk, N)
    g_br = g_ref[...]         # (B_blk, N-1)
    gc = gc_ref[...]          # (B_blk, N)
    vc = vc_ref[...]          # (B_blk, N)
    n = c.shape[-1]
    cdt = c / dt * 1e-3       # fF/ns = uS -> mS units (match G in 1/kOhm)

    def body(t, v):
        s = ramp_ref[t]
        # tridiagonal assembly: A = C/dt + G(s)
        g_last = g_br[:, n - 2] * s
        g = jnp.concatenate([g_br[:, : n - 2], g_last[:, None]], axis=1)
        zeros = jnp.zeros_like(c[:, :1])
        g_lo = jnp.concatenate([zeros, g], axis=1)
        g_hi = jnp.concatenate([g, zeros], axis=1)
        diag = cdt + g_lo + g_hi + gc
        dl = jnp.concatenate([zeros, -g], axis=1)
        du = jnp.concatenate([-g, zeros], axis=1)
        rhs = cdt * v + gc * vc

        # Thomas forward sweep (static N, unrolled: N is 6-8)
        cp = [None] * n
        dp = [None] * n
        cp[0] = du[:, 0] / diag[:, 0]
        dp[0] = rhs[:, 0] / diag[:, 0]
        for i in range(1, n):
            denom = diag[:, i] - dl[:, i] * cp[i - 1]
            cp[i] = du[:, i] / denom
            dp[i] = (rhs[:, i] - dl[:, i] * dp[i - 1]) / denom
        # back substitution
        x = [None] * n
        x[n - 1] = dp[n - 1]
        for i in range(n - 2, -1, -1):
            x[i] = dp[i] - cp[i] * x[i + 1]
        v_next = jnp.stack(x, axis=1)
        trace_ref[t, :, :] = v_next
        return v_next

    jax.lax.fori_loop(0, n_steps, body, v0_ref[...])


def rc_multistep_pallas(c: jnp.ndarray, g_branch: jnp.ndarray,
                        g_clamp: jnp.ndarray, v_clamp: jnp.ndarray,
                        v0: jnp.ndarray, ramp: jnp.ndarray, dt: float,
                        *, b_blk: int = DEFAULT_B_BLK,
                        interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of `ref.rc_multistep_ref` -> (T, B, N)."""
    b, n = c.shape
    t = ramp.shape[0]
    b_blk = min(b_blk, b)
    n_blocks = pl.cdiv(b, b_blk)

    # pad batch to a block multiple
    pad = n_blocks * b_blk - b
    if pad:
        padf = lambda x: jnp.pad(x, ((0, pad), (0, 0)), constant_values=1.0)
        c, g_branch, g_clamp, v_clamp, v0 = map(
            padf, (c, g_branch, g_clamp, v_clamp, v0))

    kernel = functools.partial(_rc_kernel, n_steps=t, dt=dt)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((b_blk, n), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, n - 1), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, n), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, n), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, n), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, b_blk, n), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n_blocks * b_blk, n), c.dtype),
        interpret=interpret,
    )(c, g_branch, g_clamp, v_clamp, v0, ramp)
    return out[:, :b, :]
