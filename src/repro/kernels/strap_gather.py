"""Pallas TPU kernel: selector+strap gated KV gather + flash-decode attention.

TPU adaptation of the paper's "BL Selector + Strap" (Fig. 2d): the KV cache
is paged in HBM; pages are grouped into *straps* (G consecutive pages).  A
*selector* chooses which straps participate in a decode step; only selected
straps are streamed HBM -> VMEM, exactly like the IGO selector keeping
unselected local bitlines off the global line.  HBM bytes per decoded token
drop by the strap selectivity (the C_BL 20 fF -> 6.6 fF analogue).

Layout / schedule:
  grid = (B, Hkv, S)          S = number of selected straps per sequence
  The strap axis is the innermost (sequential, "arbitrary") grid dim; the
  kernel keeps the online-softmax state (m, l, o-accumulator) for the
  (batch, kv-head) tile in VMEM scratch across strap steps and writes the
  normalized output on the last strap.
  Page indices arrive via scalar prefetch (PrefetchScalarGridSpec) so the
  index-mapped BlockSpec can fetch k/v blocks straight from HBM at block
  granularity — i.e. the gather *is* the block index map; no materialized
  gathered copy ever exists in HBM.

q heads are grouped GQA-style: the (Hq/Hkv) query heads of a kv head are
processed together as the sublane axis of the (grp, page*G? no — strap) tile.
Masked straps (id < 0) contribute nothing (handled by -inf masking).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _compiler_params(**kw):
    """TPU compiler params across jax versions (CompilerParams was renamed)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kw)


def _strap_kernel(strap_ids_ref,          # scalar prefetch: (B, S)
                  lengths_ref,            # scalar prefetch: (B,)
                  q_ref,                  # (1, grp, D)
                  k_ref,                  # (1, G*page, 1, D)
                  v_ref,                  # (1, G*page, 1, D)
                  o_ref,                  # (1, grp, D)
                  m_ref, l_ref, acc_ref,  # VMEM scratch
                  *, scale: float, num_straps: int, blk: int):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    strap_id = strap_ids_ref[b, s]
    valid = strap_id >= 0

    q = q_ref[0, 0].astype(jnp.float32)                 # (grp, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (T_blk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (T_blk, D)

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # token-level mask: a partially filled strap has zero-padding tokens at
    # flat positions >= lengths[b]; their logit would be a perfectly valid
    # q.0 = 0 and they'd steal softmax mass, so mask them like the dense path
    tok_pos = strap_id * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    tok_ok = tok_pos < lengths_ref[b]                   # (1, blk)
    logits = jnp.where(tok_ok, logits, NEG_INF)

    m_prev = m_ref[...]                                 # (grp, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    # an invalid (masked) strap must not move the running max
    m_cur = jnp.where(valid, m_cur, jnp.full_like(m_cur, NEG_INF))
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    # zero p both for masked straps and masked tokens (the latter guards the
    # degenerate exp(NEG_INF - NEG_INF) = 1 case when nothing valid yet)
    p = jnp.where(valid & tok_ok, p, jnp.zeros_like(p))
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s == num_straps - 1)
    def _finalize():
        # guard against fully-masked selection (all straps -1): emit zeros
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def strap_attend_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, strap_ids: jnp.ndarray,
                        pages_per_strap: int, scale: float | None = None,
                        lengths: jnp.ndarray | None = None,
                        *, interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of `ref.strap_attend_ref` -> (B, Hq, D).

    q         : (B, Hq, D)
    k_pages   : (B, P, page, Hkv, D)
    v_pages   : (B, P, page, Hkv, D)
    strap_ids : (B, S) int32, -1 = masked
    lengths   : (B,) int32 valid-token counts (None = every token valid)
    """
    b, p, page, hkv, d = k_pages.shape
    _, hq, _ = q.shape
    grp = hq // hkv
    s = strap_ids.shape[1]
    g = pages_per_strap
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    # flatten pages to a token axis; a strap is a contiguous block of G*page
    # tokens, so the index map can address it directly.
    k_flat = k_pages.reshape(b, p * page, hkv, d)
    v_flat = v_pages.reshape(b, p * page, hkv, d)
    q_g = q.reshape(b, hkv, grp, d)
    blk = g * page

    raw_ids = strap_ids.astype(jnp.int32)
    if lengths is None:
        lengths = jnp.full((b,), p * page, jnp.int32)   # all tokens valid
    lengths = lengths.astype(jnp.int32)

    # NOTE: with PrefetchScalarGridSpec the index maps receive
    # (*grid_indices, *scalar_prefetch_refs).  Masked ids (-1) are clamped
    # to 0 *only for addressing*; the kernel sees the raw id for validity.
    def q_map(bi, hi, si, ids, lens):
        del ids, lens, si
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, si, ids, lens):
        del lens
        return (bi, jnp.maximum(ids[bi, si], 0), hi, 0)

    def o_map(bi, hi, si, ids, lens):
        del ids, lens, si
        return (bi, hi, 0, 0)

    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, s),
        in_specs=[
            pl.BlockSpec((1, 1, grp, d), q_map),
            pl.BlockSpec((1, blk, 1, d), kv_map),
            pl.BlockSpec((1, blk, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, grp, d), o_map),
        scratch_shapes=[
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, d), jnp.float32),
        ],
    )

    kernel = functools.partial(_strap_kernel, scale=scale, num_straps=s,
                               blk=blk)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, grp, d), q.dtype),
        interpret=interpret,
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(raw_ids, lengths, q_g, k_flat, v_flat)
    return out.reshape(b, hq, d)
