"""Jit'd public wrappers for the Pallas kernels, with backend dispatch.

`backend="auto"` picks the Pallas kernel on TPU and the pure-jnp oracle on
CPU (where `interpret=True` Pallas is a Python-level interpreter and much
slower than XLA:CPU).  Tests force `backend="pallas"` with interpret mode
to validate the kernels against the oracles.
"""

from __future__ import annotations

import functools

import jax

from . import ref
from .rc_transient import rc_multistep_pallas
from .row_cycle import row_cycle_fused_pallas
from .strap_gather import strap_attend_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("dt", "backend"))
def rc_multistep(c, g_branch, g_clamp, v_clamp, v0, ramp, dt,
                 backend: str = "auto"):
    """Batched RC-ladder implicit-Euler transient -> (T, B, N) trace."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return rc_multistep_pallas(c, g_branch, g_clamp, v_clamp, v0, ramp,
                                   dt, interpret=not _on_tpu())
    return ref.rc_multistep_ref(c, g_branch, g_clamp, v_clamp, v0, ramp, dt)


@functools.partial(jax.jit, static_argnames=("dt", "n_act", "n_res",
                                             "n_pre", "backend"))
def row_cycle_fused(c, g_branch, gc_res, gc_pre, v0, params, dt,
                    n_act, n_res, n_pre, backend: str = "auto"):
    """Fused ACT/RESTORE/PRE row-cycle engine -> (events (B,4), v_end (B,N)).

    Trace-free: O(B) outputs regardless of the number of time steps.  See
    `ref.row_cycle_fused_ref` for the params layout and event semantics.
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return row_cycle_fused_pallas(c, g_branch, gc_res, gc_pre, v0,
                                      params, dt, n_act, n_res, n_pre,
                                      interpret=not _on_tpu())
    return ref.row_cycle_fused_ref(c, g_branch, gc_res, gc_pre, v0, params,
                                   dt, n_act, n_res, n_pre)


@functools.partial(jax.jit, static_argnames=("pages_per_strap", "scale", "backend"))
def strap_attend(q, k_pages, v_pages, strap_ids, pages_per_strap,
                 scale=None, backend: str = "auto", lengths=None):
    """Selector+strap gated decode attention -> (B, Hq, D).

    `lengths` ((B,) int32, optional) is the valid token count per sequence;
    tokens at flat positions >= lengths[b] are padding inside a partially
    filled strap and are masked out of the softmax.  `None` attends every
    token of every selected strap (all-valid).
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return strap_attend_pallas(q, k_pages, v_pages, strap_ids,
                                   pages_per_strap, scale,
                                   lengths=lengths,
                                   interpret=not _on_tpu())
    return ref.strap_attend_ref(q, k_pages, v_pages, strap_ids,
                                pages_per_strap, scale, lengths=lengths)


def tridiag_solve(dl, d, du, b):
    """Batched Thomas solve (used standalone by the transient engine)."""
    return ref.tridiag_solve_ref(dl, d, du, b)
