"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the Pallas implementations are validated against
(tests sweep shapes/dtypes and assert allclose).  They are also the default
execution path on CPU, where `interpret=True` Pallas is slower.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Batched tridiagonal solve (Thomas algorithm)
# --------------------------------------------------------------------------

def tridiag_solve_ref(dl: jnp.ndarray, d: jnp.ndarray, du: jnp.ndarray,
                      b: jnp.ndarray) -> jnp.ndarray:
    """Solve A x = b for tridiagonal A, batched over leading dims.

    dl: (..., N) sub-diagonal, dl[..., 0] ignored
    d : (..., N) main diagonal
    du: (..., N) super-diagonal, du[..., N-1] ignored
    b : (..., N) right-hand side
    """
    n = d.shape[-1]

    def fwd(carry, idx):
        cp_prev, dp_prev = carry
        denom = d[..., idx] - dl[..., idx] * cp_prev
        cp = du[..., idx] / denom
        dp = (b[..., idx] - dl[..., idx] * dp_prev) / denom
        return (cp, dp), (cp, dp)

    denom0 = d[..., 0]
    cp0 = du[..., 0] / denom0
    dp0 = b[..., 0] / denom0
    (_, _), (cps, dps) = jax.lax.scan(fwd, (cp0, dp0), jnp.arange(1, n))
    # stack cp/dp including index 0; cps has shape (n-1, ...)
    cps = jnp.concatenate([cp0[None], cps], axis=0)
    dps = jnp.concatenate([dp0[None], dps], axis=0)

    def bwd(x_next, idx):
        x = dps[idx] - cps[idx] * x_next
        return x, x

    xn = dps[n - 1]
    _, xs = jax.lax.scan(bwd, xn, jnp.arange(n - 2, -1, -1))
    xs = jnp.concatenate([xn[None], xs], axis=0)[::-1]
    # move node axis back to the end
    return jnp.moveaxis(xs, 0, -1)


# --------------------------------------------------------------------------
# RC-ladder multistep implicit-Euler transient (the SPICE inner loop)
# --------------------------------------------------------------------------

def rc_multistep_ref(c: jnp.ndarray, g_branch: jnp.ndarray,
                     g_clamp: jnp.ndarray, v_clamp: jnp.ndarray,
                     v0: jnp.ndarray, ramp: jnp.ndarray,
                     dt: float) -> jnp.ndarray:
    """Simulate T implicit-Euler steps of a batched RC ladder.

    The ladder has N nodes; branch i connects node i and i+1 with
    conductance g_branch[..., i].  The LAST branch (index N-2, the cell
    access transistor) is scaled by `ramp[t]` at step t (WL ramp).  Each
    node may additionally be clamped toward v_clamp through g_clamp.

    c        : (B, N)   node capacitances            [fF]
    g_branch : (B, N-1) branch conductances          [1/kOhm]
    g_clamp  : (B, N)   clamp conductances           [1/kOhm]
    v_clamp  : (B, N)   clamp target voltages        [V]
    v0       : (B, N)   initial node voltages        [V]
    ramp     : (T,)     access-branch scale per step (0..1)
    dt       : step     [ns]    (fF/kOhm -> ps, so G uses 1e-3 factor)

    Returns trace: (T, B, N) node voltages after each step.
    """
    cdt = c / dt * 1e-3  # fF/ns = uS; G is in 1/kOhm = mS -> scale by 1e-3

    def step(v, s):
        # scale the access (last) branch by the WL ramp value for this step
        g = jnp.concatenate([g_branch[..., :-1], g_branch[..., -1:] * s], axis=-1)
        # assemble tridiagonal A = C/dt + G
        zeros = jnp.zeros_like(c[..., :1])
        g_lo = jnp.concatenate([zeros, g], axis=-1)        # g[i-1] at row i
        g_hi = jnp.concatenate([g, zeros], axis=-1)        # g[i]   at row i
        d = cdt + g_lo + g_hi + g_clamp
        dl = jnp.concatenate([zeros, -g], axis=-1)
        du = jnp.concatenate([-g, zeros], axis=-1)
        rhs = cdt * v + g_clamp * v_clamp
        v_next = tridiag_solve_ref(dl, d, du, rhs)
        return v_next, v_next

    _, trace = jax.lax.scan(step, v0, ramp)
    return trace


# --------------------------------------------------------------------------
# Fused ACT/RESTORE/PRE row-cycle engine (event-driven, trace-free)
# --------------------------------------------------------------------------

# params / events column layouts (shared with kernels.row_cycle)
(_PAR_TAU_WL, _PAR_THR_REL, _PAR_VDD, _PAR_VPRE, _PAR_ACTIVE,
 _PAR_ROLE) = range(6)
ROW_CYCLE_N_PARAMS = 6
ROW_CYCLE_N_EVENTS = 4
_RESTORE_FRAC = 0.95
_EQUALIZE_TOL_V = 5e-3

# _PAR_ROLE values: how a row's SA enable is timed during ACT.
ROLE_STANDALONE = 0.0   # fixed timing: fires on the row's own 0.9 crossing
ROLE_REPLICA = 1.0      # replica bitline: fires the SA enable of row+1,
                        # then jumps straight to DONE (no RESTORE/PRE)
ROLE_MAIN = 2.0         # main array row: SA enable fired by the replica
                        # at row-1 (rows are interleaved [replica, main])


def _thomas_small(dl, d, du, rhs):
    """Thomas solve unrolled over the last (static, small) axis."""
    n = d.shape[-1]
    cp = [None] * n
    dp = [None] * n
    cp[0] = du[..., 0] / d[..., 0]
    dp[0] = rhs[..., 0] / d[..., 0]
    for i in range(1, n):
        denom = d[..., i] - dl[..., i] * cp[i - 1]
        cp[i] = du[..., i] / denom
        dp[i] = (rhs[..., i] - dl[..., i] * dp[i - 1]) / denom
    x = [None] * n
    x[n - 1] = dp[n - 1]
    for i in range(n - 2, -1, -1):
        x[i] = dp[i] - cp[i] * x[i + 1]
    return jnp.stack(x, axis=-1)


def row_cycle_fused_ref(c: jnp.ndarray, g_branch: jnp.ndarray,
                        gc_res: jnp.ndarray, gc_pre: jnp.ndarray,
                        v0: jnp.ndarray, params: jnp.ndarray,
                        dt: float, n_act: int, n_res: int, n_pre: int):
    """Oracle for the fused row-cycle engine: one pass over ACT/RESTORE/PRE.

    Each design point runs its own phase state machine
    (0=ACT, 1=RESTORE, 2=PRE, 3=DONE):

      ACT    : access branch scaled by the rising WL ramp 1 - e^{-t/tau};
               advances when v[0] - vpre >= thr_rel or after n_act steps.
      RESTORE: access branch fully on, clamp (gc_res -> vdd);
               advances when v[N-1] >= 0.95 * vdd or after n_res steps.
      PRE    : falling WL ramp e^{-t/tau}, clamp (gc_pre -> vpre);
               done when max |v[:N-1] - vpre| <= 5 mV or after n_pre steps.

    Event times are first-crossing times (idx+1)*dt measured from the phase
    start, or NaN on timeout (never crossed inside the phase window) —
    identical semantics to the phased `core.transient` reference, which
    this oracle (and the Pallas kernel validated against it) reproduces to
    within one dt.

    c, gc_res, gc_pre, v0 : (B, N);  g_branch : (B, N-1);  params : (B, 5)
    with columns [tau_wl_ns, thr_rel_v, vdd, vpre, active], or (B, 6) with
    a trailing role column (see ROLE_*).  Replica-closed timing interleaves
    rows as [replica, main] pairs: the replica's own ACT crossing fires the
    SA enable of the main row directly after it, and the replica then skips
    RESTORE/PRE (phase 0 -> 3).  A main row's recorded dv_sense is its own
    developed signal at the moment the replica fires.

    Returns (events, v_end): (B, 4) [t_dev, dv_sense, t_res_dur, t_pre]
    and (B, N) final node voltages.
    """
    b, n = c.shape
    cdt = c / dt * 1e-3  # fF/ns = uS; G in 1/kOhm = mS -> 1e-3 factor
    tau = jnp.maximum(params[:, _PAR_TAU_WL], 1e-3)
    thr_rel = params[:, _PAR_THR_REL]
    vdd = params[:, _PAR_VDD]
    vpre = params[:, _PAR_VPRE]
    active = params[:, _PAR_ACTIVE] > 0.5
    role = (params[:, _PAR_ROLE] if params.shape[1] > _PAR_ROLE
            else jnp.zeros_like(tau))      # static: role column presence
    is_rep = jnp.abs(role - ROLE_REPLICA) < 0.5
    is_main = role > ROLE_MAIN - 0.5
    t_total = n_act + n_res + n_pre
    caps = jnp.asarray([n_act, n_res, n_pre], jnp.int32)

    def cond(state):
        t, phase, _, _, _ = state
        return jnp.logical_and(t < t_total, jnp.any(phase < 3))

    def body(state):
        t, phase, tin, v, evt = state
        in_act = phase == 0
        in_res = phase == 1
        in_pre = phase == 2
        done = phase >= 3

        t_ns = (tin.astype(jnp.float32) + 1.0) * dt
        e = jnp.exp(-t_ns / tau)
        s = jnp.where(in_act, 1.0 - e,
                      jnp.where(in_res, 1.0, jnp.where(in_pre, e, 0.0)))
        gc = jnp.where(in_res[:, None], gc_res,
                       jnp.where(in_pre[:, None], gc_pre, 0.0))
        gcv = jnp.where(in_res[:, None], gc_res * vdd[:, None],
                        jnp.where(in_pre[:, None],
                                  gc_pre * vpre[:, None], 0.0))

        g = jnp.concatenate(
            [g_branch[:, : n - 2], g_branch[:, n - 2:] * s[:, None]], axis=1)
        zeros = jnp.zeros_like(c[:, :1])
        g_lo = jnp.concatenate([zeros, g], axis=1)
        g_hi = jnp.concatenate([g, zeros], axis=1)
        d = cdt + g_lo + g_hi + gc
        dl = jnp.concatenate([zeros, -g], axis=1)
        du = jnp.concatenate([-g, zeros], axis=1)
        v_sol = _thomas_small(dl, d, du, cdt * v + gcv)
        v_next = jnp.where(done[:, None], v, v_sol)

        # SA-enable coupling: a main row's ACT crossing is the crossing of
        # the replica at row-1 (replica/main pairs run ACT in lockstep, so
        # the stateless shift is exact — the main never advances first).
        cross_own = v_next[:, 0] - vpre >= thr_rel
        cross_prev = jnp.concatenate([cross_own[-1:], cross_own[:-1]])
        cross = jnp.stack([
            jnp.where(is_main, cross_prev, cross_own),
            v_next[:, n - 1] >= _RESTORE_FRAC * vdd,
            jnp.max(jnp.abs(v_next[:, : n - 1] - vpre[:, None]),
                    axis=-1) <= _EQUALIZE_TOL_V,
        ])
        tin1 = tin + 1
        phase_c = jnp.clip(phase, 0, 2)
        crossed = jnp.take_along_axis(cross, phase_c[None, :], axis=0)[0]
        cap = caps[phase_c]
        advance = jnp.logical_and(~done,
                                  jnp.logical_or(crossed, tin1 >= cap))
        t_evt = jnp.where(crossed, tin1.astype(jnp.float32) * dt,
                          jnp.float32(jnp.nan))

        rec = lambda ph: jnp.logical_and(advance, phase == ph)
        evt = evt.at[:, 0].set(jnp.where(rec(0), t_evt, evt[:, 0]))
        evt = evt.at[:, 1].set(
            jnp.where(rec(0), v_next[:, 0] - vpre, evt[:, 1]))
        evt = evt.at[:, 2].set(jnp.where(rec(1), t_evt, evt[:, 2]))
        evt = evt.at[:, 3].set(jnp.where(rec(2), t_evt, evt[:, 3]))

        # replica rows are ACT-only: they jump straight to DONE
        phase_inc = jnp.where(is_rep, 3, 1)
        phase = jnp.where(advance, phase + phase_inc, phase)
        tin = jnp.where(advance, 0, jnp.where(done, tin, tin1))
        return t + 1, phase, tin, v_next, evt

    state = (jnp.int32(0), jnp.where(active, 0, 3).astype(jnp.int32),
             jnp.zeros((b,), jnp.int32), v0.astype(jnp.float32),
             jnp.zeros((b, ROW_CYCLE_N_EVENTS), jnp.float32))
    _, _, _, v_fin, evt_fin = jax.lax.while_loop(cond, body, state)
    return evt_fin, v_fin


# --------------------------------------------------------------------------
# Selector+strap gated KV gather + flash-decode attention
# --------------------------------------------------------------------------

def strap_attend_ref(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                     strap_ids: jnp.ndarray, pages_per_strap: int,
                     scale: float | None = None,
                     lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """Oracle for the StrapCache gated decode attention.

    q         : (B, Hq, D)                 one query token per sequence
    k_pages   : (B, P, page, Hkv, D)       paged keys   (P = pages per seq)
    v_pages   : (B, P, page, Hkv, D)       paged values
    strap_ids : (B, S)                     selected strap indices (int32);
                strap s covers pages [s*G, (s+1)*G).  Entries may be -1
                (= strap masked out).
    lengths   : (B,) int32, optional       tokens actually written per
                sequence; positions >= lengths[b] are padding and masked
                out even when their strap is selected (a partially-filled
                strap holds zero-initialised pages whose logit would
                otherwise be 0, not -inf).
    Returns   : (B, Hq, D) attention output over exactly the selected straps.
    """
    b, p, page, hkv, dh = k_pages.shape
    bq, hq, _ = q.shape
    assert bq == b
    grp = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    g = pages_per_strap

    # Build a per-page mask from the selected straps.
    page_strap = jnp.arange(p) // g                      # (P,)
    sel = strap_ids[..., None] == page_strap[None, None, :]   # (B, S, P)
    valid = (strap_ids >= 0)[..., None]
    page_mask = jnp.any(sel & valid, axis=1)             # (B, P)
    token_mask = jnp.repeat(page_mask, page, axis=1)     # (B, P*page)
    if lengths is not None:
        pos = jnp.arange(p * page)[None, :]              # (1, P*page)
        token_mask = token_mask & (pos < lengths[:, None])

    k = k_pages.reshape(b, p * page, hkv, dh)
    v = v_pages.reshape(b, p * page, hkv, dh)
    qg = q.reshape(b, hkv, grp, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(token_mask[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, dh)
