"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the Pallas implementations are validated against
(tests sweep shapes/dtypes and assert allclose).  They are also the default
execution path on CPU, where `interpret=True` Pallas is slower.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Batched tridiagonal solve (Thomas algorithm)
# --------------------------------------------------------------------------

def tridiag_solve_ref(dl: jnp.ndarray, d: jnp.ndarray, du: jnp.ndarray,
                      b: jnp.ndarray) -> jnp.ndarray:
    """Solve A x = b for tridiagonal A, batched over leading dims.

    dl: (..., N) sub-diagonal, dl[..., 0] ignored
    d : (..., N) main diagonal
    du: (..., N) super-diagonal, du[..., N-1] ignored
    b : (..., N) right-hand side
    """
    n = d.shape[-1]

    def fwd(carry, idx):
        cp_prev, dp_prev = carry
        denom = d[..., idx] - dl[..., idx] * cp_prev
        cp = du[..., idx] / denom
        dp = (b[..., idx] - dl[..., idx] * dp_prev) / denom
        return (cp, dp), (cp, dp)

    denom0 = d[..., 0]
    cp0 = du[..., 0] / denom0
    dp0 = b[..., 0] / denom0
    (_, _), (cps, dps) = jax.lax.scan(fwd, (cp0, dp0), jnp.arange(1, n))
    # stack cp/dp including index 0; cps has shape (n-1, ...)
    cps = jnp.concatenate([cp0[None], cps], axis=0)
    dps = jnp.concatenate([dp0[None], dps], axis=0)

    def bwd(x_next, idx):
        x = dps[idx] - cps[idx] * x_next
        return x, x

    xn = dps[n - 1]
    _, xs = jax.lax.scan(bwd, xn, jnp.arange(n - 2, -1, -1))
    xs = jnp.concatenate([xn[None], xs], axis=0)[::-1]
    # move node axis back to the end
    return jnp.moveaxis(xs, 0, -1)


# --------------------------------------------------------------------------
# RC-ladder multistep implicit-Euler transient (the SPICE inner loop)
# --------------------------------------------------------------------------

def rc_multistep_ref(c: jnp.ndarray, g_branch: jnp.ndarray,
                     g_clamp: jnp.ndarray, v_clamp: jnp.ndarray,
                     v0: jnp.ndarray, ramp: jnp.ndarray,
                     dt: float) -> jnp.ndarray:
    """Simulate T implicit-Euler steps of a batched RC ladder.

    The ladder has N nodes; branch i connects node i and i+1 with
    conductance g_branch[..., i].  The LAST branch (index N-2, the cell
    access transistor) is scaled by `ramp[t]` at step t (WL ramp).  Each
    node may additionally be clamped toward v_clamp through g_clamp.

    c        : (B, N)   node capacitances            [fF]
    g_branch : (B, N-1) branch conductances          [1/kOhm]
    g_clamp  : (B, N)   clamp conductances           [1/kOhm]
    v_clamp  : (B, N)   clamp target voltages        [V]
    v0       : (B, N)   initial node voltages        [V]
    ramp     : (T,)     access-branch scale per step (0..1)
    dt       : step     [ns]    (fF/kOhm -> ps, so G uses 1e-3 factor)

    Returns trace: (T, B, N) node voltages after each step.
    """
    cdt = c / dt * 1e-3  # fF/ns = uS; G is in 1/kOhm = mS -> scale by 1e-3

    def step(v, s):
        # scale the access (last) branch by the WL ramp value for this step
        g = jnp.concatenate([g_branch[..., :-1], g_branch[..., -1:] * s], axis=-1)
        # assemble tridiagonal A = C/dt + G
        n = c.shape[-1]
        zeros = jnp.zeros_like(c[..., :1])
        g_lo = jnp.concatenate([zeros, g], axis=-1)        # g[i-1] at row i
        g_hi = jnp.concatenate([g, zeros], axis=-1)        # g[i]   at row i
        d = cdt + g_lo + g_hi + g_clamp
        dl = jnp.concatenate([zeros, -g], axis=-1)
        du = jnp.concatenate([-g, zeros], axis=-1)
        rhs = cdt * v + g_clamp * v_clamp
        v_next = tridiag_solve_ref(dl, d, du, rhs)
        return v_next, v_next

    _, trace = jax.lax.scan(step, v0, ramp)
    return trace


# --------------------------------------------------------------------------
# Selector+strap gated KV gather + flash-decode attention
# --------------------------------------------------------------------------

def strap_attend_ref(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                     strap_ids: jnp.ndarray, pages_per_strap: int,
                     scale: float | None = None) -> jnp.ndarray:
    """Oracle for the StrapCache gated decode attention.

    q         : (B, Hq, D)                 one query token per sequence
    k_pages   : (B, P, page, Hkv, D)       paged keys   (P = pages per seq)
    v_pages   : (B, P, page, Hkv, D)       paged values
    strap_ids : (B, S)                     selected strap indices (int32);
                strap s covers pages [s*G, (s+1)*G).  Entries may be -1
                (= strap masked out).
    Returns   : (B, Hq, D) attention output over exactly the selected straps.
    """
    b, p, page, hkv, dh = k_pages.shape
    bq, hq, _ = q.shape
    assert bq == b
    grp = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    g = pages_per_strap

    # Build a per-page mask from the selected straps.
    page_strap = jnp.arange(p) // g                      # (P,)
    sel = strap_ids[..., None] == page_strap[None, None, :]   # (B, S, P)
    valid = (strap_ids >= 0)[..., None]
    page_mask = jnp.any(sel & valid, axis=1)             # (B, P)
    token_mask = jnp.repeat(page_mask, page, axis=1)     # (B, P*page)

    k = k_pages.reshape(b, p * page, hkv, dh)
    v = v_pages.reshape(b, p * page, hkv, dh)
    qg = q.reshape(b, hkv, grp, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(token_mask[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, dh)
