"""Pallas TPU kernel: fused ACT/RESTORE/PRE row-cycle transient engine.

The phased engine (`rc_transient.rc_multistep_pallas` called three times from
`core.transient`) materializes a full (T, B, N) waveform per phase in HBM and
then scans it on the host side for the threshold crossings (90% signal
development, 95% restore, 5 mV equalization).  For the DSE — thousands of
(tech x scheme x layers) design points — those traces are pure waste: the
sweep only consumes O(B) event times and end-state voltages.

This kernel runs the *whole* row cycle in one `pallas_call`:

  - each design point carries its own phase state machine
    (0=ACT, 1=RESTORE, 2=PRE, 3=DONE) and a step-in-phase counter, so
    points cross thresholds and switch phases independently;
  - the WL ramp is evaluated analytically from the per-point WL tau
    (no (T,) ramp table, no gather);
  - crossings are detected in-VMEM right after each implicit-Euler step;
  - a `while_loop` exits as soon as every point in the block is DONE,
    so the typical step count is the sum of the *actual* phase durations,
    not the sum of the worst-case phase windows;
  - HBM traffic is one read of the netlist and one write of the O(B)
    events — independent of the number of time steps.

Phase semantics replicate `core.transient.simulate_row_cycle` (the phased
reference) step-for-step, so event times agree to within one dt.

Grid:      (ceil(B / B_BLK),)  — batch is the only blocked axis.
Outputs:   events (B, 4) = [t_dev_ns, dv_sense_v, t_restore_dur_ns,
           t_pre_ns] and v_end (B, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _thomas_small

DEFAULT_B_BLK = 128

# params (B, 6) column layout
PAR_TAU_WL = 0      # WL driver RC time constant [ns]
PAR_THR_REL = 1     # ACT threshold: v[0] - vpre >= thr_rel  [V]
PAR_VDD = 2         # restore rail (SA drives sense node here) [V]
PAR_VPRE = 3        # precharge / equalize target [V]
PAR_ACTIVE = 4      # 1.0 = live design point, 0.0 = padding (starts DONE)
PAR_ROLE = 5        # 0 = standalone fixed timing, 1 = replica bitline
                    # (fires row+1's SA enable, then DONE), 2 = main row
                    # closed by the replica at row-1.  A legacy (B, 5)
                    # params array is accepted: role defaults to 0.
N_PARAMS = 6

# events (B, 4) column layout
EVT_T_DEV = 0       # ACT: time to 90% signal development [ns]
EVT_DV_SENSE = 1    # developed signal at SA enable [V]
EVT_T_RES = 2       # RESTORE: duration to 95% VDD in the cell [ns]
EVT_T_PRE = 3       # PRE: duration to 5 mV equalization [ns]
N_EVENTS = 4

RESTORE_FRAC = 0.95     # cell restored when v_cell >= RESTORE_FRAC * VDD
EQUALIZE_TOL_V = 5e-3   # BL equalized when max |v - vpre| <= 5 mV

# PAR_ROLE values (float-coded in the params array)
ROLE_STANDALONE = 0.0
ROLE_REPLICA = 1.0
ROLE_MAIN = 2.0


def _row_cycle_kernel(c_ref, g_ref, gcr_ref, gcp_ref, v0_ref, par_ref,
                      evt_ref, vend_ref, *, n_act: int, n_res: int,
                      n_pre: int, dt: float):
    """One batch-block: phase state machine until every point is DONE."""
    c = c_ref[...]                 # (B_blk, N)
    g_br = g_ref[...]              # (B_blk, N-1)
    gc_res = gcr_ref[...]          # (B_blk, N)
    gc_pre = gcp_ref[...]          # (B_blk, N)
    tau = jnp.maximum(par_ref[..., PAR_TAU_WL], 1e-3)
    thr_rel = par_ref[..., PAR_THR_REL]
    vdd = par_ref[..., PAR_VDD]
    vpre = par_ref[..., PAR_VPRE]
    active = par_ref[..., PAR_ACTIVE] > 0.5
    role = (par_ref[..., PAR_ROLE] if par_ref.shape[-1] > PAR_ROLE
            else jnp.zeros_like(thr_rel))   # static: role column presence
    is_rep = jnp.abs(role - 1.0) < 0.5
    is_main = role > 1.5
    b, n = c.shape
    cdt = c / dt * 1e-3            # fF/ns = uS -> mS (match G in 1/kOhm)
    t_total = n_act + n_res + n_pre
    n_phase = jnp.stack([
        jnp.full((b,), n_act, jnp.int32),
        jnp.full((b,), n_res, jnp.int32),
        jnp.full((b,), n_pre, jnp.int32),
    ])

    def cond(state):
        t, phase, _, _, _ = state
        return jnp.logical_and(t < t_total, jnp.any(phase < 3))

    def body(state):
        t, phase, tin, v, evt = state
        in_act = phase == 0
        in_res = phase == 1
        in_pre = phase == 2
        done = phase >= 3

        # WL ramp, analytic (matches transient.wl_ramp): x = 1 - e^{-t/tau}
        t_ns = (tin.astype(jnp.float32) + 1.0) * dt
        e = jnp.exp(-t_ns / tau)
        s = jnp.where(in_act, 1.0 - e,
                      jnp.where(in_res, 1.0, jnp.where(in_pre, e, 0.0)))

        # per-phase clamp network (ACT has none)
        gc = jnp.where(in_res[:, None], gc_res,
                       jnp.where(in_pre[:, None], gc_pre, 0.0))
        gcv = jnp.where(in_res[:, None], gc_res * vdd[:, None],
                        jnp.where(in_pre[:, None],
                                  gc_pre * vpre[:, None], 0.0))

        # tridiagonal assembly: A = C/dt + G(s); access branch scaled by s
        g_last = g_br[:, n - 2] * s
        g = jnp.concatenate([g_br[:, : n - 2], g_last[:, None]], axis=1)
        zeros = jnp.zeros_like(c[:, :1])
        g_lo = jnp.concatenate([zeros, g], axis=1)
        g_hi = jnp.concatenate([g, zeros], axis=1)
        diag = cdt + g_lo + g_hi + gc
        dl = jnp.concatenate([zeros, -g], axis=1)
        du = jnp.concatenate([-g, zeros], axis=1)
        rhs = cdt * v + gcv
        v_sol = _thomas_small(dl, diag, du, rhs)
        v_next = jnp.where(done[:, None], v, v_sol)

        # threshold crossings on the fresh state.  A main row's ACT
        # crossing is the crossing of the replica at row-1 ([replica,
        # main] pairs run ACT in lockstep, so the shift is exact).
        cross_own = v_next[:, 0] - vpre >= thr_rel
        cross_prev = jnp.concatenate([cross_own[-1:], cross_own[:-1]])
        cross = jnp.stack([
            jnp.where(is_main, cross_prev, cross_own),
            v_next[:, n - 1] >= RESTORE_FRAC * vdd,
            jnp.max(jnp.abs(v_next[:, : n - 1] - vpre[:, None]),
                    axis=-1) <= EQUALIZE_TOL_V,
        ])

        tin1 = tin + 1
        phase_c = jnp.clip(phase, 0, 2)
        crossed = jnp.take_along_axis(cross, phase_c[None, :], axis=0)[0]
        cap = jnp.take_along_axis(n_phase, phase_c[None, :], axis=0)[0]
        advance = jnp.logical_and(~done,
                                  jnp.logical_or(crossed, tin1 >= cap))
        # first-crossing time: (idx+1)*dt, or NaN if the phase timed out
        t_evt = jnp.where(crossed, tin1.astype(jnp.float32) * dt,
                          jnp.float32(jnp.nan))

        rec = lambda ph: jnp.logical_and(advance, phase == ph)
        evt = evt.at[:, EVT_T_DEV].set(
            jnp.where(rec(0), t_evt, evt[:, EVT_T_DEV]))
        evt = evt.at[:, EVT_DV_SENSE].set(
            jnp.where(rec(0), v_next[:, 0] - vpre, evt[:, EVT_DV_SENSE]))
        evt = evt.at[:, EVT_T_RES].set(
            jnp.where(rec(1), t_evt, evt[:, EVT_T_RES]))
        evt = evt.at[:, EVT_T_PRE].set(
            jnp.where(rec(2), t_evt, evt[:, EVT_T_PRE]))

        # replica rows are ACT-only: they jump straight to DONE
        phase_inc = jnp.where(is_rep, 3, 1)
        phase = jnp.where(advance, phase + phase_inc, phase)
        tin = jnp.where(advance, 0, jnp.where(done, tin, tin1))
        return t + 1, phase, tin, v_next, evt

    phase0 = jnp.where(active, 0, 3).astype(jnp.int32)
    state = (jnp.int32(0), phase0, jnp.zeros((b,), jnp.int32),
             v0_ref[...], jnp.zeros((b, N_EVENTS), jnp.float32))
    _, _, _, v_fin, evt_fin = jax.lax.while_loop(cond, body, state)
    evt_ref[...] = evt_fin
    vend_ref[...] = v_fin


def row_cycle_fused_pallas(c: jnp.ndarray, g_branch: jnp.ndarray,
                           gc_res: jnp.ndarray, gc_pre: jnp.ndarray,
                           v0: jnp.ndarray, params: jnp.ndarray,
                           dt: float, n_act: int, n_res: int, n_pre: int,
                           *, b_blk: int = DEFAULT_B_BLK,
                           interpret: bool = True):
    """Pallas-backed equivalent of `ref.row_cycle_fused_ref`.

    Returns (events, v_end) with shapes ((B, 4), (B, N)).
    """
    b, n = c.shape
    b_blk = min(b_blk, b)
    n_blocks = pl.cdiv(b, b_blk)

    pad = n_blocks * b_blk - b
    if pad:
        padf = lambda x, v: jnp.pad(x, ((0, pad), (0, 0)), constant_values=v)
        c, g_branch, gc_res, gc_pre, v0 = (
            padf(x, 1.0) for x in (c, g_branch, gc_res, gc_pre, v0))
        # padded rows get active=0 -> they start DONE and never step
        params = padf(params, 0.0)

    kernel = functools.partial(_row_cycle_kernel, n_act=n_act, n_res=n_res,
                               n_pre=n_pre, dt=dt)
    bspec = lambda w: pl.BlockSpec((b_blk, w), lambda i: (i, 0))
    events, v_end = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[bspec(n), bspec(n - 1), bspec(n), bspec(n), bspec(n),
                  bspec(params.shape[1])],  # (B, 5) legacy or (B, 6)
        out_specs=[bspec(N_EVENTS), bspec(n)],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks * b_blk, N_EVENTS), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks * b_blk, n), c.dtype),
        ],
        interpret=interpret,
    )(c, g_branch, gc_res, gc_pre, v0, params)
    return events[:b], v_end[:b]
