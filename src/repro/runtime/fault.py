"""Fault-tolerance runtime: heartbeats, failure injection, straggler
mitigation, elastic re-meshing.

This container is a single host, so the *cluster* is simulated (per the
mandate) while the *mechanisms* are real and unit-tested:

  - HeartbeatMonitor: workers report liveness; detection by timeout.
  - FailureInjector: deterministic fault schedule for tests/examples.
  - StragglerPolicy: bounded-wait gradient buckets — proceed with the
    fastest (1 - drop_fraction) workers, rescaling the gradient mean
    (the classic backup-worker trick).
  - ElasticPlan: given surviving device count, re-derive the largest valid
    (data, model) mesh and signal a checkpoint-restore onto it.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


class HeartbeatMonitor:
    def __init__(self, workers: list[str], timeout_s: float = 10.0,
                 clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen = {w: clock() for w in workers}

    def beat(self, worker: str, at: float | None = None):
        self.last_seen[worker] = self.clock() if at is None else at

    def dead(self, now: float | None = None) -> list[str]:
        now = self.clock() if now is None else now
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive(self, now: float | None = None) -> list[str]:
        dead = set(self.dead(now))
        return [w for w in self.last_seen if w not in dead]


@dataclass
class FailureInjector:
    """Deterministic schedule: {step: kind} with kind in
    {"crash", "nan", "slow:<worker>", "drop:<worker>"}.

    `FaultTolerantRunner` interprets "crash"/"nan" itself; other kinds
    are consumer-defined — the elastic sweep driver (`launch.elastic`)
    keys its schedule by slab index and reads "drop:<host>" as that
    simulated host ceasing to heartbeat after the slab's dispatch."""
    schedule: dict[int, str] = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def check(self, step: int) -> str | None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            return self.schedule[step]
        return None


@dataclass
class StragglerPolicy:
    """Bounded-wait gradient buckets.

    Given per-worker step durations, wait only until `quorum_fraction` of
    workers have reported or `deadline_factor` x median has elapsed; late
    gradients are dropped and the mean rescaled by n/actual.
    """
    quorum_fraction: float = 0.9375   # 15/16: tolerate 1 straggler per 16
    deadline_factor: float = 2.0

    def admit(self, durations: dict[str, float]) -> tuple[list[str], float]:
        if not durations:
            return [], 0.0
        items = sorted(durations.items(), key=lambda kv: kv[1])
        n = len(items)
        quorum = max(1, math.ceil(self.quorum_fraction * n))
        med = items[n // 2][1]
        deadline = self.deadline_factor * med
        admitted = [w for i, (w, t) in enumerate(items)
                    if i < quorum or t <= deadline]
        rescale = n / len(admitted)
        return admitted, rescale


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def replan_mesh(surviving_devices: int, model_parallel: int = 16,
                min_data: int = 1) -> ElasticPlan:
    """Largest (data, model) grid fitting the survivors: model parallelism
    is kept (param shards must stay complete); data shrinks to the largest
    whole multiple."""
    if surviving_devices < model_parallel:
        # degrade model parallelism to the largest power-of-two that fits
        mp = 1 << (surviving_devices.bit_length() - 1)
        return ElasticPlan(data=surviving_devices // mp, model=mp)
    data = max(min_data, surviving_devices // model_parallel)
    return ElasticPlan(data=data, model=model_parallel)


class FaultTolerantRunner:
    """Drives a step function with checkpoint/restart + failure simulation.

    step_fn(state, step) -> (state, metrics); save_fn(step, state);
    restore_fn() -> (state, step).  Used by train/loop.py and tested with
    injected crash/nan faults.
    """

    def __init__(self, step_fn, save_fn, restore_fn, injector=None,
                 ckpt_every: int = 50):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.injector = injector or FailureInjector()
        self.ckpt_every = ckpt_every
        self.restarts = 0

    def run(self, state, n_steps: int, start_step: int = 0):
        step = start_step
        metrics_log = []
        while step < n_steps:
            fault = self.injector.check(step)
            try:
                if fault == "crash":
                    raise RuntimeError(f"injected crash at step {step}")
                state, metrics = self.step_fn(state, step)
                if fault == "nan":
                    metrics = dict(metrics, loss=float("nan"))
                loss = metrics.get("loss")
                if loss is not None and not math.isfinite(float(loss)):
                    raise FloatingPointError(f"non-finite loss at {step}")
                metrics_log.append(dict(metrics, step=step))
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except (RuntimeError, FloatingPointError):
                self.restarts += 1
                state, step = self.restore_fn()
        return state, metrics_log
