"""Qwen1.5-110B: dense GQA (kv=8) with QKV bias, wide FFN."""

from .base import ArchConfig

QWEN15_110B = ArchConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-110B (family: Qwen/Qwen1.5-0.5B); hf",
)

CONFIG = QWEN15_110B
