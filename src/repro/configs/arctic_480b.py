"""Snowflake Arctic 480B: 128-expert top-2 MoE + dense residual MLP."""

from .base import ArchConfig

ARCTIC_480B = ArchConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_dense_residual=True,
    optimizer="adamw8bit",          # int8 moments: fits HBM at 256 chips
    source="hf:Snowflake/snowflake-arctic-base; hf",
)

CONFIG = ARCTIC_480B
