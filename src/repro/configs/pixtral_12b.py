"""Pixtral-12B: mistral-nemo-style decoder; ViT frontend is a stub."""

from .base import ArchConfig

PIXTRAL_12B = ArchConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
    head_dim=128, rope_theta=1e6, n_vision_tokens=256,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

CONFIG = PIXTRAL_12B
