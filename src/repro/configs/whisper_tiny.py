"""Whisper-tiny: encoder-decoder; conv audio frontend is a stub."""

from .base import ArchConfig

WHISPER_TINY = ArchConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
    is_encdec=True, n_enc_layers=4, act="gelu", norm="layernorm",
    rope_theta=0.0,  # sinusoidal absolute positions, no RoPE
    source="arXiv:2212.04356; unverified",
)

CONFIG = WHISPER_TINY
