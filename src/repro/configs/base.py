"""Architecture config schema + shape cells + abstract input specs.

Every assigned architecture is a frozen `ArchConfig`; the dry-run obtains
pure ShapeDtypeStruct stand-ins from `input_specs(cfg, shape_cell)` so that
no device memory is ever allocated for the full-size configs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shape cells (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------

SHAPE_CELLS = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}

SMOKE_SHAPE = dict(seq_len=128, global_batch=2, kind="train")


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln (olmo)
    act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    moe_dense_residual: bool = False    # arctic: dense MLP in parallel
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    conv_kernel: int = 4
    # --- hybrid (zamba2): one shared attn+mlp block every N ssm layers ---
    shared_attn_every: int = 0
    # --- enc-dec (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    # --- vlm (pixtral): stub ViT embeddings prepended to the text stream ---
    n_vision_tokens: int = 0
    # --- numerics / execution ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 512         # query-block size for chunked attention
    optimizer: str = "adamw"      # adamw | adamw8bit (int8 moments)
    remat: bool = True
    # selector+strap gated decode (the paper's technique in the HLO):
    strap_decode: bool = False
    decode_strap_tokens: int = 2048
    decode_top_straps: int = 8
    # perf levers (see launch/optlevels.py + EXPERIMENTS.md §Perf):
    shard_acts: bool = False      # explicit activation sharding constraints
    seq_parallel: bool = False    # Megatron-style: residual stream seq-sharded
    ssm_split_proj: bool = False  # shard-aligned per-stream SSM projections
    moe_ep: bool = False          # shard_map expert-parallel MoE dispatch
    vocab_round: int = 256        # pad vocab to multiple (16-way TP of embed)
    # --- provenance ---
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_round)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (SSM / hybrid only)."""
        return self.family in ("ssm", "hybrid")

    def runnable_cells(self) -> list[str]:
        cells = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            cells.append("long_500k")
        return cells

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp = 3 * d * f if self.act == "swiglu" else 2 * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per_layer = self._ssm_layer_params()
            return self.n_layers * per_layer + emb + d
        if self.family == "hybrid":
            per_ssm = self._ssm_layer_params()
            shared = attn + mlp + 2 * d
            return self.n_layers * per_ssm + shared + emb + d
        if self.n_experts:
            expert_mlp = self.n_experts * 3 * d * f + d * self.n_experts
            dense_res = 3 * d * f if self.moe_dense_residual else 0
            return self.n_layers * (attn + expert_mlp + dense_res + 2 * d) + emb + d
        layers = self.n_layers * (attn + mlp + 2 * d)
        if self.is_encdec:
            layers += self.n_enc_layers * (attn + mlp + 2 * d)
            layers += self.n_layers * (attn + d)       # cross-attention
        return layers + emb + d

    def active_param_count(self) -> int:
        """Active (per-token) params: MoE counts top_k experts only."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return total - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration: same family/topology, tiny sizes."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            vocab_round=64,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            capacity_factor=4.0,      # no capacity drops at smoke scale
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            attn_chunk=32,
            param_dtype="float32",
            compute_dtype="float32",
        )

    def _ssm_layer_params(self) -> int:
        d, di = self.d_model, self.d_inner
        ng, st = self.ssm_ngroups, self.ssm_state
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * ng * st + nh)
        conv = self.conv_kernel * (di + 2 * ng * st)
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * nh + di + d


# ---------------------------------------------------------------------------
# Abstract input specs per shape cell
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    Modality frontends are stubs: audio (whisper) supplies precomputed frame
    embeddings; vlm (pixtral) supplies precomputed patch embeddings.
    """
    spec = SHAPE_CELLS[cell] if cell in SHAPE_CELLS else SMOKE_SHAPE
    s, b, kind = spec["seq_len"], spec["global_batch"], spec["kind"]
    emb_dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    if cfg.is_encdec:
        # encoder frames : decoder tokens split the cell's seq budget
        s_enc, s_dec = s // 2, s // 2
        if kind == "train":
            return dict(enc_embeds=_sds((b, s_enc, cfg.d_model), emb_dt),
                        tokens=_sds((b, s_dec), jnp.int32),
                        targets=_sds((b, s_dec), jnp.int32))
        if kind == "prefill":
            return dict(enc_embeds=_sds((b, s_enc, cfg.d_model), emb_dt),
                        tokens=_sds((b, s_dec), jnp.int32))
        return dict(token=_sds((b, 1), jnp.int32),
                    pos=_sds((b,), jnp.int32))

    if cfg.n_vision_tokens and kind != "decode":
        nv = cfg.n_vision_tokens
        if kind == "train":
            return dict(vision_embeds=_sds((b, nv, cfg.d_model), emb_dt),
                        tokens=_sds((b, s - nv), jnp.int32),
                        targets=_sds((b, s - nv), jnp.int32))
        return dict(vision_embeds=_sds((b, nv, cfg.d_model), emb_dt),
                    tokens=_sds((b, s - nv), jnp.int32))

    if kind == "train":
        return dict(tokens=_sds((b, s), jnp.int32),
                    targets=_sds((b, s), jnp.int32))
    if kind == "prefill":
        return dict(tokens=_sds((b, s), jnp.int32))
    # decode: one new token against a cache of length s (cache specs are
    # provided separately by the model's cache_specs()).
    return dict(token=_sds((b, 1), jnp.int32), pos=_sds((b,), jnp.int32))


def cell_batch_seq(cell: str) -> tuple[int, int]:
    spec = SHAPE_CELLS[cell]
    return spec["global_batch"], spec["seq_len"]
