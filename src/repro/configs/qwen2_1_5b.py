"""Qwen2-1.5B: dense GQA (kv=2) with QKV bias, tied embeddings."""

from .base import ArchConfig

QWEN2_1_5B = ArchConfig(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)

CONFIG = QWEN2_1_5B
