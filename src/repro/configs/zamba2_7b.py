"""Zamba2-7B: Mamba2 backbone + shared attention block every 6 layers."""

from .base import ArchConfig

ZAMBA2_7B = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64,
    shared_attn_every=6,            # one shared attn+mlp block every 6 Mamba2
    tie_embeddings=True,
    source="arXiv:2411.15242; unverified",
)

CONFIG = ZAMBA2_7B
