"""DeepSeek-67B: llama-style dense GQA (kv=8)."""

from .base import ArchConfig

DEEPSEEK_67B = ArchConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab_size=102400,
    rope_theta=1e4, source="arXiv:2401.02954; hf",
)

CONFIG = DEEPSEEK_67B
