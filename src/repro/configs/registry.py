"""Aggregates the 10 assigned architecture configs (one module each)."""

from __future__ import annotations

from .base import ArchConfig
from .arctic_480b import ARCTIC_480B
from .deepseek_67b import DEEPSEEK_67B
from .mamba2_780m import MAMBA2_780M
from .olmo_1b import OLMO_1B
from .phi35_moe import PHI35_MOE
from .pixtral_12b import PIXTRAL_12B
from .qwen15_110b import QWEN15_110B
from .qwen2_1_5b import QWEN2_1_5B
from .whisper_tiny import WHISPER_TINY
from .zamba2_7b import ZAMBA2_7B

ARCHS: dict[str, ArchConfig] = {c.name: c for c in (
    ZAMBA2_7B, WHISPER_TINY, QWEN2_1_5B, DEEPSEEK_67B, OLMO_1B,
    QWEN15_110B, MAMBA2_780M, ARCTIC_480B, PHI35_MOE, PIXTRAL_12B,
)}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].reduced()
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)
