"""Mamba2-780M: attention-free SSD (state-space duality)."""

from .base import ArchConfig

MAMBA2_780M = ArchConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

CONFIG = MAMBA2_780M
