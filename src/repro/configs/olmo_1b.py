"""OLMo-1B: dense MHA with non-parametric LayerNorm."""

from .base import ArchConfig

OLMO_1B = ArchConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=50304,
    norm="nonparam_ln", tie_embeddings=True,
    source="arXiv:2402.00838; hf",
)

CONFIG = OLMO_1B
