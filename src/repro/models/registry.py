"""Family dispatch: one uniform model API over lm.py / encdec.py."""

from __future__ import annotations

import jax.numpy as jnp

from . import encdec, lm
from .common import axes_from_schema


def _mod(cfg):
    return encdec if cfg.is_encdec else lm


def schema(cfg):
    return encdec.encdec_schema(cfg) if cfg.is_encdec else lm.lm_schema(cfg)


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def param_axes(cfg):
    return _mod(cfg).param_axes(cfg)


def abstract_params(cfg):
    return _mod(cfg).abstract_params(cfg)


def forward_train(cfg, params, batch):
    return _mod(cfg).forward_train(cfg, params, batch)


def loss_fn(cfg, params, batch):
    return _mod(cfg).loss_fn(cfg, params, batch)


def prefill(cfg, params, batch):
    return _mod(cfg).prefill(cfg, params, batch)


def decode_step(cfg, params, cache, token, pos):
    return _mod(cfg).decode_step(cfg, params, cache, token, pos)


def cache_schema(cfg, batch: int, seq: int):
    if cfg.is_encdec:
        return encdec.cache_schema(cfg, batch, seq // 2)
    return lm.cache_schema(cfg, batch, seq)


def cache_axes(cfg, batch: int, seq: int):
    return axes_from_schema(cache_schema(cfg, batch, seq))


def _cache_dtype(cfg, key):
    # SSM states and strap key-sums are carried in fp32
    if "ssm" in key or key == "ksum":
        return jnp.float32
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def abstract_cache(cfg, batch: int, seq: int):
    import jax
    sch = cache_schema(cfg, batch, seq)
    return {k: jax.ShapeDtypeStruct(v.shape, _cache_dtype(cfg, k))
            for k, v in sch.items()}


def init_cache(cfg, batch: int, seq: int):
    sch = cache_schema(cfg, batch, seq)
    return {k: jnp.zeros(v.shape, _cache_dtype(cfg, k))
            for k, v in sch.items()}
