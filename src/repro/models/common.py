"""Shared model components: schema-driven params, norms, RoPE, embeddings.

Parameter trees and their logical sharding axes are derived from a single
*schema* (dict name -> ParamSpec), so the two trees can never drift apart.
Layer-stacked weights carry a leading "layers" axis and are consumed by
`jax.lax.scan` over layers.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis name per dim
    scale: float | str = "fan_in"  # gaussian std, or "fan_in", or "zeros"/"ones"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict  # nested dict name -> ParamSpec


def init_from_schema(schema: Schema, key: jax.Array, dtype) -> dict:
    leaves, treedef = jax.tree.flatten(schema,
                                       is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def init_one(spec: ParamSpec, k):
        if spec.scale == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.scale == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.scale == "fan_in":
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = fan_in ** -0.5
        else:
            std = float(spec.scale)
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [init_one(s, k) for s, k in zip(leaves, keys)])


def axes_from_schema(schema: Schema) -> dict:
    return jax.tree.map(lambda s: s.axes, schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_from_schema(schema: Schema, dtype) -> dict:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Activation sharding annotations (no-op without a registered mesh)
# ---------------------------------------------------------------------------

def constrain(cfg, x, logical, force: bool = False):
    """Pin an activation's sharding: `logical` names one of
    {"dp","model",None} per dim.  Indivisible dims degrade to None.
    Active only when cfg.shard_acts (or force=True) and a mesh is
    registered."""
    if not (getattr(cfg, "shard_acts", False) or force):
        return x
    from ..distributed import context as mesh_ctx
    sizes = mesh_ctx.axis_sizes()
    if not sizes:
        return x
    from jax.sharding import PartitionSpec as P
    entries = []
    for dim, a in zip(x.shape, logical):
        if a == "dp":
            chosen, prod = [], 1
            for m in ("pod", "data"):
                if m in sizes and dim % (prod * sizes[m]) == 0:
                    chosen.append(m)
                    prod *= sizes[m]
            entries.append(tuple(chosen) if chosen else None)
        elif a == "model" and "model" in sizes and dim % sizes["model"] == 0:
            entries.append("model")
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(x, P(*entries))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def nonparam_ln(x, eps=1e-5):
    """OLMo's non-parametric LayerNorm (no scale / bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(cfg, x, layer_params, prefix: str):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, layer_params[prefix + "_w"])
    if cfg.norm == "layernorm":
        return layernorm(x, layer_params[prefix + "_w"], layer_params[prefix + "_b"])
    return nonparam_ln(x)


def norm_schema(cfg, d: int) -> Schema:
    if cfg.norm == "rmsnorm":
        return {"_w": ParamSpec((d,), ("dmodel",), "ones")}
    if cfg.norm == "layernorm":
        return {"_w": ParamSpec((d,), ("dmodel",), "ones"),
                "_b": ParamSpec((d,), ("dmodel",), "zeros")}
    return {}


def add_norm(schema: Schema, cfg, name: str, d: int, layers: int | None = None):
    for suffix, spec in norm_schema(cfg, d).items():
        if layers is not None:
            spec = ParamSpec((layers,) + spec.shape, ("layers",) + spec.axes,
                             spec.scale)
        schema[name + suffix] = spec


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos_emb(seq: int, d: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    inv = 1e4 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_schema(cfg) -> Schema:
    v, d = cfg.padded_vocab, cfg.d_model
    s: Schema = {"embed": ParamSpec((v, d), ("vocab", "dmodel"), 0.02)}
    add_norm(s, cfg, "final", d)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((v, d), ("vocab", "dmodel"), "fan_in")
    return s


def embed_tokens(params, tokens, dtype):
    return jnp.take(params["embed"], tokens, axis=0).astype(dtype)


def lm_logits(cfg, params, h):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                      table.astype(jnp.float32))


def cross_entropy(logits, targets, vocab_size: int):
    """Mean CE over all tokens; ignores padded vocab tail via clipping."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
