"""Dense MLP blocks: SwiGLU (llama-family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, Schema


def mlp_schema(cfg, layers: int | None = None, prefix: str = "",
               d_ff: int | None = None) -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = (layers,) if layers is not None else ()
    A = ("layers",) if layers is not None else ()
    if cfg.act == "swiglu":
        return {
            prefix + "w_gate": ParamSpec(L + (d, f), A + ("dmodel", "ff"), "fan_in"),
            prefix + "w_up": ParamSpec(L + (d, f), A + ("dmodel", "ff"), "fan_in"),
            prefix + "w_down": ParamSpec(L + (f, d), A + ("ff", "dmodel"), "fan_in"),
        }
    return {
        prefix + "w_in": ParamSpec(L + (d, f), A + ("dmodel", "ff"), "fan_in"),
        prefix + "b_in": ParamSpec(L + (f,), A + ("ff",), "zeros"),
        prefix + "w_out": ParamSpec(L + (f, d), A + ("ff", "dmodel"), "fan_in"),
        prefix + "b_out": ParamSpec(L + (d,), A + ("dmodel",), "zeros"),
    }


def mlp_apply(cfg, p, x, prefix: str = ""):
    if cfg.act == "swiglu":
        g = x @ p[prefix + "w_gate"]
        u = x @ p[prefix + "w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return h @ p[prefix + "w_down"]
    h = x @ p[prefix + "w_in"] + p[prefix + "b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ p[prefix + "w_out"] + p[prefix + "b_out"].astype(x.dtype)
