"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch strategy (the SPMD-friendly production path, not the one-hot
einsum): token->expert assignments are sorted, each token takes a slot
`(expert, position_in_expert)` capped by capacity; slot->token indices feed
a gather, experts run as a single batched einsum over the expert dim (which
is expert-parallel on the `model` mesh axis), and results scatter-add back
weighted by the router gate.  Tokens beyond capacity are dropped (standard
capacity-factor semantics); the router uses an auxiliary load-balancing
loss (Switch-style) to keep drops rare.

Arctic additionally runs a *dense residual* MLP in parallel with the MoE
(its published topology).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, Schema
from .mlp import mlp_apply, mlp_schema


def moe_schema(cfg, layers: int | None = None) -> Schema:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = (layers,) if layers is not None else ()
    A = ("layers",) if layers is not None else ()
    s: Schema = {
        "router": ParamSpec(L + (d, e), A + ("dmodel", "experts"), "fan_in"),
        "we_gate": ParamSpec(L + (e, d, f), A + ("experts", "dmodel", "ff"), "fan_in"),
        "we_up": ParamSpec(L + (e, d, f), A + ("experts", "dmodel", "ff"), "fan_in"),
        "we_down": ParamSpec(L + (e, f, d), A + ("experts", "ff", "dmodel"), "fan_in"),
    }
    if cfg.moe_dense_residual:
        s.update(mlp_schema(cfg, layers, prefix="res_"))
    return s


def _capacity(cfg, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cap, cfg.top_k * 4)


def moe_apply(cfg, p, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = _capacity(cfg, t)
    xf = x.reshape(t, d)

    # --- routing (fp32) -------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style aux loss: fraction-of-tokens x mean router prob per expert
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # --- sort-based slotting --------------------------------------------
    flat_expert = expert_idx.reshape(-1)                         # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_expert)                             # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position of each slot within its expert
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap

    # slot table: (E*cap,) -> source token (or T = dummy)
    slot = se * cap + jnp.where(keep, pos_in_e, 0)
    slot_token = jnp.full((e * cap,), t, jnp.int32)
    slot_token = slot_token.at[jnp.where(keep, slot, e * cap - 1)].set(
        jnp.where(keep, st, t).astype(jnp.int32), mode="drop")
    slot_gate = jnp.zeros((e * cap,), jnp.float32).at[
        jnp.where(keep, slot, 0)].set(jnp.where(keep, sg, 0.0), mode="drop")

    # --- gather -> expert GEMMs -> scatter-add ---------------------------
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = jnp.take(xpad, slot_token, axis=0).reshape(e, cap, d)
    gate_h = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"]).reshape(e * cap, d)
    ye = ye * slot_gate[:, None].astype(ye.dtype)

    y = jnp.zeros((t + 1, d), x.dtype).at[slot_token].add(ye)[:t]
    y = y.reshape(b, s, d)

    if cfg.moe_dense_residual:
        y = y + mlp_apply(cfg, p, x, prefix="res_")
    return y, aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map): the beyond-baseline §Perf path
# ---------------------------------------------------------------------------

def moe_apply_ep(cfg, p, x):
    """shard_map expert-parallel MoE.

    The baseline `moe_apply` routes over GLOBAL tokens; under GSPMD the
    slot gather materializes an all-gather of the full token activations
    per layer (~tokens x d_model bytes, the dominant collective of the MoE
    train cells).  This path keeps tokens device-local: local top-k ->
    local capacity slots -> ONE all-to-all over the `model` axis moving
    only the dispatched slots (tokens_loc x top_k x d x cf bytes), expert
    GEMMs against the local expert shard, reverse all-to-all, local
    combine.  Capacity is enforced per (device, expert) — the standard EP
    semantics (local drops instead of global).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..distributed import context as mesh_ctx

    mesh = mesh_ctx.get_mesh()
    sizes = mesh_ctx.axis_sizes()
    e = cfg.n_experts
    ep = sizes.get("model", 1)
    if mesh is None or e % max(ep, 1) or ep <= 1:
        return moe_apply(cfg, p, x)         # no mesh / indivisible: fallback

    dp = mesh_ctx.dp_axes()
    b, s, d = x.shape
    k = cfg.top_k
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    # tokens must partition across BOTH dp (batch) and model (sequence):
    # with x replicated over `model`, every EP rank would redundantly
    # dispatch the same slots (measured: 16x compute, see §Perf).
    if b % max(dp_size, 1) or s % ep:
        return moe_apply(cfg, p, x)

    x_spec = P(dp if dp else None, "model", None)
    router_spec = P(None, None)
    we_spec = P("model", None, None)        # experts sharded over `model`
    wd_spec = P("model", None, None)

    def local(xl, router, wg, wu, wd, res_w=None):
        bl, sl, _ = xl.shape
        t = bl * sl
        cap = max(int(t * k / e * cfg.capacity_factor), 4 * k)
        xf = xl.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e,
                                     dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp) if dp else aux

        flat_expert = expert_idx.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_expert)
        se, st_, sg = flat_expert[order], flat_token[order], flat_gate[order]
        counts = jnp.bincount(se, length=e)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(t * k) - starts[se]
        keep = pos_in_e < cap
        slot = se * cap + jnp.where(keep, pos_in_e, 0)
        slot_token = jnp.full((e * cap,), t, jnp.int32).at[
            jnp.where(keep, slot, e * cap - 1)].set(
                jnp.where(keep, st_, t).astype(jnp.int32), mode="drop")
        slot_gate = jnp.zeros((e * cap,), jnp.float32).at[
            jnp.where(keep, slot, 0)].set(jnp.where(keep, sg, 0.0),
                                          mode="drop")

        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        xe = jnp.take(xpad, slot_token, axis=0).reshape(e, cap, d)
        # ---- all-to-all: slots travel to their expert's shard ----------
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)            # (e/ep, cap*ep, d)
        gate_h = jnp.einsum("ecd,edf->ecf", xe, wg)
        up_h = jnp.einsum("ecd,edf->ecf", xe, wu)
        hh = jax.nn.silu(gate_h.astype(jnp.float32)).astype(xl.dtype) * up_h
        ye = jnp.einsum("ecf,efd->ecd", hh, wd)
        # ---- reverse all-to-all ----------------------------------------
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)            # (e, cap, d)
        ye = ye.reshape(e * cap, d) * slot_gate[:, None].astype(ye.dtype)
        y = jnp.zeros((t + 1, d), xl.dtype).at[slot_token].add(ye)[:t]
        y = y.reshape(bl, sl, d)
        if res_w is not None:
            rg, ru, rd = res_w
            g = xl @ rg
            u = xl @ ru
            hres = jax.nn.silu(g.astype(jnp.float32)).astype(xl.dtype) * u
            y = y + hres @ rd
        return y, aux

    args = [x, p["router"], p["we_gate"], p["we_up"], p["we_down"]]
    in_specs = [x_spec, router_spec, we_spec, we_spec, wd_spec]
    if cfg.moe_dense_residual:
        res = (p["res_w_gate"], p["res_w_up"], p["res_w_down"])
        fn = lambda xl, r, wg, wu, wd, rg, ru, rd: local(
            xl, r, wg, wu, wd, (rg, ru, rd))
        args += list(res)
        in_specs += [P(None, "model"), P(None, "model"), P("model", None)]
    else:
        fn = lambda xl, r, wg, wu, wd: local(xl, r, wg, wu, wd)

    mapped = shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=(x_spec, P()), check_rep=False)
    return mapped(*args)
