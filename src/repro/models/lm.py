"""Decoder-only LM covering the dense / MoE / VLM / SSM / hybrid families.

Layers are weight-stacked and executed with `jax.lax.scan` (small HLO, fast
compile, remat-friendly).  The hybrid (Zamba2) family runs an outer scan
over groups of `shared_attn_every` Mamba2 layers followed by ONE weight-
shared attention+MLP block (its defining topology), plus trailing Mamba2
layers.

Public entry points (all pure functions of (cfg, params, ...)):
  init_params / param_axes / abstract_params
  forward_train   -> (logits, aux_loss)
  loss_fn         -> scalar loss
  prefill         -> (last_logits, cache)
  decode_step     -> (logits, new_cache)
  cache_schema    -> Schema of the decode cache (shapes + logical axes)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attn_schema, causal_attention, decode_attention,
                        decode_attention_gated)
from .common import (ParamSpec, Schema, abstract_from_schema, add_norm,
                     apply_norm, axes_from_schema, constrain, cross_entropy,
                     embed_schema, embed_tokens, init_from_schema, lm_logits)
from .mlp import mlp_apply, mlp_schema
from .moe import moe_apply, moe_apply_ep, moe_schema
from .ssm import ssm_apply, ssm_decode_step, ssm_schema


# ---------------------------------------------------------------------------
# Schema assembly
# ---------------------------------------------------------------------------

def _tf_layer_schema(cfg, layers: int) -> Schema:
    s: Schema = {}
    add_norm(s, cfg, "ln1", cfg.d_model, layers)
    s.update(attn_schema(cfg, layers))
    add_norm(s, cfg, "ln2", cfg.d_model, layers)
    if cfg.n_experts:
        s.update(moe_schema(cfg, layers))
    else:
        s.update(mlp_schema(cfg, layers))
    return s


def _ssm_layer_schema(cfg, layers: int) -> Schema:
    s: Schema = {}
    add_norm(s, cfg, "ln1", cfg.d_model, layers)
    s.update(ssm_schema(cfg, layers))
    return s


def _shared_block_schema(cfg) -> Schema:
    """Zamba2's weight-shared attention+MLP block (no layer stacking)."""
    s: Schema = {}
    add_norm(s, cfg, "ln1", cfg.d_model)
    s.update(attn_schema(cfg))
    add_norm(s, cfg, "ln2", cfg.d_model)
    s.update(mlp_schema(cfg))
    return s


def _hybrid_split(cfg) -> tuple[int, int, int]:
    """(groups, per_group, trailing) for the hybrid family."""
    per = cfg.shared_attn_every
    groups = cfg.n_layers // per
    trailing = cfg.n_layers - groups * per
    return groups, per, trailing


def lm_schema(cfg) -> Schema:
    s = embed_schema(cfg)
    if cfg.family == "ssm":
        s["layers"] = _ssm_layer_schema(cfg, cfg.n_layers)
    elif cfg.family == "hybrid":
        groups, per, trailing = _hybrid_split(cfg)
        grouped = _ssm_layer_schema(cfg, groups * per)
        # reshape the stacked specs to (groups, per, ...)
        s["layers"] = {
            k: ParamSpec((groups, per) + v.shape[1:],
                         ("layer_groups",) + v.axes, v.scale)
            for k, v in grouped.items()}
        if trailing:
            s["trailing"] = _ssm_layer_schema(cfg, trailing)
        s["shared"] = _shared_block_schema(cfg)
    else:
        s["layers"] = _tf_layer_schema(cfg, cfg.n_layers)
    return s


def init_params(cfg, key):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return init_from_schema(lm_schema(cfg), key, dtype)


def param_axes(cfg):
    return axes_from_schema(lm_schema(cfg))


def abstract_params(cfg):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return abstract_from_schema(lm_schema(cfg), dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _tf_block(cfg, lp, h, positions, collect_cache: bool):
    # seq-parallel: the residual stream (and thus LN + projections + saved
    # remat activations) lives seq-sharded over `model`; attention gathers
    # seq / scatters heads internally (Megatron-SP pattern, XLA-inserted).
    h = constrain(cfg, h, ("dp", "model" if cfg.seq_parallel else None, None))
    a_in = apply_norm(cfg, h, lp, "ln1")
    attn_out, (k, v) = causal_attention(cfg, lp, a_in, positions)
    h = h + attn_out
    m_in = apply_norm(cfg, h, lp, "ln2")
    if cfg.n_experts:
        moe_fn = moe_apply_ep if cfg.moe_ep else moe_apply
        mo, aux = moe_fn(cfg, lp, m_in)
    else:
        mo, aux = mlp_apply(cfg, lp, m_in), jnp.zeros((), jnp.float32)
    h = h + mo
    cache = (k, v) if collect_cache else None
    return h, aux, cache


def _ssm_block(cfg, lp, h, h0=None, conv0=None, collect_state: bool = False):
    h = constrain(cfg, h, ("dp", "model" if cfg.seq_parallel else None, None))
    a_in = apply_norm(cfg, h, lp, "ln1")
    if collect_state:
        out, hf, convf = ssm_apply(cfg, lp, a_in, h0, conv0, return_state=True)
        return h + out, (hf, convf)
    return h + ssm_apply(cfg, lp, a_in), None


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch, dtype):
    """Token (+ modality-stub) embedding -> (B, S, D), positions (1, S)."""
    tok_emb = embed_tokens(params, batch["tokens"], dtype)
    h = (jnp.concatenate([batch["vision_embeds"].astype(dtype), tok_emb],
                         axis=1)
         if cfg.n_vision_tokens and "vision_embeds" in batch else tok_emb)
    positions = jnp.arange(h.shape[1])[None, :]
    return h, positions


def _run_layers(cfg, params, h, positions, collect_cache: bool = False):
    """Scan the layer stack; returns (h, aux_total, cache_stack_or_None)."""
    if cfg.family == "ssm":
        def body(carry, lp):
            hh, _ = _ssm_block(cfg, lp, carry)
            return hh, None
        h, _ = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
        return h, jnp.zeros((), jnp.float32), None

    if cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(carry, glp):
            hh = carry
            def inner(c, lp):
                cc, _ = _ssm_block(cfg, lp, c)
                return cc, None
            hh, _ = jax.lax.scan(inner, hh, glp)
            # weight-shared attention + MLP block
            a_in = apply_norm(cfg, hh, shared, "ln1")
            attn_out, (k, v) = causal_attention(cfg, shared, a_in, positions)
            hh = hh + attn_out
            m_in = apply_norm(cfg, hh, shared, "ln2")
            hh = hh + mlp_apply(cfg, shared, m_in)
            return hh, (k, v) if collect_cache else None

        h, kv = jax.lax.scan(_maybe_remat(cfg, group_body), h, params["layers"])
        if "trailing" in params:
            def tbody(c, lp):
                cc, _ = _ssm_block(cfg, lp, c)
                return cc, None
            h, _ = jax.lax.scan(tbody, h, params["trailing"])
        return h, jnp.zeros((), jnp.float32), kv

    def body(carry, lp):
        hh, aux, cache = _tf_block(cfg, lp, carry, positions, collect_cache)
        return hh, (aux, cache) if collect_cache else aux

    h, ys = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
    if collect_cache:
        auxs, caches = ys
        return h, jnp.sum(auxs), caches
    return h, jnp.sum(ys), None


def forward_train(cfg, params, batch):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    h, positions = _embed_inputs(cfg, params, batch, dtype)
    h, aux, _ = _run_layers(cfg, params, h, positions)
    h = apply_norm(cfg, h, params, "final")
    return lm_logits(cfg, params, h), aux


def loss_fn(cfg, params, batch, aux_weight: float = 0.01):
    logits, aux = forward_train(cfg, params, batch)
    if cfg.n_vision_tokens and "vision_embeds" in batch:
        nv = batch["vision_embeds"].shape[1]
        t = batch["targets"].shape[1]
        logits = jax.lax.dynamic_slice_in_dim(logits, nv - 1, t, axis=1)
    loss = cross_entropy(logits, batch["targets"], cfg.padded_vocab)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch):
    """Forward over the prompt; returns (last-token logits, decode cache)."""
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    h, positions = _embed_inputs(cfg, params, batch, dtype)

    if cfg.family in ("ssm", "hybrid"):
        cache = _prefill_ssm_like(cfg, params, h, positions)
        hh = cache.pop("_h")
        logits = lm_logits(cfg, params, apply_norm(cfg, hh[:, -1:, :], params,
                                                   "final"))
        return logits[:, 0], cache

    h, _, caches = _run_layers(cfg, params, h, positions, collect_cache=True)
    k, v = caches
    cache = dict(k=k.astype(dtype), v=v.astype(dtype))
    logits = lm_logits(cfg, params, apply_norm(cfg, h[:, -1:, :], params,
                                               "final"))
    return logits[:, 0], cache


def _prefill_ssm_like(cfg, params, h, positions):
    if cfg.family == "ssm":
        def body(carry, lp):
            hh = carry
            hh, (hf, convf) = _ssm_block(cfg, lp, hh, collect_state=True)
            return hh, (hf, convf)
        h, (hs, convs) = jax.lax.scan(body, h, params["layers"])
        return {"_h": h, "ssm": hs, "conv": convs}

    shared = params["shared"]

    def group_body(carry, glp):
        hh = carry
        def inner(c, lp):
            cc, st = _ssm_block(cfg, lp, c, collect_state=True)
            return cc, st
        hh, states = jax.lax.scan(inner, hh, glp)
        a_in = apply_norm(cfg, hh, shared, "ln1")
        attn_out, (k, v) = causal_attention(cfg, shared, a_in, positions)
        hh = hh + attn_out
        m_in = apply_norm(cfg, hh, shared, "ln2")
        hh = hh + mlp_apply(cfg, shared, m_in)
        return hh, (states, (k, v))

    h, (states, kv) = jax.lax.scan(group_body, h, params["layers"])
    cache = {"_h": h, "ssm": states[0], "conv": states[1],
             "k": kv[0], "v": kv[1]}
    if "trailing" in params:
        def tbody(c, lp):
            cc, st = _ssm_block(cfg, lp, c, collect_state=True)
            return cc, st
        h, tstates = jax.lax.scan(tbody, cache.pop("_h"), params["trailing"])
        cache["_h"] = h
        cache["t_ssm"], cache["t_conv"] = tstates
    return cache


def cache_schema(cfg, batch: int, seq: int) -> Schema:
    """Decode-cache schema (shapes + logical axes) for abstract lowering."""
    hd, hkv = cfg.head_dim_, cfg.n_kv_heads
    nh, hp, st = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    kc = cfg.conv_kernel - 1
    kv_axes = ("layers", "batch", "seq", "kv", None)
    if cfg.strap_decode and cfg.family in ("dense", "moe", "vlm"):
        # gated decode: seq stays device-local (the gather must be local);
        # TP moves to the head_dim axis instead.
        nst = max(seq // cfg.decode_strap_tokens, 1)
        kv_axes = ("layers", "batch", None, "kv", "headdim")
        return {
            "k": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes,
                           "zeros"),
            "v": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes,
                           "zeros"),
            "ksum": ParamSpec((cfg.n_layers, batch, nst, hkv, hd),
                              ("layers", "batch", None, "kv", "headdim"),
                              "zeros"),
        }

    if cfg.family == "ssm":
        return {
            "ssm": ParamSpec((cfg.n_layers, batch, nh, hp, st),
                             ("layers", "batch", "heads", None, None), "zeros"),
            "conv": ParamSpec((cfg.n_layers, batch, kc, conv_dim),
                              ("layers", "batch", None, "ssm_out"), "zeros"),
        }
    if cfg.family == "hybrid":
        groups, per, trailing = _hybrid_split(cfg)
        s: Schema = {
            "ssm": ParamSpec((groups, per, batch, nh, hp, st),
                             ("layer_groups", "layers", "batch", "heads",
                              None, None), "zeros"),
            "conv": ParamSpec((groups, per, batch, kc, conv_dim),
                              ("layer_groups", "layers", "batch", None,
                               "ssm_out"), "zeros"),
            "k": ParamSpec((groups, batch, seq, hkv, hd),
                           ("layers", "batch", "seq", "kv", None), "zeros"),
            "v": ParamSpec((groups, batch, seq, hkv, hd),
                           ("layers", "batch", "seq", "kv", None), "zeros"),
        }
        if trailing:
            s["t_ssm"] = ParamSpec((trailing, batch, nh, hp, st),
                                   ("layers", "batch", "heads", None, None),
                                   "zeros")
            s["t_conv"] = ParamSpec((trailing, batch, kc, conv_dim),
                                    ("layers", "batch", None, "ssm_out"),
                                    "zeros")
        return s
    return {
        "k": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes, "zeros"),
        "v": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes, "zeros"),
    }


def decode_step(cfg, params, cache, token, pos):
    """One decode step: (B,1) token ids -> (B, vocab) logits + new cache."""
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    h = embed_tokens(params, token, dtype)                   # (B,1,D)

    if cfg.family == "ssm":
        def body(carry, xs):
            lp, hs, cv = xs
            a_in = apply_norm(cfg, carry, lp, "ln1")
            out, h_new, cv_new = ssm_decode_step(cfg, lp, a_in, hs, cv)
            return carry + out, (h_new, cv_new)
        h, (hs, convs) = jax.lax.scan(
            body, h, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache = dict(ssm=hs, conv=convs)

    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(carry, xs):
            glp, hs_g, cv_g, k_g, v_g = xs
            hh = carry
            def inner(c, ys):
                lp, hs, cv = ys
                a_in = apply_norm(cfg, c, lp, "ln1")
                out, h_new, cv_new = ssm_decode_step(cfg, lp, a_in, hs, cv)
                return c + out, (h_new, cv_new)
            hh, (hs_new, cv_new) = jax.lax.scan(inner, hh, (glp, hs_g, cv_g))
            a_in = apply_norm(cfg, hh, shared, "ln1")
            attn_out, k_new, v_new = decode_attention(cfg, shared, a_in,
                                                      k_g, v_g, pos)
            hh = hh + attn_out
            m_in = apply_norm(cfg, hh, shared, "ln2")
            hh = hh + mlp_apply(cfg, shared, m_in)
            return hh, (hs_new, cv_new, k_new, v_new)

        h, (hs, cvs, ks, vs) = jax.lax.scan(
            group_body, h,
            (params["layers"], cache["ssm"], cache["conv"],
             cache["k"], cache["v"]))
        new_cache = dict(ssm=hs, conv=cvs, k=ks, v=vs)
        if "t_ssm" in cache:
            def tbody(c, ys):
                lp, hs_, cv_ = ys
                a_in = apply_norm(cfg, c, lp, "ln1")
                out, h_new, cv_new = ssm_decode_step(cfg, lp, a_in, hs_, cv_)
                return c + out, (h_new, cv_new)
            h, (ths, tcvs) = jax.lax.scan(
                tbody, h, (params["trailing"], cache["t_ssm"],
                           cache["t_conv"]))
            new_cache["t_ssm"], new_cache["t_conv"] = ths, tcvs

    elif cfg.strap_decode and cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            lp, k_c, v_c, ks_c = xs
            a_in = apply_norm(cfg, carry, lp, "ln1")
            attn_out, k_new, v_new, ks_new = decode_attention_gated(
                cfg, lp, a_in, k_c, v_c, ks_c, pos)
            hh = carry + attn_out
            m_in = apply_norm(cfg, hh, lp, "ln2")
            if cfg.n_experts:
                mo, _ = moe_apply(cfg, lp, m_in)
            else:
                mo = mlp_apply(cfg, lp, m_in)
            return hh + mo, (k_new, v_new, ks_new)

        h, (ks, vs, kss) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"],
                      cache["ksum"]))
        new_cache = dict(k=ks, v=vs, ksum=kss)

    else:
        def body(carry, xs):
            lp, k_c, v_c = xs
            a_in = apply_norm(cfg, carry, lp, "ln1")
            attn_out, k_new, v_new = decode_attention(cfg, lp, a_in,
                                                      k_c, v_c, pos)
            hh = carry + attn_out
            m_in = apply_norm(cfg, hh, lp, "ln2")
            if cfg.n_experts:
                mo, _ = moe_apply(cfg, lp, m_in)
            else:
                mo = mlp_apply(cfg, lp, m_in)
            return hh + mo, (k_new, v_new)

        h, (ks, vs) = jax.lax.scan(body, h,
                                   (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(k=ks, v=vs)

    h = apply_norm(cfg, h, params, "final")
    logits = lm_logits(cfg, params, h)[:, 0]
    return logits, new_cache
