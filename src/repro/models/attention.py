"""GQA attention: chunked-causal for train/prefill, cache-based for decode.

Memory discipline: the (S x S) score matrix is never materialized — queries
are processed in blocks of `cfg.attn_chunk` via `lax.scan` (flash-attention
structure expressed in XLA; the TPU kernel analogue is fused by Mosaic).
Decode attends one token against a (possibly seq-sharded) KV cache; softmax
statistics reduce over the sharded axis with XLA-inserted collectives
(flash-decoding style combine).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, Schema, apply_rope, constrain

NEG_INF = -1e30


def attn_schema(cfg, layers: int | None = None, prefix: str = "") -> Schema:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    L = (layers,) if layers is not None else ()
    A = ("layers",) if layers is not None else ()
    s: Schema = {
        prefix + "wq": ParamSpec(L + (d, hq * hd), A + ("dmodel", "qkv"), "fan_in"),
        prefix + "wk": ParamSpec(L + (d, hkv * hd), A + ("dmodel", "qkv"), "fan_in"),
        prefix + "wv": ParamSpec(L + (d, hkv * hd), A + ("dmodel", "qkv"), "fan_in"),
        prefix + "wo": ParamSpec(L + (hq * hd, d), A + ("qkv", "dmodel"), "fan_in"),
    }
    if cfg.qkv_bias:
        s[prefix + "bq"] = ParamSpec(L + (hq * hd,), A + ("qkv",), "zeros")
        s[prefix + "bk"] = ParamSpec(L + (hkv * hd,), A + ("qkv",), "zeros")
        s[prefix + "bv"] = ParamSpec(L + (hkv * hd,), A + ("qkv",), "zeros")
    return s


def _project_qkv(cfg, p, x, prefix: str = ""):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    q = x @ p[prefix + "wq"]
    k = x @ p[prefix + "wk"]
    v = x @ p[prefix + "wv"]
    if cfg.qkv_bias:
        q = q + p[prefix + "bq"].astype(q.dtype)
        k = k + p[prefix + "bk"].astype(k.dtype)
        v = v + p[prefix + "bv"].astype(v.dtype)
    return (q.reshape(b, s, hq, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


def _gqa_scores(q, k, scale):
    """q: (B,Sq,Hq,hd)  k: (B,Sk,Hkv,hd) -> (B,Hkv,grp,Sq,Sk) fp32."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    qg = q.reshape(b, sq, hkv, grp, hd)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _gqa_out(w, v, out_dtype):
    """w: (B,Hkv,grp,Sq,Sk)  v: (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd)."""
    b, hkv, grp, sq, sk = w.shape
    hd = v.shape[-1]
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, hkv * grp, hd).astype(out_dtype)


def causal_attention(cfg, p, x, positions=None, prefix: str = "",
                     causal: bool = True, kv_override=None):
    """Chunked (causal) self-attention for train/prefill.

    x: (B, S, D).  Returns (out (B,S,D), (k, v)) — the cache material.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim_
    scale = hd ** -0.5
    q, k, v = _project_qkv(cfg, p, x, prefix)
    if kv_override is not None:                 # cross-attention path
        k, v = kv_override
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.rope_theta > 0 and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(cfg, q, ("dp", None, "model", None))
    k = constrain(cfg, k, ("dp", None, "model", None))
    v = constrain(cfg, v, ("dp", None, "model", None))

    chunk = min(cfg.attn_chunk, s)
    if s % chunk:
        chunk = s                     # non-divisible (odd test lengths): full
    n_chunks = max(s // chunk, 1)
    sk = k.shape[1]
    k_pos = jnp.arange(sk)

    if n_chunks == 1:
        logits = _gqa_scores(q, k, scale)
        if causal:
            mask = positions[0][:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = _gqa_out(w, v, x.dtype)
    else:
        qc = q.reshape(b, n_chunks, chunk, q.shape[2], hd)
        pc = positions[0].reshape(n_chunks, chunk)

        def body(_, inputs):
            q_blk, pos_blk = inputs               # (B,chunk,Hq,hd), (chunk,)
            logits = _gqa_scores(q_blk, k, scale)
            if causal:
                mask = pos_blk[:, None] >= k_pos[None, :]
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            w = jax.nn.softmax(logits, axis=-1)
            return None, _gqa_out(w, v, x.dtype)

        _, out = jax.lax.scan(body, None,
                              (jnp.moveaxis(qc, 1, 0), pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, -1, hd)

    o = out.reshape(b, s, -1)
    return o @ p[prefix + "wo"], (k, v)


def decode_attention(cfg, p, x, k_cache, v_cache, pos, prefix: str = "",
                     cross: bool = False, cache_positions=None):
    """One-token attention against the cache.

    x: (B, 1, D); k_cache/v_cache: (B, S, Hkv, hd); pos: (B,) current index.
    Returns (out (B,1,D), new_k, new_v).  For cross-attention the cache is
    static (encoder outputs) and not updated.
    """
    b = x.shape[0]
    hd = cfg.head_dim_
    scale = hd ** -0.5
    q, k_new, v_new = _project_qkv(cfg, p, x, prefix)
    s_cache = k_cache.shape[1]

    if cross:
        k, v = k_cache, v_cache
        valid = jnp.ones((b, s_cache), bool)
    else:
        if cfg.rope_theta > 0:
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
            k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
        # scatter the new token into the cache at `pos` (per sequence)
        onehot = jax.nn.one_hot(pos, s_cache, dtype=k_cache.dtype)  # (B,S)
        k_cache = k_cache * (1 - onehot[..., None, None]) \
            + onehot[..., None, None] * k_new.astype(k_cache.dtype)
        v_cache = v_cache * (1 - onehot[..., None, None]) \
            + onehot[..., None, None] * v_new.astype(v_cache.dtype)
        k, v = k_cache, v_cache
        valid = jnp.arange(s_cache)[None, :] <= pos[:, None]

    logits = _gqa_scores(q, k, scale)[..., 0, :]       # (B,Hkv,grp,S)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    out = o @ p[prefix + "wo"]
    if cross:
        return out, None, None
    return out, k_cache, v_cache


def decode_attention_gated(cfg, p, x, k_cache, v_cache, ksum, pos,
                           prefix: str = ""):
    """Selector+strap gated decode (the paper's technique in the HLO).

    The KV cache is viewed as straps of `cfg.decode_strap_tokens` tokens.
    A selector scores straps with the running per-strap key sum (`ksum`),
    gathers only the top `cfg.decode_top_straps` straps (newest always
    included), and attends over that subset — the lowered HLO reads only
    the selected pages, cutting decode HBM traffic by the selectivity
    (C_BL 20 fF -> 6.6 fF, in bytes).  The cache update is a vmapped
    dynamic-update-slice (one page touched) instead of the one-hot
    full-cache rewrite of the baseline path.

    k_cache/v_cache: (B, S, Hkv, hd); ksum: (B, n_straps, Hkv, hd).
    """
    b = x.shape[0]
    hd = cfg.head_dim_
    scale = hd ** -0.5
    T = cfg.decode_strap_tokens
    q, k_new, v_new = _project_qkv(cfg, p, x, prefix)
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    s_cache = k_cache.shape[1]
    nst = s_cache // T

    # ---- scatter the new token (touches ONE page, not the whole cache) --
    def upd_one(cb, nb, pb):
        return jax.lax.dynamic_update_slice_in_dim(
            cb, nb.astype(cb.dtype), pb, axis=0)
    k_cache = jax.vmap(upd_one)(k_cache, k_new, pos)
    v_cache = jax.vmap(upd_one)(v_cache, v_new, pos)
    strap_idx = pos // T
    ksum = ksum + (jax.nn.one_hot(strap_idx, nst, dtype=jnp.float32)
                   [:, :, None, None]
                   * k_new[:, 0][:, None].astype(jnp.float32))

    # ---- selector: score straps by aggregated q . ksum ------------------
    hkv = k_cache.shape[2]
    grp = q.shape[2] // hkv
    qg = q.reshape(b, hkv, grp, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bnhd->bn", qg, ksum)
    base = jnp.arange(nst) * T
    valid = base[None, :] <= pos[:, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    scores = scores + 1e30 * jax.nn.one_hot(strap_idx, nst)  # keep newest
    k_sel = min(cfg.decode_top_straps, nst)
    _, ids = jax.lax.top_k(scores, k_sel)                    # (B, K)

    # ---- gather ONLY the selected straps ---------------------------------
    kr = k_cache.reshape(b, nst, T, hkv, hd)
    vr = v_cache.reshape(b, nst, T, hkv, hd)
    idx = ids[:, :, None, None, None]
    k_g = jnp.take_along_axis(kr, idx, axis=1).reshape(b, k_sel * T, hkv, hd)
    v_g = jnp.take_along_axis(vr, idx, axis=1).reshape(b, k_sel * T, hkv, hd)
    # keep the gather device-local: batch on dp, head_dim on model (the
    # cache's own layout) — without this GSPMD replicates the gathered KV
    k_g = constrain(cfg, k_g, ("dp", None, None, "model"), force=True)
    v_g = constrain(cfg, v_g, ("dp", None, None, "model"), force=True)
    gpos = (ids[:, :, None] * T
            + jnp.arange(T)[None, None, :]).reshape(b, k_sel * T)
    tok_valid = gpos <= pos[:, None]

    logits = _gqa_scores(q, k_g, scale)[..., 0, :]           # (B,Hkv,grp,K*T)
    logits = jnp.where(tok_valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_g.astype(jnp.float32))
    o = o.reshape(b, 1, -1).astype(x.dtype)
    return o @ p[prefix + "wo"], k_cache, v_cache, ksum
