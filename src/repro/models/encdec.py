"""Whisper-style encoder-decoder backbone (conv audio frontend is a stub:
`enc_embeds` arrive precomputed, matching the assignment's frontend-stub
rule).  Sinusoidal positions, LayerNorm, GELU MLP, MHA (kv == q heads).

Decoder layers carry both self-attention (causal, cached at decode) and
cross-attention over the encoder output (cached once at prefill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_schema, causal_attention, decode_attention
from .common import (ParamSpec, Schema, abstract_from_schema, add_norm,
                     apply_norm, axes_from_schema, cross_entropy,
                     embed_schema, embed_tokens, init_from_schema, lm_logits,
                     sinusoid_pos_emb)
from .mlp import mlp_apply, mlp_schema


def _enc_layer_schema(cfg) -> Schema:
    s: Schema = {}
    add_norm(s, cfg, "ln1", cfg.d_model, cfg.n_enc_layers)
    s.update(attn_schema(cfg, cfg.n_enc_layers))
    add_norm(s, cfg, "ln2", cfg.d_model, cfg.n_enc_layers)
    s.update(mlp_schema(cfg, cfg.n_enc_layers))
    return s


def _dec_layer_schema(cfg) -> Schema:
    s: Schema = {}
    add_norm(s, cfg, "ln1", cfg.d_model, cfg.n_layers)
    s.update(attn_schema(cfg, cfg.n_layers))
    add_norm(s, cfg, "lnx", cfg.d_model, cfg.n_layers)
    s.update(attn_schema(cfg, cfg.n_layers, prefix="x"))
    add_norm(s, cfg, "ln2", cfg.d_model, cfg.n_layers)
    s.update(mlp_schema(cfg, cfg.n_layers))
    return s


def encdec_schema(cfg) -> Schema:
    s = embed_schema(cfg)
    s["enc_layers"] = _enc_layer_schema(cfg)
    s["dec_layers"] = _dec_layer_schema(cfg)
    add_norm(s, cfg, "enc_final", cfg.d_model)
    return s


def init_params(cfg, key):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return init_from_schema(encdec_schema(cfg), key, dtype)


def param_axes(cfg):
    return axes_from_schema(encdec_schema(cfg))


def abstract_params(cfg):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return abstract_from_schema(encdec_schema(cfg), dtype)


# ---------------------------------------------------------------------------

def encode(cfg, params, enc_embeds):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b, s, d = enc_embeds.shape
    h = enc_embeds.astype(dtype) + sinusoid_pos_emb(s, d).astype(dtype)[None]

    def body(carry, lp):
        a_in = apply_norm(cfg, carry, lp, "ln1")
        attn, _ = causal_attention(cfg, lp, a_in, causal=False)
        hh = carry + attn
        m_in = apply_norm(cfg, hh, lp, "ln2")
        return hh + mlp_apply(cfg, lp, m_in), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(cfg, h, params, "enc_final")


def _cross_kv(cfg, lp, enc_out):
    """Project encoder output to one decoder layer's cross K/V."""
    b, s, _ = enc_out.shape
    hd, hkv = cfg.head_dim_, cfg.n_kv_heads
    k = (enc_out @ lp["xwk"]).reshape(b, s, hkv, hd)
    v = (enc_out @ lp["xwv"]).reshape(b, s, hkv, hd)
    if cfg.qkv_bias:
        k = k + lp["xbk"].reshape(hkv, hd)
        v = v + lp["xbv"].reshape(hkv, hd)
    return k, v


def decode_train(cfg, params, tokens, enc_out, collect_cache: bool = False):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b, s = tokens.shape
    h = embed_tokens(params, tokens, dtype)
    h = h + sinusoid_pos_emb(s, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(s)[None, :]

    def body(carry, lp):
        a_in = apply_norm(cfg, carry, lp, "ln1")
        attn, (k, v) = causal_attention(cfg, lp, a_in, positions)
        hh = carry + attn
        x_in = apply_norm(cfg, hh, lp, "lnx")
        xk, xv = _cross_kv(cfg, lp, enc_out)
        xattn, _ = causal_attention(cfg, lp, x_in, prefix="x", causal=False,
                                    kv_override=(xk, xv))
        hh = hh + xattn
        m_in = apply_norm(cfg, hh, lp, "ln2")
        hh = hh + mlp_apply(cfg, lp, m_in)
        ys = (k, v, xk, xv) if collect_cache else None
        return hh, ys

    h, ys = jax.lax.scan(body, h, params["dec_layers"])
    h = apply_norm(cfg, h, params, "final")
    return (h, ys) if collect_cache else (h, None)


def forward_train(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    h, _ = decode_train(cfg, params, batch["tokens"], enc_out)
    return lm_logits(cfg, params, h), jnp.zeros((), jnp.float32)


def loss_fn(cfg, params, batch, aux_weight: float = 0.0):
    logits, _ = forward_train(cfg, params, batch)
    return cross_entropy(logits, batch["targets"], cfg.padded_vocab)


def prefill(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    h, (k, v, xk, xv) = decode_train(cfg, params, batch["tokens"], enc_out,
                                     collect_cache=True)
    logits = lm_logits(cfg, params, h[:, -1:, :])
    return logits[:, 0], dict(k=k, v=v, xk=xk, xv=xv)


def cache_schema(cfg, batch: int, seq: int) -> Schema:
    hd, hkv = cfg.head_dim_, cfg.n_kv_heads
    s_enc = seq                                  # encoder length == cell seq/2
    kv_axes = ("layers", "batch", "seq", "kv", None)
    return {
        "k": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes, "zeros"),
        "v": ParamSpec((cfg.n_layers, batch, seq, hkv, hd), kv_axes, "zeros"),
        "xk": ParamSpec((cfg.n_layers, batch, s_enc, hkv, hd), kv_axes, "zeros"),
        "xv": ParamSpec((cfg.n_layers, batch, s_enc, hkv, hd), kv_axes, "zeros"),
    }


def decode_step(cfg, params, cache, token, pos):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    h = embed_tokens(params, token, dtype)
    # per-sequence sinusoidal position for the new token
    d = cfg.d_model
    inv = 1e4 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos[:, None].astype(jnp.float32) * inv[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    h = h + pe[:, None, :].astype(dtype)

    def body(carry, xs):
        lp, k_c, v_c, xk_c, xv_c = xs
        a_in = apply_norm(cfg, carry, lp, "ln1")
        attn, k_new, v_new = decode_attention(cfg, lp, a_in, k_c, v_c, pos)
        hh = carry + attn
        x_in = apply_norm(cfg, hh, lp, "lnx")
        xattn, _, _ = decode_attention(cfg, lp, x_in, xk_c, xv_c, pos,
                                       prefix="x", cross=True)
        hh = hh + xattn
        m_in = apply_norm(cfg, hh, lp, "ln2")
        hh = hh + mlp_apply(cfg, lp, m_in)
        return hh, (k_new, v_new)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = apply_norm(cfg, h, params, "final")
    logits = lm_logits(cfg, params, h)[:, 0]
    return logits, dict(k=ks, v=vs, xk=cache["xk"], xv=cache["xv"])
