"""Mamba2 (SSD — state-space duality) block: chunked train scan + decode step.

Chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the sequence is split
into chunks of Q tokens; within a chunk the quadratic dual form runs on the
MXU (einsums), while a `lax.scan` carries the (nh, headdim, state) SSM state
across chunks with per-chunk decay.  Per-token recurrence never appears, so
everything vectorizes; the cross-chunk scan is O(L/Q) sequential steps.

Decode keeps (conv_state, ssm_state) and advances one token in O(1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, Schema, constrain, rmsnorm


def ssm_schema(cfg, layers: int | None = None) -> Schema:
    d, di = cfg.d_model, cfg.d_inner
    ng, st, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    k = cfg.conv_kernel
    conv_dim = di + 2 * ng * st
    d_in_proj = 2 * di + 2 * ng * st + nh
    L = (layers,) if layers is not None else ()
    A = ("layers",) if layers is not None else ()
    if cfg.ssm_split_proj:
        # shard-aligned split of the fused in_proj/conv: mathematically the
        # same linear map, but every output slice lands on TP shard
        # boundaries, so no collective-permute on the z/x/B/C/dt split
        # (H1 iteration 2, EXPERIMENTS.md SPerf).
        gs = ng * st
        return {
            "in_z": ParamSpec(L + (d, di), A + ("dmodel", "ssm_out"), "fan_in"),
            "in_x": ParamSpec(L + (d, di), A + ("dmodel", "ssm_out"), "fan_in"),
            "in_B": ParamSpec(L + (d, gs), A + ("dmodel", "ssm_out"), "fan_in"),
            "in_C": ParamSpec(L + (d, gs), A + ("dmodel", "ssm_out"), "fan_in"),
            "in_dt": ParamSpec(L + (d, nh), A + ("dmodel", None), "fan_in"),
            "conv_x_w": ParamSpec(L + (k, di), A + (None, "ssm_out"), 0.2),
            "conv_B_w": ParamSpec(L + (k, gs), A + (None, "ssm_out"), 0.2),
            "conv_C_w": ParamSpec(L + (k, gs), A + (None, "ssm_out"), 0.2),
            "conv_x_b": ParamSpec(L + (di,), A + ("ssm_out",), "zeros"),
            "conv_B_b": ParamSpec(L + (gs,), A + ("ssm_out",), "zeros"),
            "conv_C_b": ParamSpec(L + (gs,), A + ("ssm_out",), "zeros"),
            "A_log": ParamSpec(L + (nh,), A + (None,), 0.5),
            "D_skip": ParamSpec(L + (nh,), A + (None,), "ones"),
            "dt_bias": ParamSpec(L + (nh,), A + (None,), "zeros"),
            "ssm_norm_w": ParamSpec(L + (di,), A + ("ssm_out",), "ones"),
            "out_proj": ParamSpec(L + (di, d), A + ("ssm_out", "dmodel"), "fan_in"),
        }
    return {
        "in_proj": ParamSpec(L + (d, d_in_proj), A + ("dmodel", "ssm_out"), "fan_in"),
        "conv_w": ParamSpec(L + (k, conv_dim), A + (None, "ssm_out"), 0.2),
        "conv_b": ParamSpec(L + (conv_dim,), A + ("ssm_out",), "zeros"),
        "A_log": ParamSpec(L + (nh,), A + (None,), 0.5),
        "D_skip": ParamSpec(L + (nh,), A + (None,), "ones"),
        "dt_bias": ParamSpec(L + (nh,), A + (None,), "zeros"),
        "ssm_norm_w": ParamSpec(L + (di,), A + ("ssm_out",), "ones"),
        "out_proj": ParamSpec(L + (di, d), A + ("ssm_out", "dmodel"), "fan_in"),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner
    gs = cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * gs]
    dt = zxbcdt[..., di + di + 2 * gs:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along seq: xbc (B,L,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _split_xbc(cfg, xbc):
    di, ng, st = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    nh, hp = cfg.ssm_nheads, cfg.ssm_headdim
    b, l, _ = xbc.shape
    x = xbc[..., :di].reshape(b, l, nh, hp)
    bmat = xbc[..., di: di + ng * st].reshape(b, l, ng, st)
    cmat = xbc[..., di + ng * st:].reshape(b, l, ng, st)
    return x, bmat, cmat


def ssd_chunked(cfg, x, bmat, cmat, dt, a_neg, h0=None):
    """Chunked SSD scan.

    x    : (B, L, nh, hp)   (already conv'd + activated)
    bmat : (B, L, ng, st)
    cmat : (B, L, ng, st)
    dt   : (B, L, nh)       (softplus'd, fp32)
    a_neg: (nh,)            A = -exp(A_log), fp32
    h0   : optional (B, nh, hp, st) initial state
    Returns (y (B,L,nh,hp), h_final).
    """
    b, l, nh, hp = x.shape
    ng, st = bmat.shape[2], bmat.shape[3]
    q = min(cfg.ssm_chunk, l)
    while l % q:                      # largest divisor <= ssm_chunk
        q -= 1
    nc = l // q
    rep = nh // ng                            # heads per B/C group

    xq = x.reshape(b, nc, q, nh, hp).astype(jnp.float32)
    bq = bmat.reshape(b, nc, q, ng, st).astype(jnp.float32)
    cq = cmat.reshape(b, nc, q, ng, st).astype(jnp.float32)
    dtq = dt.reshape(b, nc, q, nh)
    # pin shardings so GSPMD never reshards the big SSD intermediates.
    # seq-parallel mode shards the CHUNK axis over `model` (chunks align
    # with shards; the inter-chunk scan passes only the small SSM state
    # between neighbours) — otherwise TP rides the SSM head axis.
    seq_ax = "model" if cfg.seq_parallel else None
    head_ax = None if cfg.seq_parallel else "model"
    xq = constrain(cfg, xq, ("dp", seq_ax, None, head_ax, None))
    bq = constrain(cfg, bq, ("dp", seq_ax, None, None, None))
    cq = constrain(cfg, cq, ("dp", seq_ax, None, None, None))
    dtq = constrain(cfg, dtq, ("dp", seq_ax, None, head_ax))
    da = dtq * a_neg[None, None, None, :]     # (B,nc,Q,nh) negative values
    da = constrain(cfg, da, ("dp", seq_ax, None, head_ax))
    cs = jnp.cumsum(da, axis=2)               # inclusive cumsum within chunk
    total = cs[:, :, -1, :]                   # (B,nc,nh)

    # expand B/C groups to heads
    bh = jnp.repeat(bq, rep, axis=3) if ng > 1 else jnp.broadcast_to(
        bq, (b, nc, q, 1, st))
    ch = jnp.repeat(cq, rep, axis=3) if ng > 1 else jnp.broadcast_to(
        cq, (b, nc, q, 1, st))
    # head index of each B/C column (ng==1 -> broadcast dim of size 1)
    def bc(h_idx):                             # not used; clarity only
        return h_idx // rep

    dtx = xq * dtq[..., None]                 # (B,nc,Q,nh,hp)

    # ---- intra-chunk (dual quadratic form) ------------------------------
    # decay(qi, si) = exp(cs[qi] - cs[si]) for qi >= si
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,nc,Q,S,nh)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    decay = constrain(cfg, decay, ("dp", seq_ax, None, None, head_ax))
    if ng == 1:
        scores = jnp.einsum("bcqgn,bcsgn->bcqs", ch, bh)          # (B,nc,Q,S)
        y_diag = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", scores, decay, dtx)
    else:
        scores = jnp.einsum("bcqhn,bcshn->bcqsh", ch, bh)
        y_diag = jnp.einsum("bcqsh,bcqsh,bcshp->bcqhp", scores, decay, dtx)

    # ---- chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(total[:, :, None, :] - cs)             # (B,nc,Q,nh)
    spec = "bcsgn,bcsh,bcshp->bchpn" if ng == 1 else "bcshn,bcsh,bcshp->bchpn"
    s_chunk = jnp.einsum(spec, bh, decay_to_end, dtx)

    # ---- inter-chunk scan -------------------------------------------------
    if h0 is None:
        h0 = jnp.zeros((b, nh, hp, st), jnp.float32)

    def scan_fn(h, inputs):
        s_c, tot_c = inputs                    # (B,nh,hp,st), (B,nh)
        h_prev = h
        h = h * jnp.exp(tot_c)[:, :, None, None] + s_c
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)      # (B,nc,nh,hp,st)

    # ---- inter-chunk contribution -----------------------------------------
    state_decay = jnp.exp(cs)                  # decay from chunk start to qi
    spec = "bcqgn,bchpn,bcqh->bcqhp" if ng == 1 else "bcqhn,bchpn,bcqh->bcqhp"
    y_off = jnp.einsum(spec, ch, h_prevs, state_decay)

    y = constrain(cfg, y_diag + y_off, ("dp", seq_ax, None, head_ax, None))
    y = y.reshape(b, l, nh, hp)
    return y, h_final


def ssm_apply(cfg, p, xin, h0=None, conv0=None, return_state: bool = False):
    """Full Mamba2 mixer on (B, L, D).  Optionally consumes/returns state."""
    bsz, l, _ = xin.shape
    if cfg.ssm_split_proj:
        return _ssm_apply_split(cfg, p, xin, h0, conv0, return_state)
    zxbcdt = xin @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    if conv0 is not None:
        ctx = jnp.concatenate([conv0.astype(xbc.dtype), xbc], axis=1)
        xbc_conv = _causal_conv(ctx, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
        conv_out = ctx[:, -(cfg.conv_kernel - 1):, :]
    else:
        xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        conv_out = xbc[:, -(cfg.conv_kernel - 1):, :]
    xbc_act = jax.nn.silu(xbc_conv.astype(jnp.float32)).astype(xin.dtype)
    x, bmat, cmat = _split_xbc(cfg, xbc_act)
    dt32 = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(cfg, x, bmat, cmat, dt32, a_neg, h0)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(bsz, l, cfg.d_inner).astype(xin.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["ssm_norm_w"])
    out = y @ p["out_proj"]
    if return_state:
        return out, h_final, conv_out
    return out


def _ssm_apply_split(cfg, p, xin, h0, conv0, return_state):
    """Split-projection forward: identical math, shard-aligned streams.

    conv state layout: concatenation [x | B | C] along channels (same as
    the fused path's xbc), so decode caches stay compatible.
    """
    bsz, l, _ = xin.shape
    di, ng, st = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    gs = ng * st
    nh, hp = cfg.ssm_nheads, cfg.ssm_headdim
    z = xin @ p["in_z"]
    xs = xin @ p["in_x"]
    bs = xin @ p["in_B"]
    cssr = xin @ p["in_C"]
    dt = xin @ p["in_dt"]

    def conv_one(stream, w, b_, c0):
        if c0 is not None:
            ctx = jnp.concatenate([c0.astype(stream.dtype), stream], axis=1)
            out = _causal_conv(ctx, w, b_)[:, c0.shape[1]:]
            tail = ctx[:, -(cfg.conv_kernel - 1):, :]
        else:
            out = _causal_conv(stream, w, b_)
            tail = stream[:, -(cfg.conv_kernel - 1):, :]
        return out, tail

    cx0 = cb0 = cc0 = None
    if conv0 is not None:
        cx0 = conv0[..., :di]
        cb0 = conv0[..., di:di + gs]
        cc0 = conv0[..., di + gs:]
    xc, xt = conv_one(xs, p["conv_x_w"], p["conv_x_b"], cx0)
    bc_, bt = conv_one(bs, p["conv_B_w"], p["conv_B_b"], cb0)
    cc_, ct = conv_one(cssr, p["conv_C_w"], p["conv_C_b"], cc0)
    conv_out = jnp.concatenate([xt, bt, ct], axis=-1)

    act = lambda t: jax.nn.silu(t.astype(jnp.float32)).astype(xin.dtype)
    x = act(xc).reshape(bsz, l, nh, hp)
    bmat = act(bc_).reshape(bsz, l, ng, st)
    cmat = act(cc_).reshape(bsz, l, ng, st)
    dt32 = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(cfg, x, bmat, cmat, dt32, a_neg, h0)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None]         * x.astype(jnp.float32)
    y = y.reshape(bsz, l, di).astype(xin.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["ssm_norm_w"])
    out = y @ p["out_proj"]
    if return_state:
        return out, h_final, conv_out
    return out


def ssm_decode_step(cfg, p, xin, h, conv_state):
    """One-token recurrent step.

    xin        : (B, 1, D)
    h          : (B, nh, hp, st) fp32
    conv_state : (B, K-1, conv_dim)
    """
    bsz = xin.shape[0]
    nh, hp, st, ng = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    if cfg.ssm_split_proj:
        out, h_new, conv_new = _ssm_apply_split(cfg, p, xin, h, conv_state[:, None][:, 0:0] if False else None, True)             if False else _ssm_decode_split(cfg, p, xin, h, conv_state)
        return out, h_new, conv_new
    zxbcdt = xin @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    ctx = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    conv_new = ctx[:, 1:, :]                                   # (B, K-1, C)
    xbc_conv = jnp.einsum("bkc,kc->bc", ctx, p["conv_w"].astype(ctx.dtype)) \
        + p["conv_b"].astype(ctx.dtype)
    xbc_act = jax.nn.silu(xbc_conv.astype(jnp.float32))        # (B, C)

    di = cfg.d_inner
    x = xbc_act[:, :di].reshape(bsz, nh, hp)
    bmat = xbc_act[:, di: di + ng * st].reshape(bsz, ng, st)
    cmat = xbc_act[:, di + ng * st:].reshape(bsz, ng, st)
    rep = nh // ng
    bh = jnp.repeat(bmat, rep, axis=1)                         # (B, nh, st)
    chh = jnp.repeat(cmat, rep, axis=1)

    dt32 = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))  # (B, nh)
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt32 * a_neg[None, :])                        # (B, nh)

    dtx = x * dt32[..., None]                                  # (B, nh, hp)
    h_new = h * da[..., None, None] + jnp.einsum("bhp,bhn->bhpn", dtx, bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, chh)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(bsz, 1, di).astype(xin.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["ssm_norm_w"])
    return y @ p["out_proj"], h_new, conv_new


def _ssm_decode_split(cfg, p, xin, h, conv_state):
    """One-token step for the split-projection layout."""
    bsz = xin.shape[0]
    di, ng, st = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    gs = ng * st
    nh, hp = cfg.ssm_nheads, cfg.ssm_headdim
    z = xin @ p["in_z"]
    xs = xin @ p["in_x"]
    bs = xin @ p["in_B"]
    cs_ = xin @ p["in_C"]
    dt = xin @ p["in_dt"]

    def step_conv(stream, w, b_, c0):
        ctx = jnp.concatenate([c0.astype(stream.dtype), stream], axis=1)
        out = jnp.einsum("bkc,kc->bc", ctx, w.astype(ctx.dtype)) \
            + b_.astype(ctx.dtype)
        return out, ctx[:, 1:, :]

    cx0 = conv_state[..., :di]
    cb0 = conv_state[..., di:di + gs]
    cc0 = conv_state[..., di + gs:]
    xc, xt = step_conv(xs, p["conv_x_w"], p["conv_x_b"], cx0)
    bc_, bt = step_conv(bs, p["conv_B_w"], p["conv_B_b"], cb0)
    cc_, ct = step_conv(cs_, p["conv_C_w"], p["conv_C_b"], cc0)
    conv_new = jnp.concatenate([xt, bt, ct], axis=-1)

    act32 = lambda t: jax.nn.silu(t.astype(jnp.float32))
    x = act32(xc).reshape(bsz, nh, hp)
    bmat = act32(bc_).reshape(bsz, ng, st)
    cmat = act32(cc_).reshape(bsz, ng, st)
    rep = nh // ng
    bh = jnp.repeat(bmat, rep, axis=1)
    chh = jnp.repeat(cmat, rep, axis=1)
    dt32 = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt32 * a_neg[None, :])
    dtx = x * dt32[..., None]
    h_new = h * da[..., None, None] + jnp.einsum("bhp,bhn->bhpn", dtx, bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, chh)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(bsz, 1, di).astype(xin.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["ssm_norm_w"])
    return y @ p["out_proj"], h_new, conv_new
