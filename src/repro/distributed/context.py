"""Ambient mesh context for model-internal sharding annotations.

The launchers (dryrun/train/serve) register the active mesh here before
tracing; model code then can pin activation shardings / run shard_map EP
without threading the mesh object through every call.  When no mesh is
registered (CPU unit tests), every annotation degrades to a no-op.
"""

from __future__ import annotations

from jax.sharding import Mesh

_CURRENT: list[Mesh | None] = [None]


def set_mesh(mesh: Mesh | None):
    _CURRENT[0] = mesh


def get_mesh() -> Mesh | None:
    return _CURRENT[0]


def axis_sizes() -> dict[str, int]:
    m = _CURRENT[0]
    if m is None:
        return {}
    return dict(zip(m.axis_names, m.devices.shape))


def dp_axes() -> tuple[str, ...]:
    s = axis_sizes()
    return tuple(a for a in ("pod", "data") if a in s)
