"""Strapped hierarchical collectives (the paper's Selector+Strap, on a mesh).

The pod boundary is the HCB interface: few, expensive links.  In-pod ICI is
the local strap.  A gradient all-reduce therefore runs as:

  1. reduce-scatter over the in-pod "data" axis   (strap-local aggregation)
  2. all-reduce of the 1/N shard over "pod"       (one bond per strap),
     optionally int8-compressed with a shared scale + error feedback
  3. all-gather back over "data"

Cross-pod bytes drop by |data| (x4 more with int8), exactly like C_BL when
the selector keeps unselected straps off the global line.

These run inside `shard_map`; `hierarchical_psum_tree` is the user-facing
gradient synchronizer (used by the DP train loop and the perf experiments).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _pad_to(x, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def _psum_int8(x, axis_name: str):
    """Cross-pod all-reduce of an int8-quantized tensor with a pod-agreed
    scale.  Returns the dequantized sum and the local quantization error
    (for error feedback)."""
    absmax = jnp.max(jnp.abs(x))
    scale = jax.lax.pmax(absmax, axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    err = x - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, err


def strapped_psum(x, data_axis: str = "data", pod_axis: str | None = "pod",
                  compress: bool = False):
    """Hierarchical psum of one flat array inside shard_map.

    Returns (summed x, error_feedback or None)."""
    nd = jax.lax.psum(1, data_axis)
    flat = x.reshape(-1)
    flat, n = _pad_to(flat, nd)
    # 1. strap-local reduce-scatter
    shard = jax.lax.psum_scatter(flat, data_axis, scatter_dimension=0,
                                 tiled=True)
    err = None
    if pod_axis is not None:
        # 2. one bond per strap crosses the pod boundary
        if compress:
            shard, err = _psum_int8(shard, pod_axis)
        else:
            shard = jax.lax.psum(shard, pod_axis)
    # 3. strap-local all-gather
    full = jax.lax.all_gather(shard, data_axis, axis=0, tiled=True)
    out = full[:n].reshape(x.shape)
    if err is not None:
        err_full = jax.lax.all_gather(err, data_axis, axis=0, tiled=True)
        err = err_full[:n].reshape(x.shape)
    return out, err


def hierarchical_psum_tree(grads, mesh: Mesh, compress: bool = False,
                           mean: bool = True):
    """Synchronize a replicated gradient pytree across ("pod","data").

    Gradients enter per-device (each device holds its local-batch gradient)
    and leave identical on all devices.  Returns (grads, error_feedback)."""
    has_pod = "pod" in mesh.axis_names
    pod_axis = "pod" if has_pod else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    # gradients are reduced over the DP axes only (model shards hold
    # different parameter shards and never mix)
    n_total = sizes.get("data", 1) * sizes.get("pod", 1)

    def inner(*leaves):
        outs, errs = [], []
        for leaf in leaves:
            s, e = strapped_psum(leaf.astype(jnp.float32), "data", pod_axis,
                                 compress)
            if mean:
                s = s / n_total
            outs.append(s)
            errs.append(e if e is not None else jnp.zeros_like(s))
        return tuple(outs) + tuple(errs)

    leaves, treedef = jax.tree.flatten(grads)
    spec = P()  # every leaf fully replicated; shard_map sees local copies
    fn = shard_map(inner, mesh=mesh,
                   in_specs=tuple(spec for _ in leaves),
                   out_specs=tuple(spec for _ in range(2 * len(leaves))),
                   check_rep=False)
    results = fn(*leaves)
    outs = jax.tree.unflatten(treedef, results[: len(leaves)])
    errs = jax.tree.unflatten(treedef, results[len(leaves):])
    return outs, errs


def collective_matrix(mesh: Mesh) -> dict:
    """Bandwidth bookkeeping for the roofline: bytes crossing each axis for
    a hierarchical vs flat all-reduce of G bytes on this mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nd = sizes.get("data", 1)
    npod = sizes.get("pod", 1)
    flat_cross_pod = 2.0 * (npod - 1) / npod         # ring AR fraction
    strapped_cross_pod = flat_cross_pod / nd          # shard is 1/nd
    return dict(axes=sizes,
                flat_cross_pod_bytes_per_byte=flat_cross_pod,
                strapped_cross_pod_bytes_per_byte=strapped_cross_pod,
                strap_factor=nd)
