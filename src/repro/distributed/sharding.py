"""Sharding rules: logical parameter/cache axes -> mesh PartitionSpecs.

Baseline policy (the "CBA placement" discipline from DESIGN.md §2.2: keep
every reduction on the widest-bandwidth axis and co-locate optimizer shards
with parameters):

  TP ("model"):   vocab, ff, fused qkv out, experts, ssm channel dims
  FSDP ("data"):  the d_model (row) dim of every large 2-D weight — params,
                  grads and Adam moments are all fully sharded (ZeRO-3)
  DP ("pod","data"): the batch dim of activations / caches
  decode caches:  seq -> "model" (flash-decoding combine), batch -> DP

Every rule is divisibility-checked against the actual dim; indivisible dims
drop to replicated rather than relying on GSPMD padding.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (order = fallback preference)
AXIS_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "ff": ("model",),
    "qkv": ("model",),
    "experts": ("model",),
    "ssm_out": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "headdim": ("model",),
    "dmodel": ("data",),          # FSDP shard of the row dimension
    "seq": ("model",),            # decode-cache sequence axis
    "batch": ("pod", "data"),     # data parallel (multi-axis)
    "layers": (),
    "layer_groups": (),
}


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes: tuple, shape: tuple, mesh: Mesh) -> P:
    """Build a PartitionSpec for one array given logical axes + shape."""
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    entries = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in AXIS_RULES:
            entries.append(None)
            continue
        if ax == "batch":
            # use as many DP axes as divide the batch
            chosen = []
            prod = 1
            for m in AXIS_RULES["batch"]:
                if m in sizes and m not in used and dim % (prod * sizes[m]) == 0:
                    chosen.append(m)
                    prod *= sizes[m]
            for m in chosen:
                used.add(m)
            entries.append(tuple(chosen) if chosen else None)
            continue
        placed = None
        for m in AXIS_RULES[ax]:
            if m in sizes and m not in used and dim % sizes[m] == 0:
                placed = m
                used.add(m)
                break
        entries.append(placed)
    return P(*entries)


def tree_specs(axes_tree, abstract_tree, mesh: Mesh):
    """Map (logical-axes tree, ShapeDtypeStruct tree) -> PartitionSpec tree."""
    return jax.tree.map(
        lambda ax, ab: spec_for_axes(ax, ab.shape, mesh),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation / batch specs
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh, batch: int) -> tuple[str, ...]:
    sizes = _mesh_axis_sizes(mesh)
    chosen = []
    prod = 1
    for m in ("pod", "data"):
        if m in sizes and batch % (prod * sizes[m]) == 0:
            chosen.append(m)
            prod *= sizes[m]
    return tuple(chosen)


def batch_specs(input_tree, mesh: Mesh):
    """Inputs: shard dim0 (batch) over DP axes; everything else replicated.
    Embedding-stub inputs (B, S, D) also get their batch dim sharded."""
    def spec(x):
        axes = dp_axes(mesh, x.shape[0])
        if not axes:
            return P(*([None] * len(x.shape)))
        return P(axes, *([None] * (len(x.shape) - 1)))
    return jax.tree.map(spec, input_tree)


def cache_specs(cfg, cache_axes_tree, cache_abs_tree, mesh: Mesh):
    """Decode-cache specs.  batch=1 cells (long_500k) shard the sequence
    over ("data","model") instead of the (unshardable) batch."""
    def one(ax, ab):
        p = spec_for_axes(ax, ab.shape, mesh)
        # upgrade: if batch unsharded and a seq axis exists and divides, use
        # ("data","model") on seq.
        sizes = _mesh_axis_sizes(mesh)
        if "batch" in ax and "seq" in ax:
            bdim = ax.index("batch")
            sdim = ax.index("seq")
            if p[bdim] is None and "data" in sizes:
                full = sizes["data"] * sizes.get("model", 1)
                if ab.shape[sdim] % full == 0:
                    entries = list(p)
                    entries[sdim] = ("data", "model")
                    p = P(*entries)
        return p
    return jax.tree.map(one, cache_axes_tree, cache_abs_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
