"""Perf-iteration (hillclimb) config variants — see EXPERIMENTS.md §Perf.

Levels stack (1 ⊂ 2 ⊂ ... ⊂ 5):
  1: attn_chunk 512 -> 1024 (fewer scan trips, larger MXU tiles)
  2: remat off for serve cells (no grad -> no recompute needed)
  3: selector+strap gated decode (decode cells, full-attention families)
     — the paper's technique lowered into the HLO; plus scatter (not
     one-hot) cache update.
"""

from __future__ import annotations

import dataclasses


def apply_opt_level(cfg, cell: str, level: int):
    if level >= 1:
        cfg = dataclasses.replace(cfg, attn_chunk=1024)
    if level >= 2 and cell != "train_4k":
        cfg = dataclasses.replace(cfg, remat=False)
    if level >= 3 and cell in ("decode_32k", "long_500k") \
            and cfg.family in ("dense", "moe", "vlm"):
        cfg = dataclasses.replace(cfg, strap_decode=True,
                                  decode_strap_tokens=2048,
                                  decode_top_straps=4)
    if level >= 4:
        # explicit activation sharding constraints (kills GSPMD reshards)
        cfg = dataclasses.replace(cfg, shard_acts=True)
    if level >= 5 and cfg.n_experts:
        # shard_map expert-parallel MoE dispatch (all-to-all, not gather)
        cfg = dataclasses.replace(cfg, moe_ep=True)
    if level >= 6 and cell == "train_4k" and cfg.family in ("dense", "moe",
                                                            "vlm"):
        # sequence-parallel residual stream (activation memory / 16,
        # AR -> RS+AG on the TP boundary)
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if level >= 7 and cfg.ssm_state:
        # shard-aligned split of the fused SSM in_proj/conv (H1 iter 2)
        cfg = dataclasses.replace(cfg, ssm_split_proj=True)
    if level >= 8 and cfg.family == "ssm" and cell in ("train_4k",
                                                       "prefill_32k"):
        # seq-parallel residual for attention-free models (H1 iter 3):
        # chunks align with shards; inter-chunk scan passes only the state
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    return cfg
