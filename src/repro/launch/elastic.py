"""Elastic sharded sweep: heartbeat-monitored slab dispatch + re-slabbing.

The sharded sweep (`launch.shard`) assumes every device lives for the
whole dispatch.  This driver makes the sweep survive host loss instead:
the design-point range is cut into slabs, each slab is dispatched as one
sharded columns call (`shard.sharded_sweep_columns(..., rows=...)`) with
completed slabs checkpointed, and a `runtime.fault` stack supervises the
loop —

  - `HeartbeatMonitor` (driven by a deterministic simulated clock, one
    simulated host per mesh device) detects the dropped host;
  - `replan_mesh` re-derives the mesh plan for the survivors and the
    dispatch mesh is rebuilt over the surviving devices only;
  - `FaultTolerantRunner` catches the failure, restores the last
    checkpoint and resumes from the first incomplete slab — only the
    in-flight slab's work is recomputed.

Because per-row scoring is slab-shape and mesh-size independent (the
`sharded_sweep_columns` contract), the concatenated slab columns are
bit-identical to a fault-free `dse.sweep(space)` whatever mesh each slab
ended up on — the recovery path cannot change results, only cost.  That
cost is the deterministic `ElasticReport.resume_overhead_frac`
(recomputed / total points), which `benchmarks/bench_sharded_sweep.py`
records and CI gates.

Failure injection (`runtime.fault.FailureInjector` schedule {slab: kind}):

    "drop:<host>"  the named simulated host stops heartbeating after the
                   slab's dispatch; detection -> re-slab -> resume
    "crash"        hard failure of the coordinator step (no mesh change)
    "nan"          poisons the step metrics' loss (runner restores)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import transient
from ..runtime.fault import (FailureInjector, FaultTolerantRunner,
                             HeartbeatMonitor, replan_mesh)
from jax.sharding import Mesh

__all__ = ["HostDropError", "ClusterLostError", "ElasticReport",
           "elastic_sweep"]


class HostDropError(RuntimeError):
    """A heartbeat-detected host loss mid-sweep (recoverable: the runner
    restores the last checkpoint onto the survivors' mesh)."""


class ClusterLostError(Exception):
    """Every simulated host is dead.  Deliberately NOT a RuntimeError:
    the runner's recovery path catches (RuntimeError, FloatingPointError)
    and would otherwise restore-and-retry a sweep with no devices left —
    this must propagate to the caller instead."""


@dataclass
class ElasticReport:
    """What the elastic run did — all integers deterministic for a given
    space + injection schedule, so the overhead fraction is benchmarkable
    and CI-gateable."""
    n_slabs: int
    slab_points: int
    total_points: int
    restarts: int = 0
    recomputed_points: int = 0
    dropped_hosts: list = field(default_factory=list)
    device_history: list = field(default_factory=list)  # devices per slab run

    @property
    def resume_overhead_frac(self) -> float:
        """Recomputed work as a fraction of the sweep's useful work."""
        return self.recomputed_points / max(self.total_points, 1)


def elastic_sweep(space=None, mesh=None, *, slab_points: int | None = None,
                  injector: FailureInjector | None = None,
                  heartbeat_timeout_s: float = 10.0, backend: str = "auto",
                  b_chunk: int = transient.DEFAULT_B_CHUNK):
    """Fault-tolerant sharded sweep -> (DesignBatch, ElasticReport).

    Equivalent to `dse.sweep(space, sharding=mesh)` — bit-identically,
    by the slab-independence contract — but dispatched slab-by-slab
    under heartbeat supervision so an injected (or, on a real cluster,
    genuine) host drop re-slabs onto the survivors and resumes from the
    last completed slab instead of losing the sweep.

    `slab_points` is the checkpoint granularity in design points
    (default: four slabs); `injector` a `runtime.fault.FailureInjector`
    keyed by slab index (see module docstring for kinds).
    """
    from ..core import dse
    from . import shard

    mesh = shard._as_mesh(mesh)
    plan = dse.plan_sweep(space)
    n = len(plan.sp)
    if slab_points is None:
        slab_points = max(1, -(-n // 4))
    n_slabs = -(-n // slab_points)

    devices = list(mesh.devices.flat)
    workers = [f"host{i}" for i in range(len(devices))]
    device_of = dict(zip(workers, devices))
    # deterministic simulated cluster clock: one tick per slab, a jump
    # past the timeout when a drop is injected — detection is exact and
    # reproducible, never wall-clock dependent
    clock = [0.0]
    monitor = HeartbeatMonitor(workers, timeout_s=heartbeat_timeout_s,
                               clock=lambda: clock[0])
    injector = injector or FailureInjector()
    report = ElasticReport(n_slabs=n_slabs, slab_points=slab_points,
                           total_points=n)
    ctx = {"mesh": mesh, "alive": list(workers)}

    def step_fn(state, step):
        lo = step * slab_points
        hi = min(n, lo + slab_points)
        clock[0] += 1.0
        for w in ctx["alive"]:
            monitor.beat(w)
        report.device_history.append(int(ctx["mesh"].devices.size))
        cols = shard.sharded_sweep_columns(plan, ctx["mesh"], backend=backend,
                                           b_chunk=b_chunk, rows=(lo, hi))
        cols = {k: np.asarray(v) for k, v in cols.items()}
        fault = injector.check(step)
        if fault is not None and fault.startswith("drop:"):
            lost = fault.split(":", 1)[1]
            if lost not in ctx["alive"]:
                raise ValueError(f"cannot drop unknown/dead host {lost!r}")
            # the host stops beating; everyone else keeps beating until
            # the timeout elapses, at which point the monitor flags it
            clock[0] += monitor.timeout + 1.0
            for w in ctx["alive"]:
                if w != lost:
                    monitor.beat(w)
            # earlier casualties stay dead in the monitor, so membership —
            # not equality — is the detection check
            if lost not in monitor.dead():
                raise RuntimeError(
                    f"heartbeat detection drift: {lost!r} should be dead, "
                    f"monitor says dead={monitor.dead()}")
            survivors = monitor.alive()
            if not survivors:
                raise ClusterLostError(
                    "all hosts lost — nothing to re-slab onto")
            plan_new = replan_mesh(len(survivors), model_parallel=1)
            ctx["alive"] = survivors[:plan_new.devices]
            ctx["mesh"] = Mesh(
                np.asarray([device_of[w] for w in ctx["alive"]]), ("batch",))
            report.dropped_hosts.append(lost)
            # this slab's columns die with the exception: the restore
            # path recomputes exactly [lo, hi) on the survivors' mesh
            report.recomputed_points += hi - lo
            raise HostDropError(
                f"host {lost} missed heartbeat at slab {step}; re-slabbing "
                f"{len(devices)} -> {len(survivors)} devices")
        if fault == "crash":
            report.recomputed_points += hi - lo
            raise RuntimeError(f"injected crash at slab {step}")
        state = {"cols": {**state["cols"], step: cols}}
        metrics = {"slab": step, "points": hi - lo,
                   "devices": int(ctx["mesh"].devices.size)}
        if fault == "nan":
            report.recomputed_points += hi - lo
            metrics["loss"] = float("nan")
        return state, metrics

    checkpoint = [({"cols": {}}, 0)]

    def save_fn(step, state):
        checkpoint[0] = (state, step)

    def restore_fn():
        return checkpoint[0]

    runner = FaultTolerantRunner(step_fn, save_fn, restore_fn,
                                 injector=FailureInjector(), ckpt_every=1)
    state, _metrics = runner.run({"cols": {}}, n_slabs)
    report.restarts = runner.restarts

    cols_full = {k: np.concatenate([state["cols"][i][k]
                                    for i in range(n_slabs)])
                 for k in state["cols"][0]}
    return dse.assemble_batch(plan.sp, cols_full), report
