import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (jax locks the device
# count on first init).  Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch x shape-cell x mesh).

For each cell this lowers the real step function (train_step for train_4k,
serve_prefill for prefill_32k, serve_decode for decode_32k / long_500k)
against pure ShapeDtypeStruct inputs on the production mesh, compiles it,
and records memory_analysis / cost_analysis / the HLO collective schedule
into results/dryrun/<arch>__<cell>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --cell train_4k --mesh single
  python -m repro.launch.dryrun --all            # orchestrates subprocesses
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, cell: str, mesh_kind: str, opt_level: int = 0) -> dict:
    import jax

    from ..configs.base import SHAPE_CELLS, input_specs
    from ..configs.registry import get_arch
    from ..distributed import sharding as shard
    from ..models import registry as M
    from ..roofline.hlo import parse_collectives
    from ..train.optimizer import abstract_opt_state, opt_state_axes
    from ..train.step import make_serve_decode, make_serve_prefill, make_train_step
    from .mesh import make_production_mesh

    cfg = get_arch(arch)
    if opt_level:
        cfg = apply_opt_level(cfg, cell, opt_level)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    from ..distributed import context as mesh_ctx
    mesh_ctx.set_mesh(mesh)
    ns = lambda tree: shard.named(tree, mesh)
    kind = SHAPE_CELLS[cell]["kind"]
    b, s = SHAPE_CELLS[cell]["global_batch"], SHAPE_CELLS[cell]["seq_len"]

    t0 = time.time()
    abs_params = M.abstract_params(cfg)
    p_axes = M.param_axes(cfg)
    p_specs = shard.tree_specs(p_axes, abs_params, mesh)

    if kind == "train":
        batch_abs = input_specs(cfg, cell)
        batch_specs = shard.batch_specs(batch_abs, mesh)
        abs_opt = abstract_opt_state(cfg.optimizer, abs_params)
        o_axes = opt_state_axes(cfg.optimizer, p_axes)
        o_specs = shard.tree_specs(o_axes, abs_opt, mesh)
        step_fn, _ = make_train_step(cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(ns(p_specs), ns(o_specs), ns(batch_specs)),
            out_shardings=(ns(p_specs), ns(o_specs), None),
            donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(abs_params, abs_opt, batch_abs)
    elif kind == "prefill":
        batch_abs = input_specs(cfg, cell)
        batch_specs = shard.batch_specs(batch_abs, mesh)
        cache_axes = M.cache_axes(cfg, b, s)
        cache_abs = M.abstract_cache(cfg, b, s)
        c_specs = shard.cache_specs(cfg, cache_axes, cache_abs, mesh)
        step_fn = make_serve_prefill(cfg)
        jitted = jax.jit(step_fn,
                         in_shardings=(ns(p_specs), ns(batch_specs)),
                         out_shardings=(None, ns(c_specs)))
        with mesh:
            lowered = jitted.lower(abs_params, batch_abs)
    else:  # decode
        batch_abs = input_specs(cfg, cell)
        cache_axes = M.cache_axes(cfg, b, s)
        cache_abs = M.abstract_cache(cfg, b, s)
        c_specs = shard.cache_specs(cfg, cache_axes, cache_abs, mesh)
        tok_spec = shard.batch_specs(
            {"token": batch_abs["token"], "pos": batch_abs["pos"]}, mesh)
        step_fn = make_serve_decode(cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(ns(p_specs), ns(c_specs),
                          ns(tok_spec)["token"], ns(tok_spec)["pos"]),
            out_shardings=(ns(tok_spec)["token"], None, ns(c_specs)),
            donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(abs_params, cache_abs,
                                   batch_abs["token"], batch_abs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_d[f] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and (
                  "flops" in k or "bytes" in k or k in ("utilization",))}

    hlo = compiled.as_text()
    coll = parse_collectives(hlo, pod_size=256)
    # exact per-device dot FLOPs + collective bytes with while-loop trip
    # multipliers (XLA cost_analysis counts loop bodies once — verified)
    from ..roofline.hlo_exact import analyze as hlo_analyze
    from ..roofline.analytic import hbm_bytes_per_device, model_flops
    exact = hlo_analyze(hlo, pod_size=256)
    import gzip
    tag = f"{arch}__{cell}__{mesh_kind}" + (f"__opt{opt_level}" if opt_level else "")
    RESULTS.mkdir(parents=True, exist_ok=True)
    with gzip.open(RESULTS / f"{tag}.hlo.gz", "wt") as fh:
        fh.write(hlo)

    n_dev = mesh.devices.size
    result = dict(
        arch=arch, cell=cell, mesh=mesh_kind, devices=int(n_dev),
        mesh_shape=list(mesh.devices.shape), axes=list(mesh.axis_names),
        kind=kind, global_batch=b, seq_len=s, opt_level=opt_level,
        ok=True, t_lower_s=t_lower, t_compile_s=t_compile,
        memory=mem_d,
        flops_per_device=cost_d.get("flops", 0.0),
        bytes_accessed_per_device=cost_d.get("bytes accessed", 0.0),
        cost_analysis=cost_d,
        collectives=coll,
        hlo_exact=exact,
        analytic_hbm_bytes_per_device=float(
            hbm_bytes_per_device(cfg, cell, n_dev)),
        model_flops=float(model_flops(cfg, cell)),
        model_params=int(cfg.param_count()),
        active_params=int(cfg.active_param_count()),
        hlo_bytes=len(hlo),
    )
    return result


from .optlevels import apply_opt_level  # noqa: E402  (re-export)


def cell_list(only_arch=None, only_cell=None):
    from ..configs.registry import ARCHS
    cells = []
    # cheapest architectures first so results stream in early
    for name, cfg in sorted(ARCHS.items(), key=lambda kv: kv[1].param_count()):
        for cell in cfg.runnable_cells():
            if only_arch and name != only_arch:
                continue
            if only_cell and cell != only_cell:
                continue
            cells.append((name, cell))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--opt-level", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = []
        todo = cell_list(args.arch, args.cell)
        meshes = args.meshes.split(",")
        for name, cell in todo:
            for mesh_kind in meshes:
                tag = f"{name}__{cell}__{mesh_kind}"
                if args.opt_level:
                    tag += f"__opt{args.opt_level}"
                out = RESULTS / f"{tag}.json"
                if out.exists() and not args.force:
                    print(f"[skip] {tag}", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", name, "--cell", cell, "--mesh", mesh_kind,
                       "--opt-level", str(args.opt_level)]
                print(f"[run ] {tag}", flush=True)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout,
                                   cwd=str(Path(__file__).resolve().parents[3]),
                                   env={**os.environ,
                                        "PYTHONPATH": "src"})
                dt = time.time() - t0
                if r.returncode != 0:
                    failures.append(tag)
                    err = (r.stderr or "")[-2000:]
                    out.write_text(json.dumps(dict(
                        arch=name, cell=cell, mesh=mesh_kind, ok=False,
                        error=err, opt_level=args.opt_level), indent=1))
                    print(f"[FAIL] {tag} ({dt:.0f}s): {err[-300:]}", flush=True)
                else:
                    print(f"[ ok ] {tag} ({dt:.0f}s)", flush=True)
        print(f"done; {len(failures)} failures: {failures}", flush=True)
        sys.exit(1 if failures else 0)

    assert args.arch and args.cell
    tag = f"{args.arch}__{args.cell}__{args.mesh}"
    if args.opt_level:
        tag += f"__opt{args.opt_level}"
    try:
        result = run_cell(args.arch, args.cell, args.mesh, args.opt_level)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    out = RESULTS / f"{tag}.json"
    out.write_text(json.dumps(result, indent=1))
    print(json.dumps({k: result[k] for k in
                      ("arch", "cell", "mesh", "ok", "t_compile_s")}))


if __name__ == "__main__":
    main()
