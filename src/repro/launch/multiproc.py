"""Two-process `jax.distributed` integration smoke for the sweep fabric.

`launch.shard`'s multi-process story has three load-bearing claims:

  1. the DesignSpace lowering is deterministic and host-replicated, so
     every process assembles bit-identical operand batches on its own;
  2. `put_global` assembles a global array one addressable shard at a
     time via `jax.make_array_from_callback`, each shard bit-identical
     to the corresponding rows of the host-replicated batch;
  3. per-row evaluation + scoring is slab-independent, so the rows a
     host computes are bit-identical to the same rows of a single-host
     sweep — which is what makes the union over hosts THE sweep.

This module proves all three under a REAL `jax.distributed.initialize`
cluster: a coordinator + worker pair on localhost (the `run_smoke`
parent picks a free port and spawns both), each child asserting the
shard contents of `put_global` against the host batch and its own point
slab against the full single-host oracle, bit for bit.

One honest limitation, empirically pinned by this smoke's development:
the CPU backend refuses jit execution over arrays spanning processes
("Multiprocess computations aren't implemented on the CPU backend"), so
the cross-process dispatch itself only executes on GPU/TPU clusters.
On CPU CI the children therefore dispatch their slabs on their LOCAL
device mesh — which, by claim 3 (asserted, not assumed), is the same
computation the global mesh would shard across hosts.

CLI:  python -m repro.launch.multiproc --smoke         (the CI entry)
      ... --smoke --mc 16 --local-devices 4            (bigger variant)
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
from pathlib import Path

__all__ = ["run_smoke"]

_SRC_DIR = Path(__file__).resolve().parents[2]


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _child_main(coordinator: str, num_processes: int, process_id: int,
                mc: int) -> None:
    """One cluster member: initialize distributed JAX FIRST, then verify
    the sharded-sweep multi-process contract and emit one JSON line."""
    import jax
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    import numpy as np
    from jax.sharding import Mesh

    from ..core import dse, transient
    from ..core.space import DesignSpace
    from . import shard

    if jax.process_count() != num_processes:
        raise SystemExit(f"process_count {jax.process_count()} != "
                         f"{num_processes} — distributed init did not take")
    gdevs, ldevs = jax.devices(), jax.local_devices()
    if len(gdevs) <= len(ldevs):
        raise SystemExit(f"global devices ({len(gdevs)}) must exceed local "
                         f"({len(ldevs)}) — the mesh does not span processes")
    gmesh = Mesh(np.asarray(gdevs), ("batch",))
    gsharding = shard.sweep_sharding(gmesh)
    lmesh = Mesh(np.asarray(ldevs), ("batch",))

    spaces = [
        ("targets", DesignSpace.paper_targets()),
        ("targets-mc", DesignSpace.paper_targets().with_mc(mc)),
        ("replica-mc", DesignSpace.paper_targets().with_replica().with_mc(mc)),
    ]
    checks = {}
    for label, space in spaces:
        plan = dse.plan_sweep(space)
        # claim 2: put_global's make_array_from_callback path — every
        # addressable shard of the global operand array must equal the
        # corresponding rows of the host-replicated padded batch
        core = list(plan.operands[:6])
        b = core[0].shape[0]
        target = shard._dispatch_target(b, len(gdevs),
                                        transient.DEFAULT_B_CHUNK)
        for x in transient._pad_operands(core, target - b):
            gx = shard.put_global(x, gsharding)
            host = np.asarray(x)
            if gx.shape != host.shape:
                raise SystemExit(f"{label}: global shape {gx.shape} != "
                                 f"host {host.shape}")
            for s in gx.addressable_shards:
                if not np.array_equal(np.asarray(s.data), host[s.index]):
                    raise SystemExit(
                        f"{label}: addressable shard {s.index} of the "
                        "global operand array differs from the "
                        "host-replicated batch — put_global broke")
        # claims 1+3: this process's point slab, computed here from its
        # own (independently lowered) plan, must be bit-identical to the
        # single-host oracle's rows
        oracle = dse.sweep(space)
        n = len(plan.sp)
        lo = process_id * n // num_processes
        hi = (process_id + 1) * n // num_processes
        cols = shard.sharded_sweep_columns(plan, lmesh, rows=(lo, hi))
        bad = [k for k, v in cols.items()
               if not np.array_equal(np.asarray(v),
                                     np.asarray(getattr(oracle, k))[lo:hi])]
        if bad:
            raise SystemExit(f"{label}: slab [{lo}, {hi}) NOT bit-identical "
                             f"to the single-host sweep: {bad}")
        checks[label] = {"points": n, "rows": [lo, hi]}
    print(json.dumps({"process": process_id, "ok": True,
                      "global_devices": len(gdevs),
                      "local_devices": len(ldevs), "checks": checks}),
          flush=True)


def run_smoke(num_processes: int = 2, mc: int = 8, local_devices: int = 2,
              timeout_s: float = 600.0) -> None:
    """Launch the coordinator + worker children and verify their reports."""
    addr = f"127.0.0.1:{_free_port()}"
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    # last flag wins, so the forced per-process device count survives any
    # XLA_FLAGS the caller exported
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{local_devices}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(_SRC_DIR), env.get("PYTHONPATH")) if p)
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.multiproc", "--child",
         "--coordinator", addr, "--num-processes", str(num_processes),
         "--process-id", str(i), "--mc", str(mc)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(num_processes)]
    results, failures = [], []
    for i, p in enumerate(procs):
        try:
            out, err = p.communicate(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise SystemExit(f"multiproc smoke: process {i} timed out after "
                             f"{timeout_s:.0f}s")
        if p.returncode != 0:
            failures.append(f"process {i} rc={p.returncode}:\n"
                            f"{out.strip()}\n{err.strip()[-2000:]}")
            continue
        lines = [ln for ln in out.splitlines() if ln.strip()]
        results.append(json.loads(lines[-1]))
    if failures:
        raise SystemExit("multiproc smoke FAILED:\n" + "\n---\n".join(failures))

    for r in results:
        if not r.get("ok"):
            raise SystemExit(f"multiproc smoke: process {r['process']} "
                             f"reported not-ok: {r}")
    # the per-process slabs must tile every space's full point range —
    # a smoke where both processes checked the same rows proves nothing
    for label in results[0]["checks"]:
        slabs = sorted(r["checks"][label]["rows"] for r in results)
        n = results[0]["checks"][label]["points"]
        covered = slabs[0][0] == 0 and slabs[-1][1] == n and all(
            a[1] == b[0] for a, b in zip(slabs, slabs[1:]))
        if not covered:
            raise SystemExit(f"multiproc smoke: slabs {slabs} do not tile "
                             f"[0, {n}) on {label}")
        print(f"{label}: {n} points tiled over {len(results)} processes "
              f"{slabs} — each slab bit-identical to the single-host sweep")
    print(f"multiproc smoke: OK ({num_processes} processes x "
          f"{local_devices} devices, coordinator {addr})")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="run the 2-process integration smoke (parent)")
    parser.add_argument("--child", action="store_true",
                        help=argparse.SUPPRESS)
    parser.add_argument("--coordinator", default=None)
    parser.add_argument("--num-processes", type=int, default=2)
    parser.add_argument("--process-id", type=int, default=0)
    parser.add_argument("--mc", type=int, default=8,
                        help="MC samples for the with_mc spaces")
    parser.add_argument("--local-devices", type=int, default=2,
                        help="forced CPU devices per process")
    parser.add_argument("--timeout", type=float, default=600.0)
    args = parser.parse_args(argv)
    if args.child:
        _child_main(args.coordinator, args.num_processes, args.process_id,
                    args.mc)
    elif args.smoke:
        run_smoke(num_processes=args.num_processes, mc=args.mc,
                  local_devices=args.local_devices, timeout_s=args.timeout)
    else:
        parser.print_help()


if __name__ == "__main__":
    main()
