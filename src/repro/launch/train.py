"""Training launcher.

Single-host execution runs for real (CPU here, TPU on a pod); the
production meshes are exercised via `--dryrun` (see dryrun.py for the full
sweep harness).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 100 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--inject-crash", type=int, default=-1,
                    help="inject a crash at this step (fault-tolerance demo)")
    args = ap.parse_args()

    from ..configs.registry import get_arch
    from ..train.loop import TrainConfig, train
    from ..train.optimizer import OptConfig

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_arch(name)
    schedule = {args.inject_crash: "crash"} if args.inject_crash >= 0 else {}
    tc = TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        microbatch=args.microbatch or None,
        opt=OptConfig(lr=args.lr, total_steps=args.steps),
        failure_schedule=schedule)
    out = train(cfg, tc)
    print(f"done: first loss {out['first_loss']:.4f} -> "
          f"final {out['final_loss']:.4f} ({out['restarts']} restarts)")


if __name__ == "__main__":
    main()
