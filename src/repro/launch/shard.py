"""Sharded multi-device driver for the fused row-cycle DSE sweep.

The array-native DSE layer already lowers a whole `DesignSpace` to ONE
flat operand batch (`transient.FusedOperands`, batch axis only).  The
single-host path then feeds that batch through the fused engine in a
*sequential* Python loop of `b_chunk`-sized dispatches.  This module
replaces that loop with a sharded dispatch:

    mesh    = make_sweep_mesh()                  # or any jax Mesh
    batch   = dse.sweep(space, sharding=mesh)    # each device: own slab

    # equivalently, via this module's convenience wrapper:
    batch   = shard.sharded_sweep(space, mesh=mesh)

Mechanics (the `pad_to` + `device_put` contract of `core.batch`):

1. the operand batch is padded with inactive design points so every
   device receives an identical, B_ALIGN-aligned slab (for grids larger
   than `n_devices * b_chunk`, a whole number of `b_chunk` chunks);
2. every operand is placed with a `NamedSharding` over the batch axis
   (`P(mesh.axis_names)` — a multi-axis mesh shards over the full device
   product, so `launch.mesh.make_test_mesh` works as-is);
3. a `shard_map`-wrapped engine call runs per device, chunking its local
   slab by `b_chunk` exactly like the sequential path — same compiled
   kernel shapes, same per-row arithmetic, hence bit-identical event
   times (the single-host sweep remains the equivalence oracle).

Under multi-process JAX (`jax.distributed.initialize` before any jax
import, then the same `dse.sweep(space, sharding=mesh)` call on every
host), the mesh spans all hosts and each process computes only its
addressable shards; operands are assembled per-shard from the
(host-replicated) lowered space via `jax.make_array_from_callback`.

Run `python -m repro.launch.shard --smoke` (with
`XLA_FLAGS=--xla_force_host_platform_device_count=N`) for the
sharded-vs-single-host bit-equivalence smoke `tools/ci_check.sh` uses.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import contracts, transient
from ..core.transient import (B_ALIGN, DT_NS, FusedOperands, N_ACT_STEPS,
                              N_PRE_STEPS, N_RESTORE_STEPS, RowCycleResult)
from ..kernels import ops
from .mesh import make_sweep_mesh

__all__ = [
    "sweep_sharding", "batch_sharding", "put_global",
    "row_cycle_fused_sharded", "simulate_row_cycle_sharded",
    "sharded_sweep_columns", "sharded_pareto_dominated",
    "sharded_sweep",
]


def _as_mesh(sharding) -> Mesh:
    """Normalize a `sharding=` argument (Mesh | NamedSharding | None).

    A `NamedSharding` must be equivalent to the canonical batch-axis
    sharding of its mesh — the driver always distributes the flat batch
    over the FULL device product, so a partial-axis spec would silently
    place operands differently than the caller asked; reject it instead.
    """
    if sharding is None:
        return make_sweep_mesh()
    if isinstance(sharding, NamedSharding):
        mesh = sharding.mesh
        canonical = NamedSharding(mesh, P(mesh.axis_names))
        if not sharding.is_equivalent_to(canonical, 2):
            raise ValueError(
                f"sharding spec {sharding.spec} does not shard the batch "
                f"axis over the mesh's full device product; pass the mesh "
                f"itself (or sweep_sharding(mesh) == {canonical.spec}) — "
                "partial-axis placement is not supported by the sweep "
                "driver")
        return mesh
    if isinstance(sharding, Mesh):
        return sharding
    raise TypeError(
        f"sharding must be a jax Mesh or NamedSharding, got {sharding!r}")


def sweep_sharding(sharding=None) -> NamedSharding:
    """The canonical sweep sharding: batch axis over ALL mesh axes.

    Accepts a Mesh (or None for a fresh all-device `make_sweep_mesh()`)
    and returns the `NamedSharding` that splits axis 0 over the mesh's
    full device product — regardless of how many named axes the mesh has.
    """
    mesh = _as_mesh(sharding)
    return NamedSharding(mesh, P(mesh.axis_names))


# `DesignBatch.device_put` alias for readers coming from core.batch docs
batch_sharding = sweep_sharding


def put_global(x, sharding: NamedSharding):
    """Place one (B, ...) array with the sweep sharding.

    Single-process: a plain `jax.device_put`.  Multi-process: every host
    holds the full lowered operand batch (the DesignSpace lowering is
    deterministic and host-replicated), so the global array is assembled
    from the local copy one addressable shard at a time.
    """
    x = jnp.asarray(x)
    if jax.process_count() == 1:
        return jax.device_put(x, sharding)
    return jax.make_array_from_callback(
        x.shape, sharding, lambda idx: np.asarray(x[idx]))


def _dispatch_target(b: int, n_dev: int, b_chunk: int) -> int:
    """Padded batch size: identical per-device slabs, each a B_ALIGN
    multiple; slabs larger than `b_chunk` hold a whole number of chunks
    so in-device chunking never exceeds the memory bound."""
    slab = -(-b // n_dev)
    quantum = b_chunk if slab > b_chunk else B_ALIGN
    slab = -(-slab // quantum) * quantum
    return max(slab, B_ALIGN) * n_dev


@functools.lru_cache(maxsize=None)
def _sharded_engine(mesh: Mesh, backend: str, b_chunk: int):
    """jit(shard_map(...)) of the fused engine, cached per (mesh, backend,
    chunk).  Each device chunks its local slab by `b_chunk` — the same
    fixed compiled shapes as the sequential `_row_cycle_fused_chunked`
    loop, so per-row results are identical.  Multi-chunk slabs run the
    chunks through `lax.map` (one traced body, sequential execution per
    device), so trace/compile cost stays O(one chunk) however large the
    grid — not O(slab / b_chunk) unrolled calls."""
    spec = P(mesh.axis_names, None)

    def one_chunk(args):
        return ops.row_cycle_fused(*args, DT_NS, N_ACT_STEPS,
                                   N_RESTORE_STEPS, N_PRE_STEPS,
                                   backend=backend)

    def device_fn(c, g, gc_res, gc_pre, v0, params):
        slab = c.shape[0]
        step = min(b_chunk, slab)
        args = (c, g, gc_res, gc_pre, v0, params)
        if step == slab:
            return one_chunk(args)
        chunked = tuple(x.reshape(slab // step, step, *x.shape[1:])
                        for x in args)
        evt, v_end = jax.lax.map(one_chunk, chunked)
        return (evt.reshape(slab, *evt.shape[2:]),
                v_end.reshape(slab, *v_end.shape[2:]))

    return jax.jit(shard_map(device_fn, mesh=mesh, in_specs=(spec,) * 6,
                             out_specs=(spec, spec), check_rep=False))


def row_cycle_fused_sharded(operands, sharding=None, backend: str = "auto",
                            b_chunk: int = transient.DEFAULT_B_CHUNK):
    """Sharded fused row-cycle dispatch -> (events (B, 4), v_end (B, N)).

    `operands` is a `FusedOperands` or the raw 6-tuple of kernel operand
    arrays; `sharding` is a Mesh / NamedSharding (None = all devices).
    Each device evaluates its own padded slab of the batch; the outputs
    are sliced back to the caller's B rows.
    """
    b_chunk = transient.validate_b_chunk(b_chunk)
    mesh = _as_mesh(sharding)
    sharding = sweep_sharding(mesh)
    n_dev = int(mesh.devices.size)
    core = list(operands[:6])
    b = core[0].shape[0]
    target = _dispatch_target(b, n_dev, b_chunk)
    padded = transient._pad_operands(core, target - b)
    padded = [put_global(x, sharding) for x in padded]
    evt, v_end = _sharded_engine(mesh, backend, b_chunk)(*padded)
    return evt[:b], v_end[:b]


def simulate_row_cycle_sharded(operands: FusedOperands, sharding=None,
                               backend: str = "auto",
                               b_chunk: int = transient.DEFAULT_B_CHUNK,
                               ) -> RowCycleResult:
    """Sharded twin of `transient.simulate_row_cycle_lowered`.

    Same lowered `FusedOperands` in, same trace-free `RowCycleResult`
    out — but the engine dispatch is distributed over the mesh instead of
    looping chunks on one device.  `dse.sweep(space, sharding=...)` calls
    this; the sequential path stays bit-identical and is the oracle.
    """
    contracts.check_operands(operands, where="shard.simulate_row_cycle_sharded")
    evt, _ = row_cycle_fused_sharded(operands, sharding, backend, b_chunk)
    return transient.result_from_events(operands, evt)


@functools.lru_cache(maxsize=None)
def _sharded_scorer(mesh: Mesh):
    """jit(shard_map(...)) of the sweep's rollup+score program, cached per
    mesh.  The body is `dse.score_from_events` — the IDENTICAL function
    the sequential `finalize_sweep` runs under a plain `jax.jit` — so the
    per-row arithmetic (and hence every scored column) is bit-identical;
    only the slab placement differs.  All per-row ops are elementwise, so
    no cross-device communication happens here at all."""
    from ..core import dse
    axis = mesh.axis_names
    in_specs = (P(axis), P(axis), P(axis), P(axis), P(axis, None))
    return jax.jit(shard_map(dse.score_from_events, mesh=mesh,
                             in_specs=in_specs, out_specs=P(axis),
                             check_rep=False))


def _gather_columns(cols: dict, b: int) -> dict:
    """Slice scored column shards back to the caller's B rows.

    Fully-addressable results (single process, or a multi-process run
    dispatching on its local mesh): lazy slices of the sharded arrays —
    the only host-side materialization of the whole sweep, (B,) per
    column.  Results sharded across processes: every process needs the
    full columns to assemble an identical `DesignBatch`, so the
    addressable shards are allgathered first.
    """
    gathered = {}
    for k, v in cols.items():
        if not getattr(v, "is_fully_addressable", True):
            from jax.experimental import multihost_utils
            v = np.asarray(multihost_utils.process_allgather(v, tiled=True))
        gathered[k] = v[:b]
    return gathered


def sharded_sweep_columns(plan, sharding=None, backend: str = "auto",
                          b_chunk: int = transient.DEFAULT_B_CHUNK,
                          rows: tuple[int, int] | None = None) -> dict:
    """Device-side scored columns for a planned sweep -> dict of (B,) arrays.

    The end-to-end sharded pipeline of `dse.sweep(space, sharding=...)`:
    pad the plan's operand batch to identical per-device slabs, run the
    fused engine under `shard_map` (`_sharded_engine`), keep the raw
    event columns ON DEVICE as a sharded global array, and run the
    rollup+score program (`dse.score_from_events`) as a second sharded
    dispatch over the same slabs — no (B, N)-scale intermediate and no
    per-metric array ever materializes host-side.  Returns the
    `dse.score_columns` dict, sliced to the plan's design-point count,
    ready for `dse.assemble_batch`.

    `rows=(lo, hi)` restricts the dispatch to the design-point slab
    [lo, hi) — the elastic re-slabbing unit (`launch.elastic`): a slab's
    columns are computed on whatever mesh the survivors form, and
    concatenating slab columns in order reproduces the full-range result
    bit-identically (per-row arithmetic is slab-shape independent).
    On replica spaces the operand rows are the interleaved
    [replica, main] pairs of the point range (alignment is safe: every
    slab boundary is even, B_ALIGN being so).
    """
    from ..core.space import SpaceView
    b_chunk = transient.validate_b_chunk(b_chunk)
    mesh = _as_mesh(sharding)
    sharding = sweep_sharding(mesh)
    operands = plan.operands
    contracts.check_operands(operands, where="shard.sharded_sweep_columns")
    factor = 2 if operands.replica else 1
    view = SpaceView.from_lowered(plan.sp)
    cbl = jnp.asarray(plan.par.c_bl_total_ff, jnp.float32)
    sa_tau, overhead = operands.sa_tau_ns, operands.t_overhead_ns
    core = list(operands[:6])
    lo, hi = (0, len(view)) if rows is None else rows
    if not (0 <= lo <= hi <= len(view)):
        raise ValueError(f"rows={rows} outside the plan's design-point "
                         f"range [0, {len(view)})")
    if rows is not None:
        view = view.slice_rows(lo, hi)
        cbl = cbl[lo:hi]
        core = [x[factor * lo:factor * hi] for x in core]
        sa_tau = sa_tau[factor * lo:factor * hi]
        overhead = overhead[factor * lo:factor * hi]

    n_dev = int(mesh.devices.size)
    b_ops = core[0].shape[0]
    b_pts = hi - lo
    target_ops = _dispatch_target(b_ops, n_dev, b_chunk)
    pad_ops = target_ops - b_ops
    target_pts = target_ops // factor

    padded = transient._pad_operands(core, pad_ops)
    padded = [put_global(x, sharding) for x in padded]
    evt, _ = _sharded_engine(mesh, backend, b_chunk)(*padded)

    sa_tau = jnp.pad(sa_tau, (0, pad_ops), constant_values=1.0)
    overhead = jnp.pad(overhead, (0, pad_ops), constant_values=0.0)
    view = jax.tree.map(lambda x: put_global(x, sharding),
                        view.pad_to(target_pts))
    cbl = put_global(jnp.pad(cbl, (0, target_pts - b_pts),
                             constant_values=1.0), sharding)
    sa_tau = put_global(sa_tau, sharding)
    overhead = put_global(overhead, sharding)
    cols = _sharded_scorer(mesh)(view, cbl, sa_tau, overhead, evt)
    return _gather_columns(cols, b_pts)


@functools.lru_cache(maxsize=None)
def _sharded_pareto_engine(mesh: Mesh, block: int):
    """jit(shard_map(...)) of the Pareto dominance test, cached per
    (mesh, block).  Each device sweeps ITS dominator slab over the full
    (replicated) candidate batch in `block`-row sub-blocks — the exact
    masked-broadcast body of the sequential `dse.pareto_mask` loop — and
    the per-device dominated masks OR-reduce across the mesh.  Dominance
    is pure comparisons + boolean algebra (no rounding anywhere) and OR
    is commutative, so the reduced mask is bit-identical to the
    sequential block loop's."""
    axis = mesh.axis_names

    def device_fn(hi_d, lo_d, cand_d, hi, lo, cand):
        b = hi.shape[0]
        dominated = jnp.zeros((b,), bool)
        nloc = hi_d.shape[0]
        for i0 in range(0, nloc, block):   # dominator sub-blocks (static)
            hi_i, lo_i = hi_d[i0:i0 + block], lo_d[i0:i0 + block]
            cand_i = cand_d[i0:i0 + block]
            ge = ((hi_i[:, None, :] >= hi[None, :, :]).all(-1)
                  & (lo_i[:, None, :] <= lo[None, :, :]).all(-1))
            gt = ((hi_i[:, None, :] > hi[None, :, :]).any(-1)
                  | (lo_i[:, None, :] < lo[None, :, :]).any(-1))
            dominated |= (ge & gt & cand_i[:, None] & cand[None, :]).any(axis=0)
        return jax.lax.psum(dominated.astype(jnp.int32), axis) > 0

    in_specs = (P(axis, None), P(axis, None), P(axis), P(), P(), P())
    return jax.jit(shard_map(device_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=P(), check_rep=False))


def sharded_pareto_dominated(hi, lo, cand, sharding=None,
                             block: int = 4096) -> jnp.ndarray:
    """Sharded dominated-mask for `dse.pareto_mask` -> (B,) bool.

    `hi` / `lo` are the stacked (B, K) maximize/minimize objective
    columns and `cand` the (B,) candidate mask.  The dominator axis is
    padded to identical per-device slabs (padding rows carry cand=False,
    so they dominate nothing) and each device tests its slab against the
    full batch; a cross-device OR-reduce merges the verdicts.  NaN
    objectives compare False in every direction, so NaN rows neither
    dominate nor get spuriously dominated — exactly the sequential
    semantics.
    """
    mesh = _as_mesh(sharding)
    sharding = sweep_sharding(mesh)
    replicated = NamedSharding(mesh, P())
    n_dev = int(mesh.devices.size)
    hi = jnp.asarray(hi)
    lo = jnp.asarray(lo)
    cand = jnp.asarray(cand)
    b = int(hi.shape[0])
    pad = -(-b // n_dev) * n_dev - b
    hi_d = put_global(jnp.pad(hi, ((0, pad), (0, 0))), sharding)
    lo_d = put_global(jnp.pad(lo, ((0, pad), (0, 0))), sharding)
    cand_d = put_global(jnp.pad(cand, (0, pad)), sharding)
    full = [put_global(x, replicated) for x in (hi, lo, cand)]
    # out_specs=P() -> the mask comes back fully replicated, so it is
    # addressable (and identical) on every process — no gather needed.
    return _sharded_pareto_engine(mesh, int(block))(hi_d, lo_d, cand_d, *full)


def sharded_sweep(space=None, mesh=None, **sweep_kwargs):
    """`dse.sweep` over a device mesh (all local devices by default).

    Thin convenience wrapper:  `sharded_sweep(space)` ==
    `dse.sweep(space, sharding=make_sweep_mesh())`.
    """
    from ..core import dse
    return dse.sweep(space, sharding=sweep_sharding(mesh), **sweep_kwargs)


# ---------------------------------------------------------------------------
# Bit-equivalence smoke (tools/ci_check.sh runs this under forced devices)
# ---------------------------------------------------------------------------

def _equivalence_smoke(mc_samples: int = 16,
                       expect_devices: int | None = None) -> None:
    import time

    from ..core import dse
    from ..core.batch import ARRAY_FIELDS
    from ..core.space import DesignSpace

    mesh = make_sweep_mesh()
    n_dev = int(mesh.devices.size)
    if expect_devices is not None and n_dev != expect_devices:
        raise SystemExit(
            f"expected {expect_devices} devices but found {n_dev} — the "
            "forced host device count was lost (XLA_FLAGS must be set "
            "before the first jax import); a 1-device equivalence check "
            "would be near-tautological, refusing to fake an OK")

    def check(space, label):
        t0 = time.perf_counter()
        sharded = dse.sweep(space, sharding=mesh)
        dt = time.perf_counter() - t0
        seq = dse.sweep(space)
        bad = [f for f in ARRAY_FIELDS
               if not np.array_equal(np.asarray(getattr(sharded, f)),
                                     np.asarray(getattr(seq, f)))]
        bad += [f"corners[{k}]" for k in seq.corners
                if not np.array_equal(np.asarray(sharded.corners[k]),
                                      np.asarray(seq.corners[k]))]
        if bad:
            raise SystemExit(f"sharded sweep NOT bit-identical on {label}: "
                             f"mismatched fields {bad}")
        print(f"{label}: {len(seq)} points on {n_dev} device(s) in "
              f"{dt:.2f}s — bit-identical to the single-host sweep")

    check(DesignSpace.paper_grid(), "paper grid")
    check(DesignSpace.paper_grid().with_mc(samples=mc_samples, key=0),
          f"paper grid x {mc_samples} MC samples")
    check(DesignSpace.paper_targets().with_replica()
          .with_mc(samples=mc_samples, key=0),
          f"replica-closed targets x {mc_samples} MC samples")
    print("shard smoke: OK")


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="sharded-vs-single-host bit-equivalence check")
    parser.add_argument("--mc", type=int, default=16,
                        help="MC samples for the smoke's with_mc sweep")
    parser.add_argument("--expect-devices", type=int, default=None,
                        help="fail unless exactly this many devices are "
                             "visible (guards CI against losing the "
                             "forced host device count)")
    args = parser.parse_args()
    if args.smoke:
        _equivalence_smoke(mc_samples=args.mc,
                          expect_devices=args.expect_devices)
    else:
        parser.print_help()
