"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state.  The production topology is a TPU v5e
pod of 16x16 = 256 chips; the multi-pod configuration is 2 pods = 512
chips with the "pod" axis outermost (DCN/ICI-sparse boundary — the HCB
interface of DESIGN.md §2.2).
"""

from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dryrun.py must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import)")
    # more devices than needed (single-pod mesh inside the 512-device
    # dry-run process): use the first n.
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_sweep_mesh(n_devices: int | None = None):
    """Flat 1-D "batch" mesh for sharded DSE sweeps (`repro.launch.shard`).

    The DSE batch axis is the only sharded axis, so the sweep mesh is
    simply every device on one axis.  Under
    multi-process JAX (`jax.distributed.initialize`), `jax.devices()`
    spans every host, so the same call builds the global sweep mesh on
    each host — each process then feeds only its addressable shards.
    """
    import jax
    from jax.sharding import Mesh
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devices):
        raise RuntimeError(f"need 1..{len(devices)} devices, asked for {n}")
    return Mesh(np.asarray(devices[:n]), ("batch",))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for unit tests (requires forced host device count)."""
    import jax
    from jax.sharding import Mesh
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh():
    """Single-device mesh with the production axis names (CPU smoke runs)."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
