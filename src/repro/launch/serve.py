"""Co-design-as-a-service launcher: serve DSE sweep/yield queries from
one warm micro-batching engine (`serving.dse_service.DSEService`).

    # one-shot: serve JSON requests (repeat --request, or a JSONL file)
    PYTHONPATH=src python -m repro.launch.serve \
        --request '{"kind": "sweep", "techs": ["aos"], "layers": [4, 8]}' \
        --request '{"kind": "yield", "mc": {"samples": 256}, \
                    "spec": {"margin_mv": 5.0}}'

    # CI smoke: warm engine, 2 concurrent clients -> ONE fused dispatch,
    # results bit-identical to direct dse.sweep, repeat query memo-hit
    PYTHONPATH=src python -m repro.launch.serve --smoke

Every request queued in one invocation is served through the same
micro-batch window machinery concurrent clients would share: cache
misses pack into one fused dispatch per window, repeats answer from the
LRU memo.  Responses print as one JSON line per request (summary
scalars); `--json` writes the full per-request records plus the
service's `stats()` block.

Request schema (all keys optional except none; unknown keys rejected):

    kind           "sweep" (default) | "yield"
    techs          registered technology names (default: all)
    schemes        routing scheme names (default: per-tech allowed set)
    layers         layer counts to sweep (default: registry grid)
    corners        {axis: [values, ...]} corner fan-out
    mc             {"samples": N, "key": K, ...} Monte-Carlo declaration
                   (required for kind="yield"; extra keys pass through
                   to DesignSpace.with_mc)
    replica        true -> replica-closed SA timing
    with_transient false -> skip the transient engine (static metrics)
    spec           mc_summary kwargs for kind="yield" (margin_mv, ...)

Exit codes follow the `tools/bench_check.py` convention: 0 = all
requests served, 1 = a served request failed in the engine, 2 = a
malformed request (validation error, bad JSON, unreadable file).
"""

from __future__ import annotations

import argparse
import json
import sys

EXIT_OK = 0
EXIT_FAIL = 1
EXIT_BAD_REQUEST = 2

REQUEST_KEYS = ("kind", "techs", "schemes", "layers", "corners", "mc",
                "replica", "with_transient", "spec")


class RequestError(Exception):
    """A request the service must reject: malformed JSON, unknown keys,
    unregistered names, invalid values.  Maps to exit code 2."""


def _structured_error(code: str, message: str, request=None) -> None:
    """One machine-readable error record on stderr (never a raw
    traceback — the launch/serve contract for malformed input)."""
    err = {"error": {"code": code, "message": message}}
    if request is not None:
        err["error"]["request"] = request
    print(json.dumps(err), file=sys.stderr)


def parse_request(obj):
    """Validate one JSON request object -> (kind, DesignSpace, spec).

    Names are validated through the registries' raising accessors
    (`cal.get_tech`, `routing.scheme_spec`) — an unknown name fails here
    with a `RequestError`, before anything is queued.
    """
    from ..core import calibration as cal
    from ..core import routing
    from ..core.space import DesignSpace

    if not isinstance(obj, dict):
        raise RequestError(f"request must be a JSON object, got "
                           f"{type(obj).__name__}")
    unknown = sorted(k for k in obj if k not in REQUEST_KEYS)
    if unknown:
        raise RequestError(f"unknown request key(s) {unknown}; "
                           f"allowed: {list(REQUEST_KEYS)}")

    kind = obj.get("kind", "sweep")
    techs = obj.get("techs")
    schemes = obj.get("schemes")
    layers = obj.get("layers")
    if techs is not None:
        if not isinstance(techs, list) or not techs:
            raise RequestError("'techs' must be a non-empty list of "
                               "registered technology names")
        for name in techs:
            try:
                cal.get_tech(name)
            except (KeyError, TypeError) as e:
                raise RequestError(f"bad tech in request: {e}") from None
    if schemes is not None:
        if not isinstance(schemes, list) or not schemes:
            raise RequestError("'schemes' must be a non-empty list of "
                               "routing scheme names")
        for name in schemes:
            try:
                routing.scheme_spec(name)
            except (ValueError, TypeError) as e:
                raise RequestError(f"bad scheme in request: {e}") from None
    if layers is not None:
        if (not isinstance(layers, list) or not layers
                or not all(isinstance(n, int) and not isinstance(n, bool)
                           and n >= 1 for n in layers)):
            raise RequestError("'layers' must be a non-empty list of "
                               "positive integers")
        layers = tuple(layers)

    try:
        space = DesignSpace.product(techs=techs, schemes=schemes,
                                    layers=layers)
        corners = obj.get("corners", {})
        if corners:
            if not isinstance(corners, dict):
                raise RequestError("'corners' must be an object "
                                   "{axis: [values, ...]}")
            space = space.with_corners(
                **{k: tuple(v) if isinstance(v, list) else (v,)
                   for k, v in corners.items()})
        mc = obj.get("mc")
        if mc is not None:
            if not isinstance(mc, dict) or "samples" not in mc:
                raise RequestError("'mc' must be an object with at least "
                                   "{'samples': N}")
            space = space.with_mc(**mc)
        if obj.get("replica", False):
            space = space.with_replica()
    except RequestError:
        raise
    except (TypeError, ValueError, KeyError) as e:
        raise RequestError(f"invalid request: {e}") from None

    spec = obj.get("spec", {})
    if not isinstance(spec, dict):
        raise RequestError("'spec' must be an object of mc_summary "
                           "keyword arguments")
    return kind, space, spec


def _summarize(i, req, resp) -> dict:
    """One JSON-serializable response record (summary scalars, not the
    full batch — use the library API for arrays)."""
    import numpy as np

    batch = resp.batch
    feasible = np.asarray(batch.feasible & batch.valid)
    rec = {
        "request": i,
        "kind": req.get("kind", "sweep"),
        "rows": len(batch),
        "feasible": int(feasible.sum()),
        "memo_hit": bool(resp.memo_hit),
        "elapsed_ms": round(resp.elapsed_ms, 3),
    }
    if feasible.any():
        dens = np.asarray(batch.density_gb_mm2)
        trc = np.asarray(batch.trc_ns)
        rec["max_density_gb_mm2"] = float(dens[feasible].max())
        if np.isfinite(trc[feasible]).any():
            rec["min_trc_ns"] = float(np.nanmin(trc[feasible]))
    if resp.summary is not None:
        yf = np.asarray(resp.summary.corners["yield_frac"])
        rec["yield"] = {
            "designs": len(resp.summary),
            "min_yield_frac": float(yf.min()),
            "max_yield_frac": float(yf.max()),
        }
    return rec


def _load_requests(args) -> list[dict]:
    """Collect request objects from --request strings and --requests-file
    (a JSON array, or one JSON object per line)."""
    objs = []
    for raw in args.request or ():
        try:
            objs.append(json.loads(raw))
        except json.JSONDecodeError as e:
            raise RequestError(f"--request is not valid JSON: {e}") from None
    if args.requests_file:
        try:
            with open(args.requests_file) as fh:
                text = fh.read()
        except OSError as e:
            raise RequestError(f"cannot read requests file: {e}") from None
        stripped = text.lstrip()
        try:
            if stripped.startswith("["):
                loaded = json.loads(text)
                if not isinstance(loaded, list):
                    raise RequestError("requests file: top-level JSON "
                                       "must be an array or JSONL")
                objs.extend(loaded)
            else:
                objs.extend(json.loads(line)
                            for line in text.splitlines() if line.strip())
        except json.JSONDecodeError as e:
            raise RequestError(
                f"requests file is not valid JSON/JSONL: {e}") from None
    return objs


def serve_requests(objs, args) -> int:
    """Queue every request on one warm engine, flush as micro-batch
    windows, print one summary line per response."""
    from ..serving.dse_service import DSEService

    parsed = []
    for i, obj in enumerate(objs):
        try:
            parsed.append(parse_request(obj))
        except RequestError as e:
            _structured_error("bad_request", str(e), request=i)
            return EXIT_BAD_REQUEST

    svc = DSEService(window_ms=args.window_ms, memo_entries=args.memo,
                     b_chunk=args.b_chunk)
    futures = [svc.submit(space, kind=kind, spec=spec)
               for kind, space, spec in parsed]
    svc.flush()

    status = EXIT_OK
    records = []
    for i, (obj, fut) in enumerate(zip(objs, futures)):
        try:
            resp = fut.result(timeout=0)
        except (ValueError, TypeError, KeyError) as e:
            _structured_error("bad_request", str(e), request=i)
            return EXIT_BAD_REQUEST
        except Exception as e:
            _structured_error("serve_failed",
                              f"{type(e).__name__}: {e}", request=i)
            status = EXIT_FAIL
            continue
        rec = _summarize(i, obj, resp)
        records.append(rec)
        print(json.dumps(rec))
    stats = svc.stats()
    if args.stats:
        print(json.dumps({"stats": stats}))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"responses": records, "stats": stats}, f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    return status


def _batches_identical(a, b) -> bool:
    """NaN-aware bit-identity over every array field + corner channel."""
    import numpy as np

    from ..core.batch import ARRAY_FIELDS

    def eq(x, y):
        x, y = np.asarray(x), np.asarray(y)
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if x.dtype.kind == "f":
            return bool(((x == y) | (np.isnan(x) & np.isnan(y))).all())
        return bool((x == y).all())

    return (set(a.corners) == set(b.corners)
            and all(eq(getattr(a, f), getattr(b, f)) for f in ARRAY_FIELDS)
            and all(eq(a.corners[k], b.corners[k]) for k in a.corners))


def _smoke(window_ms: float) -> None:
    """The ci_check serving smoke: a warm engine serving two concurrent
    clients' mixed sweep/yield queries from ONE shared fused dispatch,
    bit-identical to direct `dse.sweep`, with a memo hit on repeat."""
    import threading
    import time

    from ..core import dse
    from ..core.space import DesignSpace
    from ..serving.dse_service import DSEService

    svc = DSEService(window_ms=window_ms)
    t0 = time.perf_counter()
    svc.warm()
    print(f"warm-up sweep compiled in {time.perf_counter() - t0:.2f}s")

    # two concurrent clients (real threads, barrier-synchronized), mixed
    # query kinds, submitted into the same micro-batch window
    s_sweep = DesignSpace.product(techs=["aos"], layers=(4, 8, 16))
    s_yield = DesignSpace.paper_targets().with_mc(samples=32, key=1)
    before = svc.stats()
    barrier = threading.Barrier(2)
    futures = {}

    def client(name, submit):
        barrier.wait()
        futures[name] = submit()

    threads = [
        threading.Thread(target=client, args=(
            "sweep", lambda: svc.submit(s_sweep))),
        threading.Thread(target=client, args=(
            "yield", lambda: svc.submit(
                s_yield, kind="yield", spec={"margin_mv": 5.0}))),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.flush()
    after = svc.stats()
    if after["windows"] - before["windows"] != 1:
        raise SystemExit("serve smoke: expected ONE micro-batch window, "
                         f"got {after['windows'] - before['windows']}")
    if after["dispatches"] - before["dispatches"] != 1:
        raise SystemExit(
            "serve smoke: 2 concurrent clients did NOT share one fused "
            f"dispatch (got {after['dispatches'] - before['dispatches']})")

    r_sweep = futures["sweep"].result(timeout=0)
    r_yield = futures["yield"].result(timeout=0)
    if not _batches_identical(r_sweep.batch, dse.sweep(s_sweep)):
        raise SystemExit("serve smoke: packed sweep response is NOT "
                         "bit-identical to direct dse.sweep")
    if not _batches_identical(r_yield.batch, dse.sweep(s_yield)):
        raise SystemExit("serve smoke: packed yield response is NOT "
                         "bit-identical to direct dse.sweep")
    if r_yield.summary is None or "yield_frac" not in r_yield.summary.corners:
        raise SystemExit("serve smoke: yield query returned no summary")
    print(f"window smoke: 2 clients, 1 dispatch "
          f"({after['rows']['dispatched'] - before['rows']['dispatched']} "
          "packed rows), responses bit-identical to direct sweeps")

    # repeat query: answered from the memo, no new dispatch
    f_again = svc.submit(s_sweep)
    svc.flush()
    r_again = f_again.result(timeout=0)
    final = svc.stats()
    if not r_again.memo_hit:
        raise SystemExit("serve smoke: repeated query was not a memo hit")
    if final["dispatches"] != after["dispatches"]:
        raise SystemExit("serve smoke: repeated query re-dispatched "
                         "instead of answering from the memo")
    if not _batches_identical(r_again.batch, r_sweep.batch):
        raise SystemExit("serve smoke: memo hit returned a different batch")

    # background dispatcher liveness: blocking clients through the thread
    with DSEService(window_ms=window_ms) as bg:
        live = bg.sweep(s_sweep, timeout=60.0)
    if not _batches_identical(live, r_sweep.batch):
        raise SystemExit("serve smoke: dispatcher-thread result diverged")
    print(f"memo smoke: repeat answered from memo "
          f"(hit rate {final['memo']['hit_rate']:.2f}, "
          f"{final['dispatches']} dispatches for {final['requests']} "
          "requests)")
    print("serve smoke: OK")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--request", action="append",
                    help="one JSON request object (repeatable)")
    ap.add_argument("--requests-file",
                    help="JSON array or JSONL file of request objects")
    ap.add_argument("--window-ms", type=float, default=3.0,
                    help="micro-batch window length")
    ap.add_argument("--memo", type=int, default=64,
                    help="LRU memo capacity (entries; 0 disables)")
    ap.add_argument("--b-chunk", type=int, default=None,
                    help="fused-engine chunk size (B_ALIGN multiple)")
    ap.add_argument("--stats", action="store_true",
                    help="print the service stats() block after serving")
    ap.add_argument("--json", help="write full responses + stats to a file")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: concurrent shared dispatch + memo + "
                         "bit-identity checks")
    args = ap.parse_args(argv)

    if args.b_chunk is None:
        from ..core.transient import DEFAULT_B_CHUNK
        args.b_chunk = DEFAULT_B_CHUNK

    if args.smoke:
        _smoke(window_ms=args.window_ms)
        return EXIT_OK

    try:
        objs = _load_requests(args)
    except RequestError as e:
        _structured_error("bad_request", str(e))
        return EXIT_BAD_REQUEST
    if not objs:
        ap.print_help()
        return EXIT_OK
    return serve_requests(objs, args)


if __name__ == "__main__":
    sys.exit(main())
