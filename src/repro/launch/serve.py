"""Serving launcher: batched prefill+decode with dense or StrapCache
back-end.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 --cache strap --top-straps 2
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache", default="dense", choices=["dense", "strap"])
    ap.add_argument("--top-straps", type=int, default=0,
                    help="0 = exact; k>0 = gated selector (paper analogue)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages-per-strap", type=int, default=2)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs.registry import get_arch
    from ..memory.strap_cache import StrapCacheConfig
    from ..models import registry as M
    from ..serving.engine import ServeEngine

    cfg = get_arch(args.arch + ("-smoke" if args.smoke else ""))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    eng = ServeEngine(
        cfg, params, max_tokens=args.prompt_len + args.new_tokens + 8,
        cache_backend=args.cache,
        strap_cfg=StrapCacheConfig(page_size=args.page_size,
                                   pages_per_strap=args.pages_per_strap,
                                   top_straps=args.top_straps))
    t0 = time.time()
    out = eng.generate(jax.numpy.asarray(prompts), args.new_tokens)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, backend={args.cache})")
    if args.cache == "strap":  # repro-lint: disable=RL001  (KV-cache backend id, not a routing-scheme name)
        s = eng.stats
        print(f"HBM traffic vs dense: {100 * s.traffic_reduction:.1f}% "
              f"(gated {s.hbm_bytes_gated / 1e6:.1f} MB / "
              f"dense {s.hbm_bytes_dense / 1e6:.1f} MB)")
    print("sample:", np.asarray(out[0, :16]).tolist())


if __name__ == "__main__":
    main()
