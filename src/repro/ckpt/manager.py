"""Checkpoint manager: atomic, async-capable, mesh-resharding restore.

Layout (one directory per step):
    <dir>/step_000123.tmp-<pid>/   -> written, fsynced, then renamed to
    <dir>/step_000123/
        manifest.json              tree structure, shapes, dtypes
        leaf_00000.npy ...         raw leaves (np.save, host-gathered)

Restore accepts a target mesh + PartitionSpec tree and `device_put`s each
leaf with its NamedSharding — this is what makes restarts *elastic*: a
checkpoint written on one mesh restores onto any other mesh whose specs
divide the shapes (at cluster scale this would be per-shard files; the
manifest format already records enough to extend to that).
"""

from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True):
        paths, leaves, treedef = _flatten_with_paths(tree)
        # host-gather while the originals are still alive; dtypes numpy
        # cannot serialize natively (bfloat16) travel as uint16 views
        host_leaves = []
        dtypes = []
        for x in leaves:
            arr = np.asarray(jax.device_get(x))
            dtypes.append(str(jnp.asarray([], x.dtype).dtype)
                          if hasattr(x, "dtype") else str(arr.dtype))
            if dtypes[-1] == "bfloat16":
                arr = arr.view(np.uint16)
            host_leaves.append(arr)
        meta = dict(step=step,
                    paths=paths,
                    shapes=[list(x.shape) for x in host_leaves],
                    dtypes=dtypes)

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host_leaves):
                np.save(tmp / f"leaf_{i:05d}.npy", arr)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)           # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.startswith("step_") and ".tmp" not in p.name:
                with contextlib.suppress(ValueError):
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, like=None, mesh=None,
                specs=None):
        """Restore a pytree.  `like` (a pytree of arrays/ShapeDtypeStructs)
        fixes the tree structure; `mesh`+`specs` reshard on load."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        arrays = [np.load(d / f"leaf_{i:05d}.npy")
                  for i in range(len(meta["paths"]))]
        if like is None:
            raise ValueError("restore requires `like` for tree structure")
        _, leaves, treedef = _flatten_with_paths(like)
        assert len(leaves) == len(arrays), "checkpoint/tree mismatch"
        out = []
        spec_leaves = (jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            if specs is not None else [None] * len(arrays))
        for arr, ref, sp, want in zip(arrays, leaves, spec_leaves,
                                      meta["dtypes"]):
            dt = ref.dtype
            if want == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            a = jnp.asarray(arr).astype(dt)
            if mesh is not None and sp is not None:
                a = jax.device_put(a, jax.sharding.NamedSharding(mesh, sp))
            out.append(a)
        return jax.tree.unflatten(treedef, out), step
