"""Deterministic, shard-aware token data pipeline.

Sources:
  SyntheticSource : structured pseudo-text (Zipf unigrams + local n-gram
                    structure so a small LM actually has something to
                    learn), deterministic in (seed, shard, index).
  MemmapSource    : flat binary token file (np.memmap), the production
                    path for tokenized corpora.

Loader semantics match multi-host training: each data shard reads a
disjoint slice by (shard_id, num_shards); batches are (tokens, targets)
with targets = next-token labels.  A background thread keeps a prefetch
queue full.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path

import numpy as np


class SyntheticSource:
    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def sequence(self, index: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, index))
        # Zipf unigram base
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=length + 1, p=probs)
        # inject learnable bigram structure: token t+1 = f(t) half the time
        follow = (toks[:-1] * 31 + 7) % self.vocab
        mask = rng.random(length) < 0.5
        toks[1:][mask] = follow[mask]
        return toks.astype(np.int32)


class MemmapSource:
    def __init__(self, path: str | Path, dtype=np.uint16):
        self.arr = np.memmap(path, dtype=dtype, mode="r")

    def sequence(self, index: int, length: int) -> np.ndarray:
        n = len(self.arr)
        start = (index * length) % max(n - length - 1, 1)
        return np.asarray(self.arr[start: start + length + 1], np.int32)

    @staticmethod
    def write(path: str | Path, tokens: np.ndarray, dtype=np.uint16):
        np.asarray(tokens, dtype).tofile(path)


@dataclass
class LoaderConfig:
    batch_size: int          # per-shard batch
    seq_len: int
    shard_id: int = 0
    num_shards: int = 1
    prefetch: int = 2
    seed: int = 0


class DataLoader:
    """Yields {"tokens": (B, S) int32, "targets": (B, S) int32} forever."""

    def __init__(self, source, cfg: LoaderConfig):
        self.source = source
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = 0
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._stop = threading.Event()
        self._thread.start()

    def _index(self, step: int, row: int) -> int:
        c = self.cfg
        return (step * c.num_shards + c.shard_id) * c.batch_size + row

    def _make(self, step: int) -> dict:
        c = self.cfg
        seqs = np.stack([self.source.sequence(self._index(step, r), c.seq_len)
                         for r in range(c.batch_size)])
        return dict(tokens=seqs[:, :-1].astype(np.int32),
                    targets=seqs[:, 1:].astype(np.int32))

    def _fill(self):
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def batch_at(self, step: int) -> dict:
        """Random access (deterministic restart support)."""
        return self._make(step)

    def close(self):
        self._stop.set()
