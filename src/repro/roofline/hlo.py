"""Parse collective traffic out of optimized HLO text.

`compiled.cost_analysis()` gives FLOPs and memory bytes but NOT collective
bytes; we recover those by scanning the post-SPMD HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and summing operand sizes.  Each op is attributed to the mesh axes its
replica groups span — in particular whether it crosses the pod boundary
(devices 0..255 vs 256..511), which is what the strapped-collective
analysis cares about.

The generic HLO-text scanning helpers at the bottom
(`scan_custom_call_targets` / `scan_f64_mentions` / `scan_constant_bytes`
/ `scan_host_transfer_ops`) are shared with `tools/flowcheck`'s dispatch
auditor, which asserts compiled-artifact invariants on the fused engine.
"""

from __future__ import annotations

import re
import warnings
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9_]+)\[[^\]]*\])?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str, unknown: dict | None = None) -> int:
    """Total bytes of every typed shape in `shape_str`.

    Shapes whose dtype token is not in `DTYPE_BYTES` contribute 0 bytes;
    pass `unknown` (a dtype -> count dict) to have them tallied instead of
    dropped without a trace.
    """
    total = 0
    for m in SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            if unknown is not None:
                unknown[dt] = unknown.get(dt, 0) + 1
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, pod_size: int = 256) -> dict:
    """Returns per-type byte totals + pod-crossing split.

    Bytes counted = output operand size of each collective op (the payload
    that actually moves once; all-reduce ~2x for ring but roofline uses the
    standard 2(n-1)/n model applied downstream).

    Ops the byte accounting cannot attribute are counted, not dropped:
    `unknown_dtypes` tallies shape tokens outside `DTYPE_BYTES` (their
    bytes are missing from the totals — a warning flags the undercount)
    and `async_done_ops` counts `-done`-form async completions (skipped
    on purpose: their `-start` halves carry the payload; the count lets a
    caller cross-check the pairing).
    """
    out = dict(by_type=defaultdict(int), cross_pod_bytes=0,
               in_pod_bytes=0, ops=0, async_done_ops=0)
    unknown: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if m is None:
            continue
        op = m.group(1).lower()
        if "-done(" in line:
            out["async_done_ops"] += 1
            continue  # the paired -start carries the payload bytes
        # output shape: the lhs "x[...] = <shape> op(...)" — take the first
        # shape on the line (the result type)
        head = line.split("=", 1)
        shape_src = head[1] if len(head) > 1 else line
        nbytes = _shape_bytes(shape_src.split("(", 1)[0], unknown)
        if nbytes == 0:
            # tuple result: fall back to everything before the op name
            nbytes = _shape_bytes(shape_src, unknown)
        out["by_type"][op] += nbytes
        out["ops"] += 1
        # replica-group span
        crosses = False
        gm = re.search(r"replica_groups=\{(.*?)\}\s*(?:,|$)", line)
        if gm:
            groups = re.findall(r"\{([0-9,]+)\}", gm.group(0))
            for g in groups:
                ids = [int(x) for x in g.split(",") if x]
                if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                    crosses = True
                    break
        else:
            # iota-style v2 groups: [N,M]<=[...] form
            gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                            r"(?:T\(([0-9,]+)\))?", line)
            if gm2:
                ngroups, gsize = int(gm2.group(1)), int(gm2.group(2))
                dims = [int(x) for x in gm2.group(3).split(",")]
                perm = gm2.group(4)
                # reconstruct the device list and group assignment
                import numpy as np
                arr = np.arange(int(np.prod(dims))).reshape(dims)
                if perm:
                    arr = arr.transpose([int(x) for x in perm.split(",")])
                arr = arr.reshape(ngroups, gsize)
                for row in arr:
                    if (row.max() // pod_size) != (row.min() // pod_size):
                        crosses = True
                        break
        if crosses:
            out["cross_pod_bytes"] += nbytes
        else:
            out["in_pod_bytes"] += nbytes
    out["by_type"] = dict(out["by_type"])
    out["total_bytes"] = sum(out["by_type"].values())
    out["unknown_dtypes"] = unknown
    if unknown:
        warnings.warn(
            f"parse_collectives: {sum(unknown.values())} collective "
            f"operand shape(s) with dtype(s) {sorted(unknown)} are not in "
            "DTYPE_BYTES and were excluded from the byte totals — the "
            "roofline collective bytes are an undercount",
            stacklevel=2)
    if out["async_done_ops"]:
        warnings.warn(
            f"parse_collectives: skipped {out['async_done_ops']} "
            "'-done'-form async completion op(s); their '-start' halves "
            "carry the payload bytes (see async_done_ops in the result)",
            stacklevel=2)
    return out


# ---------------------------------------------------------------------------
# Generic compiled-artifact scans (shared with tools/flowcheck)
# ---------------------------------------------------------------------------

CUSTOM_CALL_RE = re.compile(r'custom_call_target="([^"]+)"')
F64_RE = re.compile(r"\bf64\[")
CONSTANT_RE = re.compile(r"=\s*([^=]*?)\bconstant\(")
HOST_TRANSFER_RE = re.compile(r"\b(infeed|outfeed|send|send-done|"
                              r"recv|recv-done)\(")


def scan_custom_call_targets(hlo_text: str) -> dict:
    """custom_call_target -> occurrence count over the HLO text."""
    out: dict[str, int] = {}
    for m in CUSTOM_CALL_RE.finditer(hlo_text):
        out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


def scan_f64_mentions(hlo_text: str, limit: int = 8) -> list:
    """Lines mentioning an f64 shape (silent-promotion probe); at most
    `limit` samples, stripped."""
    hits = []
    for line in hlo_text.splitlines():
        if F64_RE.search(line):
            hits.append(line.strip())
            if len(hits) >= limit:
                break
    return hits


def scan_constant_bytes(hlo_text: str, min_bytes: int = 0) -> list:
    """(nbytes, stripped line) per HLO constant instruction with
    nbytes >= min_bytes, largest first."""
    out = []
    for line in hlo_text.splitlines():
        m = CONSTANT_RE.search(line)
        if m is None:
            continue
        nbytes = _shape_bytes(m.group(1))
        if nbytes >= min_bytes:
            out.append((nbytes, line.strip()))
    return sorted(out, key=lambda x: -x[0])


def scan_host_transfer_ops(hlo_text: str) -> dict:
    """Host-transfer op name -> count (infeed/outfeed/send/recv)."""
    out: dict[str, int] = {}
    for m in HOST_TRANSFER_RE.finditer(hlo_text):
        out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out
