"""Exact HLO cost analysis with loop trip-count multipliers.

XLA's built-in `cost_analysis()` counts a while-loop body ONCE (verified:
a 10-iteration scan reports exactly 1/10 of the true dot FLOPs).  Since
every model here scans over layers (and chunked attention scans over query
blocks), that undercount is catastrophic.  This module re-derives:

  * dot FLOPs        = 2 * prod(out_shape) * prod(lhs_contracting_dims)
  * collective bytes = result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

per computation, then walks the call graph (fusion `calls=`, `to_apply=`,
while `body=`/`condition=`, conditionals) multiplying by the while trip
count parsed from each loop condition's comparison constant.

Collective bytes are split into in-pod vs cross-pod from replica_groups
(pod = 256 devices), which feeds the strapped-collective analysis.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# header params may contain nested parens (tuple types) -> greedy match
COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
TUPLE_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\(")
CALL_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)="
    r"%?([\w\.\-]+)")
BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
DOT_RE = re.compile(r"\bdot\(([^)]*)\)")
LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
COLLECTIVE_RE = re.compile(
    r"=\s*.*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


@dataclass
class Computation:
    name: str
    shapes: dict = field(default_factory=dict)       # instr -> (dtype, dims)
    dot_flops: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    coll_cross: float = 0.0
    coll_in: float = 0.0
    while_edges: list = field(default_factory=list)  # (body, condition)
    call_edges: list = field(default_factory=list)   # plain calls
    max_s32_const: int = 1


def _shape_bytes(dtype: str, dims: list[int]) -> int:
    n = int(np.prod(dims)) if dims else 1
    return n * DTYPE_BYTES.get(dtype, 4)


def _crosses_pod(line: str, pod_size: int) -> bool:
    gm = re.search(r"replica_groups=\{(.*?)\}\}", line)
    if gm:
        for g in re.findall(r"\{([0-9,]+)\}", gm.group(0)):
            ids = [int(x) for x in g.split(",") if x]
            if ids and max(ids) // pod_size != min(ids) // pod_size:
                return True
        return False
    gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                    r"(?:T\(([0-9,]+)\))?", line)
    if gm2:
        ngroups, gsize = int(gm2.group(1)), int(gm2.group(2))
        dims = [int(x) for x in gm2.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if gm2.group(4):
            arr = arr.transpose([int(x) for x in gm2.group(4).split(",")])
        arr = arr.reshape(ngroups, gsize)
        return bool((arr.max(1) // pod_size != arr.min(1) // pod_size).any())
    return False


def parse_module(text: str, pod_size: int = 256) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        head = COMP_HEAD.match(line.strip())
        if head and ("->" in line):
            cur = Computation(head.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = DEF_RE.match(line)
        if m:
            name, dtype, dims = m.group(1), m.group(2), m.group(3)
            if dtype in DTYPE_BYTES:
                shape = [int(x) for x in dims.split(",") if x]
                cur.shapes[name] = (dtype, shape)
        cm = CONST_RE.search(line)
        if cm:
            cur.max_s32_const = max(cur.max_s32_const, int(cm.group(1)))
        # calls
        if "while(" in line:
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            if body and cond:
                cur.while_edges.append((body.group(1), cond.group(1)))
        else:
            for cm2 in CALL_RE.finditer(line):
                cur.call_edges.append(cm2.group(1))
            bm = BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        cur.call_edges.append(b)
        # dot flops
        dm = DOT_RE.search(line)
        if dm and "=" in line:
            out = DEF_RE.match(line)
            lc = LHS_CONTRACT_RE.search(line)
            if out and lc and out.group(2) in DTYPE_BYTES:
                out_dims = [int(x) for x in out.group(3).split(",") if x]
                operands = [t.strip() for t in dm.group(1).split(",")]
                lhs_name = None
                if operands:
                    nm = re.search(r"%([\w\.\-]+)", operands[0])
                    if nm:
                        lhs_name = nm.group(1)
                lhs = cur.shapes.get(lhs_name)
                if lhs:
                    cdims = [int(x) for x in lc.group(1).split(",") if x]
                    csize = int(np.prod([lhs[1][i] for i in cdims])) if cdims else 1
                    cur.dot_flops += 2.0 * float(np.prod(out_dims)) * csize
        # collectives
        km = COLLECTIVE_RE.search(line)
        if km and "-done(" not in line:
            out = DEF_RE.match(line)
            if out and out.group(2) in DTYPE_BYTES:
                nbytes = _shape_bytes(out.group(2),
                                      [int(x) for x in out.group(3).split(",")
                                       if x])
            else:
                # tuple result: sum member shapes on the line up to the op
                nbytes = 0
                for sm in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]",
                                      line.split("=", 1)[-1].split("(", 1)[0]):
                    if sm.group(1) in DTYPE_BYTES:
                        nbytes += _shape_bytes(
                            sm.group(1),
                            [int(x) for x in sm.group(2).split(",") if x])
            op = km.group(1).lower()
            cur.coll[op] += nbytes
            if _crosses_pod(line, pod_size):
                cur.coll_cross += nbytes
            else:
                cur.coll_in += nbytes
    return comps


def analyze(text: str, pod_size: int = 256) -> dict:
    comps = parse_module(text, pod_size)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c].shapes))

    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 50:
            return
        mult[name] += m
        c = comps[name]
        for callee in c.call_edges:
            if callee != name:
                visit(callee, m, depth + 1)
        for body, cond in c.while_edges:
            trip = comps[cond].max_s32_const if cond in comps else 1
            visit(cond, m * (trip + 1), depth + 1)
            visit(body, m * trip, depth + 1)

    visit(entry, 1.0)

    flops = 0.0
    coll_by_type: dict[str, float] = defaultdict(float)
    cross = in_pod = 0.0
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        flops += c.dot_flops * m
        for k, v in c.coll.items():
            coll_by_type[k] += v * m
        cross += c.coll_cross * m
        in_pod += c.coll_in * m
    return dict(dot_flops_per_device=flops,
                collective_bytes_by_type=dict(coll_by_type),
                collective_bytes_total=sum(coll_by_type.values()),
                cross_pod_bytes=cross, in_pod_bytes=in_pod,
                n_computations=len(comps))
