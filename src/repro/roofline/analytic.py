"""Analytic per-device HBM traffic model for the roofline memory term.

XLA's `cost_analysis()` "bytes accessed" suffers the same while-body-once
undercount as its FLOPs (verified, see hlo_exact.py) AND counts fusion-
internal traffic that never leaves VMEM on a real TPU.  For the memory
term we therefore use a first-principles model of what must actually cross
HBM on a v5e per step, given the sharding rules in distributed/sharding.py
(TP=16 on `model`, FSDP over `data`, batch over DP axes):

train (per device):
    weights      3 x P_bytes / TP          (fwd + remat re-fwd + bwd reads
                                            of the gathered TP shard)
    grads        P_bytes / n_dev           (reduce-scattered shard write)
    optimizer    20 B/param / n_dev        (m,v read+write fp32, p r+w bf16)
    activations  4 x L x tok_dev x d x 2   (layer inputs w+r, fwd+bwd;
                                            nothing-saveable remat)
    logits       2 x tok_dev x V/TP x 4    (f32 write+read for CE)
prefill:
    weights 1x, activations 2x (no bwd), cache write, logits last token
decode:
    weights 1x (MoE: only the touched expert fraction) + full cache read
    + one-token cache write
"""

from __future__ import annotations

import numpy as np

from ..configs.base import SHAPE_CELLS
from ..models import registry as M

TP = 16


def _cache_bytes(cfg, b: int, s: int) -> int:
    sch = M.cache_schema(cfg, b, s)
    total = 0
    for k, spec in sch.items():
        itemsize = 4 if "ssm" in k else 2
        total += int(np.prod(spec.shape)) * itemsize
    return total


def hbm_bytes_per_device(cfg, cell: str, n_dev: int = 256) -> float:
    spec = SHAPE_CELLS[cell]
    b, s, kind = spec["global_batch"], spec["seq_len"], spec["kind"]
    dp = n_dev // TP
    n = cfg.param_count()
    p_bytes = 2 * n
    tok_dev = b * s / min(dp, b) if b >= 1 else b * s
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    v_shard = cfg.padded_vocab / TP

    if kind == "train":
        weights = 3 * p_bytes / TP
        grads = p_bytes / n_dev
        opt = (20 if cfg.optimizer == "adamw" else 8) * n / n_dev
        acts = 4 * L * tok_dev * d * 2
        logits = 2 * tok_dev * v_shard * 4
        return weights + grads + opt + acts + logits

    if kind == "prefill":
        weights = p_bytes / TP
        acts = 2 * L * tok_dev * d * 2
        cache = _cache_bytes(cfg, b, s) / n_dev
        logits = 2 * (b / min(dp, max(b, 1))) * v_shard * 4
        return weights + acts + cache + logits

    # decode: few tokens -> weights-stationary schedule (weights stay
    # sharded across ALL devices; activations travel + psum instead of
    # gathering weights), so each device reads only its own shard.
    if cfg.n_experts:
        frac = min(1.0, b * cfg.top_k / cfg.n_experts)
        expert_bytes = 2 * cfg.n_layers * cfg.n_experts * 3 * d * cfg.d_ff
        non_expert = p_bytes - expert_bytes
        weights = (non_expert + frac * expert_bytes) / n_dev
    else:
        weights = p_bytes / n_dev
    cache = _cache_bytes(cfg, b, s) / n_dev      # sharded across all devices
    if cfg.strap_decode and cfg.family in ("dense", "moe", "vlm"):
        # selector+strap: only the selected straps are read from HBM;
        # the dense baseline's one-hot update also rewrote the full cache
        # (r+w) which the scatter update avoids.
        nst = max(s // cfg.decode_strap_tokens, 1)
        frac = min(cfg.decode_top_straps, nst) / nst
        read = cache * frac + cache / max(s, 1) * 64   # + ksum metadata
        write = cache / max(s, 1)
        return weights + read + write
    # baseline dense decode: attention read + one-hot full-cache rewrite
    write = cache / max(s, 1)
    return weights + 3 * cache + write


def model_flops(cfg, cell: str) -> float:
    """Mandated MODEL_FLOPS: 6*N*D train (N_active for MoE); 2*N*D prefill;
    2*N*B decode."""
    spec = SHAPE_CELLS[cell]
    b, s, kind = spec["global_batch"], spec["seq_len"], spec["kind"]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * b * s
    if kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b
