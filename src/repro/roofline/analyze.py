"""Three-term roofline from the dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs_total    / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_total    / (chips * 819e9  B/s HBM)
  collective = per-axis collective bytes / (chips * links * 50e9 B/s)

`cost_analysis()` reports per-device (post-SPMD) numbers -> multiply by
device count for totals.  Collective time uses the parsed per-op bytes:
in-pod ops ride ICI (~50 GB/s/link; a 2D-torus v5e chip has multiple
links, we budget 2 effective links for ring traffic on each mesh axis);
cross-pod bytes ride the DCI at an effective 25 GB/s per chip pair.

Also records MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; decode counts
D = global_batch tokens) and the useful-compute ratio.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per link
ICI_LINKS_EFFECTIVE = 2.0    # ring traffic rides 2 links per chip
DCN_BW = 25e9                # cross-pod effective bytes/s per chip

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    opt_level: int
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bytes_per_device: float
    coll_bytes_total: float
    cross_pod_bytes: float
    step_time_est: float
    mfu_bound: float

    def row(self) -> dict:
        return self.__dict__.copy()


def model_flops_for(d: dict) -> float:
    """6*N*D where D = trained/processed tokens of the cell."""
    b, s = d["global_batch"], d["seq_len"]
    n = d["active_params"]
    if d["kind"] == "train":
        return 6.0 * n * b * s
    if d["kind"] == "prefill":
        return 2.0 * n * b * s          # forward only
    return 2.0 * n * b                   # decode: 1 token per sequence


def analyze_one(d: dict) -> Roofline:
    chips = d["devices"]
    exact = d.get("hlo_exact")
    if exact:
        # loop-corrected dot FLOPs (per device) from the optimized HLO
        flops_total = exact["dot_flops_per_device"] * chips
        in_pod = exact["in_pod_bytes"]
        cross = exact["cross_pod_bytes"]
        coll_total = exact["collective_bytes_total"]
    else:  # legacy artifacts (uncorrected — kept for comparison only)
        flops_total = d["flops_per_device"] * chips
        coll = d.get("collectives", {})
        in_pod = coll.get("in_pod_bytes", 0.0)
        cross = coll.get("cross_pod_bytes", 0.0)
        coll_total = coll.get("total_bytes", 0.0)
    # recompute the analytic HBM model at analysis time so baseline and
    # opt-level variants always use the same (latest) traffic model
    try:
        from ..configs.registry import get_arch
        from ..launch.optlevels import apply_opt_level
        from .analytic import hbm_bytes_per_device
        cfg = apply_opt_level(get_arch(d["arch"]), d["cell"],
                              d.get("opt_level", 0))
        bytes_dev = hbm_bytes_per_device(cfg, d["cell"], chips)
    except Exception:
        bytes_dev = d.get("analytic_hbm_bytes_per_device",
                          d.get("bytes_accessed_per_device", 0.0))
    t_comp = flops_total / (chips * PEAK_FLOPS)
    t_mem = bytes_dev / HBM_BW
    # per-op collective bytes are per-device payloads
    t_coll = (in_pod / (ICI_LINKS_EFFECTIVE * ICI_LINK_BW)
              + cross / DCN_BW)

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = d.get("model_flops") or model_flops_for(d)
    useful = mf / flops_total if flops_total else 0.0
    # perfect-overlap step estimate: max of the three engines
    t_step = max(terms.values())
    mfu = (mf / (chips * PEAK_FLOPS)) / t_step if t_step else 0.0
    return Roofline(
        arch=d["arch"], cell=d["cell"], mesh=d["mesh"],
        opt_level=d.get("opt_level", 0),
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dominant, model_flops=mf, hlo_flops_total=flops_total,
        useful_ratio=useful, bytes_per_device=bytes_dev,
        coll_bytes_total=coll_total,
        cross_pod_bytes=cross, step_time_est=t_step, mfu_bound=mfu)


def load_all(mesh: str | None = "single", opt_level: int | None = 0
             ) -> list[Roofline]:
    out = []
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            continue
        if mesh and d["mesh"] != mesh:
            continue
        if opt_level is not None and d.get("opt_level", 0) != opt_level:
            continue
        out.append(analyze_one(d))
    return out


def table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':22s} {'cell':12s} {'mesh':6s} {'comp(ms)':>9s} "
           f"{'mem(ms)':>9s} {'coll(ms)':>9s} {'dominant':>10s} "
           f"{'useful':>7s} {'MFU-bnd':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r.arch, r.cell)):
        lines.append(
            f"{r.arch:22s} {r.cell:12s} {r.mesh:6s} "
            f"{1e3 * r.t_compute:9.3f} {1e3 * r.t_memory:9.3f} "
            f"{1e3 * r.t_collective:9.3f} {r.dominant:>10s} "
            f"{r.useful_ratio:7.3f} {r.mfu_bound:8.3f}")
    return "\n".join(lines)


def interesting_cells(rows: list[Roofline]) -> dict:
    """Hillclimb candidates: worst roofline fraction, most collective-bound,
    most paper-representative (largest decode memory term = the StrapCache /
    C_BL analogue)."""
    trains = [r for r in rows if r.cell == "train_4k"]
    worst = min(trains, key=lambda r: r.mfu_bound) if trains else None
    coll = max(rows, key=lambda r: (r.t_collective /
                                    max(r.step_time_est, 1e-12)))
    decodes = [r for r in rows if "decode" in r.cell or "long" in r.cell]
    paper = max(decodes, key=lambda r: r.t_memory) if decodes else None
    return dict(worst_mfu=worst, most_collective=coll, paper_rep=paper)


def main():
    rows = load_all()
    print(table(rows))
    print()
    picks = interesting_cells(rows)
    for k, r in picks.items():
        if r:
            print(f"{k}: {r.arch} / {r.cell} (dominant={r.dominant}, "
                  f"MFU-bound={r.mfu_bound:.3f})")


if __name__ == "__main__":
    main()
